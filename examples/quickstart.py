"""Quickstart: train a tiny LM for a few steps, then generate.

    PYTHONPATH=src python examples/quickstart.py [--arch yi-6b]

Runs on a single CPU device in under a minute: reduced config of the chosen
architecture, synthetic bigram data (learnable), AdamW, greedy decode.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

import repro.core as jmpi
from repro.configs import arch_names, get_tiny
from repro.configs.base import RunConfig, ShapeCell
from repro.launch.mesh import make_host_mesh
from repro.models import lm as lm_lib
from repro.serve.engine import Engine, ServeConfig
from repro.train import optim
from repro.train.data import SyntheticLM
from repro.train.trainer import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=arch_names())
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_tiny(args.arch)
    print(f"[quickstart] arch={cfg.name} (reduced), "
          f"jmpi initialized={jmpi.initialized()}")

    mesh = make_host_mesh(1, axes=("data",))
    cell = ShapeCell("quick", seq_len=64, global_batch=8, kind="train")
    rc = RunConfig(learning_rate=3e-3)
    bundle = build_train_step(cfg, rc, mesh, cell)
    step = bundle.jitted()

    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params, rc)
    data = SyntheticLM(cfg, cell.global_batch, cell.seq_len)

    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"  step {i:3d} loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")
    print(f"[quickstart] trained {args.steps} steps in "
          f"{time.perf_counter()-t0:.1f}s")

    if not cfg.embeds_input and not cfg.n_img_tokens:
        eng = Engine(cfg, params, ServeConfig(max_prompt=16, max_new_tokens=8))
        prompts = np.asarray(data.batch_at(0)["tokens"][:2, :16])
        out = eng.generate(prompts)
        print(f"[quickstart] generated tokens:\n{out}")


if __name__ == "__main__":
    main()
