"""Batched serving demo: prefill + decode with KV caches over the engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch h2o-danube-3-4b]

Loads a reduced config of the chosen architecture (fresh random weights —
this demonstrates the serving *path*: batched prefill, per-step decode with
donated caches, SWA ring caches where the arch uses them), runs a batch of
8 requests and reports tokens/s.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import arch_names, get_tiny  # noqa: E402
from repro.models import lm as lm_lib  # noqa: E402
from repro.serve.engine import Engine, ServeConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b", choices=arch_names())
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_tiny(args.arch)
    if cfg.embeds_input or cfg.n_img_tokens:
        print(f"{args.arch} needs modality inputs; pick a text arch")
        return
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_prompt=32,
                                          max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, 16),
                           dtype=np.int32)
    out = eng.generate(prompts)           # compile + generate
    t0 = time.perf_counter()
    out = eng.generate(prompts)
    dt = time.perf_counter() - t0
    total = out.shape[0] * out.shape[1]
    print(f"[serve_lm] {cfg.name}: batch={args.batch} "
          f"new_tokens={out.shape[1]} -> {total/dt:.0f} tok/s "
          f"(window={cfg.window})")
    print(out[:2])


if __name__ == "__main__":
    main()
