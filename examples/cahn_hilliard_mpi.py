"""Paper Listing 7: distributed Cahn–Hilliard via py-pde's recipe.

    PYTHONPATH=src python examples/cahn_hilliard_mpi.py

8 emulated ranks, decomposition [2, -1] exactly as the paper's listing;
droplet statistics printed as the simulation coarsens.
"""

import os
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")

import jax  # noqa: E402
from repro.core import compat
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.pde import cahn_hilliard as ch  # noqa: E402


def main():
    n = 128
    rng = np.random.default_rng(0)
    # paper: ScalarField.random_uniform(grid, 0.49, 0.51)
    state = jnp.asarray(rng.uniform(0.49, 0.51, (n, n)), jnp.float32)

    mesh = compat.make_mesh((2, 4), ("px", "py"))
    run = ch.make_solver(mesh, decomposition=(2, -1), dt=1e-3, k=0.01,
                         c0=0.5, inner_steps=200)

    print(f"Cahn–Hilliard on {n}x{n}, decomposition [2,-1] over 8 ranks")
    t0 = time.perf_counter()
    for outer in range(5):
        state = run(state)
        c = np.asarray(state)
        print(f"  t={(outer+1)*200} steps: <c>={c.mean():.4f} "
              f"std={c.std():.4f} min={c.min():.3f} max={c.max():.3f}")
    print(f"done in {time.perf_counter()-t0:.1f}s "
          f"(1000 steps, halo exchange inside the compiled block)")


if __name__ == "__main__":
    main()
