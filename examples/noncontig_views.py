"""Non-contiguous payloads & ragged collectives (paper §2.3 / Listing 6).

    PYTHONPATH=src python examples/noncontig_views.py

Runs on an emulated 8-device mesh and shows the derived-datatype layer:

1. **Listing-6 analogue** — a transposed (Fortran-order-style) array slice
   travels rank 0 → rank 1 without any manual staging copy: the ``View``
   (sugar over a ``subarray`` datatype) packs on send and scatters on
   receive, exactly the usability contract numba-mpi gets from MPI
   datatypes.
2. **Strided columns as a ``vector`` datatype** — every second column of a
   matrix exchanged both ways, received into the mirrored strided layout.
3. **``scatterv`` of uneven chunks** — rank r receives r+1 rows of a
   ragged table (padded-buffer SPMD form: every rank's buffer is padded
   to the max count; the valid-row counts are static).
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat

N = 8


def main():
    mesh = compat.make_mesh((N,), ("ranks",))
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.standard_normal((N, 4, 6)), jnp.float32)

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=(P("ranks"), P("ranks")))
    def listing6(x):
        x = x[0]
        # --- 1. transposed view, columns 1:3 (Fortran-order analogue) ----
        xt = x.T                                    # (6, 4)
        src_view = jmpi.View(xt, (slice(None), slice(1, 3)))
        dst = jnp.zeros((6, 4), x.dtype)
        dst_view = jmpi.View(dst, (slice(None), slice(1, 3)))
        req = jmpi.isendrecv(src_view, pairs=[(0, 1)], recv_into=dst_view)
        status, landed = jmpi.wait(req)
        # --- 2. strided columns as an explicit vector datatype -----------
        # every second column of the (4, 6) block: 4 blocks of 3 with
        # stride 6 over the flat buffer is the LEFT half; the vector below
        # picks columns 0, 2, 4 (12 blocks of 1, stride 2).
        vec = jmpi.vector(12, 1, 2)
        recv_buf = jnp.full((4, 6), -1.0, x.dtype)
        req2 = jmpi.isendrecv(x, pairs=[(0, 1), (1, 0)], datatype=vec,
                              recv_into=vec.bind(recv_buf))
        _, strided = jmpi.wait(req2)
        return landed[None], strided[None]

    landed, strided = listing6(blocks)
    want = np.zeros((6, 4), np.float32)
    want[:, 1:3] = np.asarray(blocks[0]).T[:, 1:3]
    np.testing.assert_allclose(np.asarray(landed[1]), want, rtol=1e-6)
    print("[noncontig] Listing-6 transposed view exchange: OK "
          f"(rank1 received {want[:, 1:3].size} elements into a "
          f"(6, 4) enclosing array)")
    got = np.asarray(strided[1]).reshape(-1)
    np.testing.assert_allclose(got[0::2],
                               np.asarray(blocks[0]).reshape(-1)[0::2],
                               rtol=1e-6)
    assert (got[1::2] == -1.0).all(), "odd columns must keep prior contents"
    print("[noncontig] vector-datatype strided exchange: OK "
          "(odd columns untouched — MPI recv semantics)")

    # --- 3. scatterv of uneven chunks -----------------------------------
    counts = tuple(r + 1 for r in range(N))         # 1 + 2 + ... + 8 rows
    table = jnp.asarray(rng.standard_normal((sum(counts), 3)), jnp.float32)

    @jmpi.spmd(mesh, in_specs=P(), out_specs=P("ranks"))
    def deal(full):
        status, chunk = jmpi.scatterv(full, counts, root=0)
        return chunk[None]

    chunks = deal(table)
    offs = np.concatenate([[0], np.cumsum(counts)])
    for r in range(N):
        got = np.asarray(chunks[r])
        np.testing.assert_allclose(
            got[:counts[r]], np.asarray(table)[offs[r]:offs[r + 1]],
            rtol=1e-6)
        assert (got[counts[r]:] == 0).all()
    print(f"[noncontig] scatterv of uneven chunks {counts}: OK "
          f"(rank r holds r+1 valid rows of the padded "
          f"({max(counts)}, 3) buffer)")


if __name__ == "__main__":
    main()
