"""Paper Listings 1–4, end to end: estimate π with JIT-resident allreduce.

    PYTHONPATH=src python examples/pi_parallel.py

Spawns 4 emulated ranks (the paper's worker count), runs the ``pi``
benchmark suite (``repro.bench.suites.pi``) in-process — the whole
compute+communicate loop inside one compiled block (pi_numba_mpi
analogue) against the host round-trip variant (pi_mpi4py analogue) — and
prints the speedup table that paper Fig. 1 plots.
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro.bench.core import BenchConfig          # noqa: E402
from repro.bench.cli import run_suite_inprocess   # noqa: E402


def main():
    print("rank-parallel π (4 emulated ranks)\n")
    doc = run_suite_inprocess("pi", BenchConfig(quick=True, repeats=3),
                              echo=lambda _line: None)
    rows = {(r["name"], r["size"]): r for r in doc["rows"]}

    jit = next(r for (name, _), r in rows.items()
               if name == "pi_jit_speedup")
    print(f"JIT speedup of get_pi_part (paper Listing 1 ~100x): "
          f"{jit['value']:.1f}x\n")

    print("JIT-resident comm vs host round-trip (paper Fig. 1):")
    print(f"{'N_TIMES/n_intervals':>20s} {'speedup':>9s}   "
          f"{'t_jmpi':>9s} {'t_roundtrip':>12s}")
    for (name, x), r in sorted(rows.items(), key=lambda kv: kv[0][1]):
        if name != "pi_jitresident_speedup":
            continue
        t_jmpi = rows[("pi_jmpi", x)]["value"]
        t_rt = rows[("pi_roundtrip", x)]["value"]
        print(f"{x:>20d} {r['value']:8.2f}x   {t_jmpi:7.1f}ms "
              f"{t_rt:10.1f}ms")
    assert doc["invariants"]["pi_accurate"], "π estimate drifted"
    print("\nπ accuracy invariant: OK")


if __name__ == "__main__":
    main()
