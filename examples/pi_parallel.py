"""Paper Listings 1–4, end to end: estimate π with JIT-resident allreduce.

    PYTHONPATH=src python examples/pi_parallel.py

Spawns 4 emulated ranks (the paper's worker count), runs the whole
compute+communicate loop inside one compiled block (pi_numba_mpi analogue),
the host round-trip variant (pi_mpi4py analogue), and prints the speedup
table that paper Fig. 1 plots.
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import bench_pi  # noqa: E402


def main():
    print("rank-parallel π (4 emulated ranks)\n")
    rows = bench_pi.bench_jit_speedup()
    print(f"JIT speedup of get_pi_part (paper Listing 1 ~100x): "
          f"{rows[0][1]:.1f}x   [{rows[0][2]}]\n")
    print("JIT-resident comm vs host round-trip (paper Fig. 1):")
    print(f"{'N_TIMES/n_intervals':>20s} {'speedup':>9s}   detail")
    for name, val, derived in bench_pi.bench_speedup_sweep():
        x = name.split('x')[-1]
        print(f"{x:>20s} {val:9.2f}   {derived}")


if __name__ == "__main__":
    main()
