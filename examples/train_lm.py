"""End-to-end training driver: ~100M-param LM, few hundred steps, with
data-parallel ranks, checkpoint/restart, straggler watchdog and loss curve.

    PYTHONPATH=src python examples/train_lm.py \
        --params 100m --steps 300 --ranks 4 --ckpt /tmp/ckpt_lm

Defaults are sized for a laptop-class CPU (--params 20m --steps 60); pass
--params 100m --steps 300 for the full driver run.  Restarting the same
command resumes from the last checkpoint (delete --ckpt to start over).
"""

import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--params", default="20m", choices=["20m", "100m"])
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--ranks", type=int, default=4)
ap.add_argument("--ckpt", default="/tmp/repro_ckpt_lm")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={args.ranks}"
sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ModelConfig, RunConfig, ShapeCell  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import lm as lm_lib  # noqa: E402
from repro.train import checkpoint as ckpt  # noqa: E402
from repro.train import optim  # noqa: E402
from repro.train.data import SyntheticLM  # noqa: E402
from repro.train.ft import Watchdog  # noqa: E402
from repro.train.trainer import build_train_step  # noqa: E402


def model_config(size: str) -> ModelConfig:
    if size == "100m":
        return ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                           n_heads=12, n_kv_heads=4, d_ff=2048,
                           vocab_size=32000, dtype="float32")
    return ModelConfig(name="lm-20m", n_layers=8, d_model=320, n_heads=8,
                       n_kv_heads=4, d_ff=1024, vocab_size=8000,
                       dtype="float32")


def main():
    cfg = model_config(args.params)
    from repro.models.lm import count_params
    print(f"[train_lm] {cfg.name}: {count_params(cfg)/1e6:.1f}M params, "
          f"{args.ranks} DP ranks, batch {args.batch}x{args.seq}")

    mesh = make_host_mesh(args.ranks, axes=("data",))
    cell = ShapeCell("drv", seq_len=args.seq, global_batch=args.batch,
                     kind="train")
    rc = RunConfig(learning_rate=1e-3)
    step = build_train_step(cfg, rc, mesh, cell).jitted()
    data = SyntheticLM(cfg, args.batch, args.seq)
    watchdog = Watchdog(threshold=3.0)
    saver = ckpt.AsyncSaver()

    start = ckpt.latest_step(args.ckpt)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params, rc)
    if start is not None:
        (params, opt), start, _ = ckpt.restore(args.ckpt, (params, opt))
        start += 1
        print(f"[train_lm] resumed from step {start}")
    else:
        start = 0

    import time
    for i in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        dt = time.perf_counter() - t0
        if watchdog.observe(i, dt):
            print(f"  !! straggler flagged at step {i} ({dt:.2f}s)")
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"({dt*1e3:.0f} ms/step)")
        if i % 50 == 49:
            saver.save_async(args.ckpt, (params, opt), i)
    saver.wait()
    ckpt.save(args.ckpt, (params, opt), args.steps - 1)
    print(f"[train_lm] done; checkpoint at {args.ckpt} "
          f"(stragglers flagged: {len(watchdog.stragglers)})")


if __name__ == "__main__":
    main()
