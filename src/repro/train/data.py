"""Deterministic synthetic data pipeline (shard-aware, restart-replayable).

Production shape without a dataset dependency: an infinite token stream
generated per (step, shard) by counter-based hashing — any worker can
materialize any step's batch independently (no coordination), and restart
replay is exact: resuming from step N yields byte-identical batches, which
the fault-tolerance tests assert.

The "labels" are next-token targets with a deterministic structure
(shift + mix) so training has learnable signal for the convergence examples.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


def _philox(counter: np.ndarray, key: int) -> np.ndarray:
    """Cheap counter-based hash (splitmix-style), uint64 -> uint64."""
    x = counter.astype(np.uint64) + np.uint64(key * 0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic token stream with learnable bigram structure."""

    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        b_local = self.global_batch // n_shards
        rows = np.arange(b_local) + shard * b_local + step * self.global_batch
        cols = np.arange(self.seq_len + 1)
        ctr = rows[:, None] * np.uint64(1 << 32) + cols[None, :]
        h = _philox(ctr, self.seed + 1)
        v = self.cfg.vocab_size
        # bigram structure: token_{t+1} ≡ f(token_t) with noise
        raw = (h % np.uint64(v)).astype(np.int64)
        base = np.empty_like(raw)
        base[:, 0] = raw[:, 0]
        for t in range(1, raw.shape[1]):
            noisy = (h[:, t] % np.uint64(7)) == 0
            base[:, t] = np.where(noisy, raw[:, t],
                                  (base[:, t - 1] * 31 + 7) % v)
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        d = self.cfg.d_model
        if self.cfg.embeds_input:
            emb = (_philox(ctr[:, :-1, None] * np.uint64(131) +
                           np.arange(d)[None, None, :], self.seed + 2)
                   % np.uint64(2000)).astype(np.float32) / 1000.0 - 1.0
            out = {"embeds": emb.astype(np.float32), "labels": labels}
            if self.cfg.cross_attn:
                c = self.cfg.n_cond_tokens
                cnd = (_philox(rows[:, None, None] * np.uint64(17) +
                               np.arange(c)[None, :, None] * np.uint64(131071)
                               + np.arange(d)[None, None, :], self.seed + 3)
                       % np.uint64(2000)).astype(np.float32) / 1000.0 - 1.0
                out["cond"] = cnd.astype(np.float32)
        elif self.cfg.n_img_tokens:
            i = self.cfg.n_img_tokens
            img = (_philox(rows[:, None, None] * np.uint64(23) +
                           np.arange(i)[None, :, None] * np.uint64(524287)
                           + np.arange(d)[None, None, :], self.seed + 4)
                   % np.uint64(2000)).astype(np.float32) / 1000.0 - 1.0
            out = {"tokens": tokens[:, :-i] if i < tokens.shape[1] else tokens,
                   "image_embeds": img.astype(np.float32), "labels": labels}
        return out
