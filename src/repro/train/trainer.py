"""Step builders: train_step / prefill_step / decode_step, with GSPMD
shardings derived from the rule tables, remat+scan inherited from the model,
and donation set up so params/opt-state/caches update in place.

``comm_backend``:
  gspmd      — XLA-inferred collectives inside one jit program (baseline for
               the dry-run / roofline path).
  jmpi       — the paper's technique made explicit at trainer scale: the whole
               step runs under shard_map and the data-parallel gradient
               mean is an explicit ``jmpi.allreduce`` (with optional int8/bf16
               compression) *inside* the compiled program.
  hostbridge — the mpi4py analogue: per-step host round-trip gradient
               reduction between two jit dispatches (paper Listing 2's cost,
               measured in benchmarks/bench_trainer_comm.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as jmpi
from repro.configs.base import ModelConfig, RunConfig, ShapeCell
from repro.distributed import sharding as sh
from repro.distributed.params import ParamSharder
from repro.launch import specs as specs_lib
from repro.models import lm as lm_lib
from repro.train import optim


def _dp_size(mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes.get(a, 1) for a in ("pod", "data")]))


def model_rules(cfg, cell: Optional[ShapeCell] = None, extra=None):
    rules = {}
    if cell is not None and cell.kind == "decode" and cell.global_batch == 1:
        rules.update(sh.CONTEXT_PARALLEL_RULES)
    elif cell is not None and cell.kind == "decode" and (
            cfg.mla or cfg.n_kv_heads % 16 != 0):
        # KV caches with few KV heads (and MLA latent caches) shard their
        # sequence over `model` (ParamSharder.cache_specs); the activation
        # rule must MATCH or the in-model constraint forces an all-gather of
        # the whole cache every step (found in §Perf cell C: 6.05 GB/step of
        # self-inflicted gathers).  Partial-KV attention + psum combine is
        # what GSPMD derives once the layouts agree.
        rules.update({"kv_seq": (("model",), None)})
    if cfg.n_experts and cfg.n_experts % 16 != 0:
        # expert-TP fallback (mixtral): experts replicated, expert FF sharded
        rules.update({"experts": (None,), "expert_ff": (("model",),)})
    if cell is not None and cell.kind in ("train", "prefill") \
            and cfg.n_heads % 16 != 0:
        # §Perf A3 (confirmed −43% on the dominant term): when heads can't
        # shard over `model`, shard the attention query-sequence there
        # instead of replicating the whole attention computation 16×.
        rules.setdefault("seq_attn", (("model",), None))
    if extra:
        rules.update(extra)
    return rules


class StepBundle:
    """A step function plus everything needed to lower it."""

    def __init__(self, fn, in_shardings, out_shardings, donate_argnums=(),
                 args_struct=None):
        self.fn = fn
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.donate_argnums = donate_argnums
        self.args_struct = args_struct

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args_struct)


def build_train_step(cfg: ModelConfig, run_cfg: RunConfig, mesh, cell,
                     rules_extra=None) -> StepBundle:
    """GSPMD train step: loss → grads → clip → optimizer, one XLA program."""
    cfg.moe_groups = _dp_size(mesh)
    rules = model_rules(cfg, cell, rules_extra)
    expert_2d = bool(rules.pop("_expert_2d", False))
    sharder = ParamSharder(cfg, mesh, expert_2d=expert_2d)

    params_struct = jax.eval_shape(
        lambda: lm_lib.init_params(cfg, jax.random.PRNGKey(0)))
    opt_struct = jax.eval_shape(lambda: optim.init(params_struct, run_cfg))
    batch_struct = specs_lib.batch_struct(cfg, cell.global_batch,
                                          cell.seq_len, "train")

    p_shard = sharder.tree_shardings(params_struct)
    # moments shard like their params; scalars replicate
    if run_cfg.optimizer == "adamw":
        o_shard = {"m": sharder.tree_shardings(opt_struct["m"]),
                   "v": sharder.tree_shardings(opt_struct["v"]),
                   "step": NamedSharding(mesh, P())}
    else:
        o_shard = jax.tree.map(lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
                               opt_struct)
    b_shard = jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                           sharder.batch_specs(batch_struct))

    k = max(1, run_cfg.microbatch)

    def train_step(params, opt_state, batch):
        with sh.use_sharding(mesh, rules):
            def loss_fn(p, mb):
                loss, metrics = lm_lib.train_loss(p, cfg, mb)
                return loss, metrics

            if k == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                # Microbatched gradient accumulation (activation memory /k;
                # fp32 accumulator shards like the params).
                mbs = jax.tree.map(
                    lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]),
                    batch)

                def mb_body(acc, mb):
                    (l, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    acc = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                    return acc, (l, m)

                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, (losses, ms) = jax.lax.scan(mb_body, acc0, mbs)
                grads = jax.tree.map(lambda g: g / k, grads)
                metrics = jax.tree.map(lambda x: x.mean(), ms)

            grads, gnorm = optim.clip_by_global_norm(grads, run_cfg.grad_clip)
            new_params, new_opt = optim.update(params, grads, opt_state,
                                               run_cfg)
            metrics = dict(metrics, grad_norm=gnorm)
            return new_params, new_opt, metrics

    in_sh = (p_shard, o_shard, b_shard)
    out_sh = (p_shard, o_shard, None)
    return StepBundle(train_step, in_sh, out_sh, donate_argnums=(0, 1),
                      args_struct=(params_struct, opt_struct, batch_struct))


def build_prefill_step(cfg: ModelConfig, mesh, cell, rules_extra=None) -> StepBundle:
    cfg.moe_groups = _dp_size(mesh)
    rules = model_rules(cfg, cell, rules_extra)
    sharder = ParamSharder(cfg, mesh)
    params_struct = jax.eval_shape(
        lambda: lm_lib.init_params(cfg, jax.random.PRNGKey(0)))
    batch_struct = specs_lib.batch_struct(cfg, cell.global_batch,
                                          cell.seq_len, "prefill")
    p_shard = sharder.tree_shardings(params_struct)
    b_shard = jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                           sharder.batch_specs(batch_struct))

    def prefill_step(params, batch):
        with sh.use_sharding(mesh, rules):
            logits, caches = lm_lib.prefill(params, cfg, batch, cell.seq_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return token, caches

    return StepBundle(prefill_step, (p_shard, b_shard), None,
                      args_struct=(params_struct, batch_struct))


def build_decode_step(cfg: ModelConfig, mesh, cell, rules_extra=None,
                      fsdp: bool = True) -> StepBundle:
    """fsdp=False is serving mode: parameters shard over `model` only and
    replicate over `data` — an inference step has no optimizer, so the
    FSDP all-gather-per-step tax buys nothing (§Perf cell C); combine with
    cfg.param_dtype='bfloat16' for serving-weight memory."""
    cfg.moe_groups = _dp_size(mesh)
    context_parallel = cell.global_batch == 1
    rules = model_rules(cfg, cell, rules_extra)
    sharder = ParamSharder(cfg, mesh, fsdp=fsdp)
    params_struct = jax.eval_shape(
        lambda: lm_lib.init_params(cfg, jax.random.PRNGKey(0)))
    batch_struct = specs_lib.batch_struct(cfg, cell.global_batch,
                                          cell.seq_len, "decode")
    cache_struct = specs_lib.cache_struct(cfg, cell.global_batch, cell.seq_len)

    p_shard = sharder.tree_shardings(params_struct)
    b_shard = jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                           sharder.batch_specs(batch_struct))
    c_shard = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        sharder.cache_specs(cache_struct, context_parallel=context_parallel))
    t_shard = NamedSharding(mesh, P())

    def decode_step(params, batch, caches, t):
        with sh.use_sharding(mesh, rules):
            logits, new_caches = lm_lib.decode_step(params, cfg, batch,
                                                    caches, t)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return token, new_caches

    t_struct = jax.ShapeDtypeStruct((), jnp.int32)
    return StepBundle(decode_step, (p_shard, b_shard, c_shard, t_shard),
                      (None, c_shard), donate_argnums=(2,),
                      args_struct=(params_struct, batch_struct, cache_struct,
                                   t_struct))


def build_step(cfg, run_cfg, mesh, cell, rules_extra=None,
               decode_fsdp: bool = True) -> StepBundle:
    if cell.kind == "train":
        return build_train_step(cfg, run_cfg, mesh, cell, rules_extra)
    if cell.kind == "prefill":
        return build_prefill_step(cfg, mesh, cell, rules_extra)
    return build_decode_step(cfg, mesh, cell, rules_extra, fsdp=decode_fsdp)


# ===================================================================== #
# jmpi comm backend — the paper's technique at trainer scale
# ===================================================================== #

def build_jmpi_train_step(cfg: ModelConfig, run_cfg: RunConfig, mesh,
                          batch_shape, bucket: bool = True):
    """Whole train step under shard_map: forward/backward on the local batch
    shard, then an *explicit in-program* jmpi gradient allreduce (optionally
    int8/bf16-compressed), then the optimizer — compute and communication in
    ONE compiled block, exactly the numba-mpi thesis.  Model-parallel axes
    are not used here (pure DP over all mesh axes); intended for the π-scale
    examples and the trainer-comm benchmark.

    ``bucket=True`` flattens all gradient leaves into ONE fp32 vector and
    allreduces once (NCCL-style gradient bucketing): one collective per step
    instead of one per parameter — a beyond-paper optimization recorded in
    EXPERIMENTS.md §Perf.

    Collective algorithms: every jmpi op in the step goes through the
    algorithm registry, so the payload size picks the lowering at trace
    time.  ``run_cfg.collective_policy`` (path) installs a tuner-emitted
    policy table before tracing; ``run_cfg.collective_algorithm`` forces a
    specific algorithm for the gradient allreduce (bucketed → one big
    payload; per-leaf → each leaf routed by its own size).

    Compressed/overlapped sync (``repro.distributed.overlap``):
    ``run_cfg.grad_compression`` ("int8_ef" | "topk_ef") rides the stateful
    EF registry lowerings; ``run_cfg.grad_buckets`` splits the gradient tree
    into that many wire vectors; ``run_cfg.overlap_grad_sync`` issues every
    bucket's nonblocking allreduce before one ``waitall`` ahead of the
    optimizer, opening the overlap window for XLA's scheduler.
    """
    from repro.distributed import overlap as overlap_lib

    axes = tuple(mesh.axis_names)
    bits = run_cfg.grad_compression_bits
    # Policy is applied around the step's trace only (see local_step), so
    # one RunConfig's tuned table never leaks into other steps built in the
    # same process (A/B comparisons stay independent).
    policy_table = (jmpi.PolicyTable.load(run_cfg.collective_policy)
                    if run_cfg.collective_policy else None)
    grad_algo = run_cfg.collective_algorithm or None

    def local_step(params, opt_state, comp_state, batch):
        from repro.core import registry as registry_lib
        prev_policy = registry_lib.active_policy()
        if policy_table is not None:
            registry_lib.set_policy(policy_table)  # scoped to this trace
        try:
            return _local_step(params, opt_state, comp_state, batch)
        finally:
            registry_lib.set_policy(prev_policy)

    def _local_step(params, opt_state, comp_state, batch):
        comm = jmpi.Communicator(axes)
        n = comm.size()

        def loss_fn(p):
            loss, metrics = lm_lib.train_loss(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        # Gradient sync rides persistent plans (MPI_Allreduce_init): the
        # algorithm choice (grad_algo override or policy-by-size) is frozen
        # once per payload signature and the plan cache serves every later
        # step trace — no per-step registry/policy dispatch on the hot path.
        def _grad_plan(g):
            return comm.allreduce_init(jax.ShapeDtypeStruct(g.shape, g.dtype),
                                       algorithm=grad_algo)

        if bucket:
            if run_cfg.grad_compression or run_cfg.grad_buckets > 1 \
                    or run_cfg.overlap_grad_sync:
                # Multi-bucket / compressed / overlapped path: one wire
                # vector per bucket, stateful EF lowerings when compressed,
                # issue-all + waitall when overlapped.
                grads, comp_state = overlap_lib.bucketed_grad_sync(
                    grads, comp_state, comm=comm,
                    algorithm=run_cfg.grad_compression,
                    buckets=max(1, run_cfg.grad_buckets),
                    overlap=run_cfg.overlap_grad_sync, mean=True,
                    plan_algorithm=grad_algo)
            else:
                # ONE pytree datatype for the whole gradient tree (NCCL-
                # style bucketing as a derived datatype): dt.pack is the
                # fp32 wire vector, dt.unpack restores every leaf's shape
                # and dtype.
                grad_dt = jmpi.pytree(grads, wire_dtype=jnp.float32)
                vec = grad_dt.pack(grads)
                if bits:
                    comp_dt = jmpi.pytree(comp_state, wire_dtype=jnp.float32)
                    cvec = comp_dt.pack(comp_state)
                    _, rvec, nc = jmpi.compressed_allreduce(
                        vec, jmpi.CompressionState(error=cvec), comm=comm,
                        bits=bits, mean=True)
                    comp_state = comp_dt.unpack(nc.error)
                else:
                    _, rvec = jmpi.wait(_grad_plan(vec).start(vec))
                    rvec = rvec / n
                grads = grad_dt.unpack(rvec)
        else:
            flat, tdef = jax.tree.flatten(grads)
            if bits:
                cflat = tdef.flatten_up_to(comp_state)
                out_flat, new_c = [], []
                for g, cs in zip(flat, cflat):
                    _, r, nc = jmpi.compressed_allreduce(g, cs, comm=comm,
                                                         bits=bits, mean=True)
                    out_flat.append(r)
                    new_c.append(nc)
                grads = jax.tree.unflatten(tdef, out_flat)
                comp_state = jax.tree.unflatten(tdef, new_c)
            else:
                # per-leaf plans: same-shaped leaves share one cached plan
                grads = jax.tree.unflatten(
                    tdef, [jmpi.wait(_grad_plan(g).start(g))[1] / n
                           for g in flat])

        grads, gnorm = optim.clip_by_global_norm(grads, run_cfg.grad_clip)
        new_params, new_opt = optim.update(params, grads, opt_state, run_cfg)
        loss_plan = comm.allreduce_init(
            jax.ShapeDtypeStruct(loss.shape, loss.dtype))
        _, loss_mean = jmpi.wait(loss_plan.start(loss))
        return new_params, new_opt, comp_state, loss_mean / n

    pspec = jax.tree.map(lambda _: P(), jax.eval_shape(
        lambda: lm_lib.init_params(cfg, jax.random.PRNGKey(0))))
    from jax.sharding import PartitionSpec
    data_spec = P(axes)

    step = jmpi.spmd(mesh,
                     in_specs=(P(), P(), P(), data_spec),
                     out_specs=(P(), P(), P(), P()))(local_step)
    return step
