"""Sharded checkpointing with elastic resharding (fault-tolerance substrate).

Format: one ``.npz`` per host process holding its addressable shards +
a JSON index (tree structure, global shapes, mesh, step).  Single-process
here, but the layout is the multi-host one: each host writes only what it
owns; restore re-shards to whatever mesh the restarting job has — a job that
lost a pod restarts on the smaller mesh from the same checkpoint (elastic),
asserted by tests/test_ft.py.

Writes are atomic (tmp + rename) and ``save_async`` overlaps serialization
with the next training step — the checkpoint/restart half of the
straggler/failure story (DESIGN.md §7).
"""

from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, state_tree, step: int, extra: dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state_tree)
    tmp = os.path.join(path, ".tmp.shard0.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, os.path.join(path, "shard0.npz"))
    index = {
        "step": int(step),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
        "extra": extra or {},
    }
    tmpi = os.path.join(path, ".tmp.index.json")
    with open(tmpi, "w") as f:
        json.dump(index, f)
    os.replace(tmpi, os.path.join(path, "index.json"))


class AsyncSaver:
    """Overlap checkpoint serialization with compute (one in flight)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save_async(self, path, state_tree, step, extra=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, state_tree)  # device→host now
        self._thread = threading.Thread(
            target=save, args=(path, host_tree, step, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def restore(path: str, like_tree, mesh=None, shardings=None):
    """Restore into the structure of ``like_tree``; if ``shardings`` given,
    device_put each leaf with its (possibly different-mesh) sharding —
    elastic resharding is exactly this re-placement."""
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(path, "shard0.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for pathk, leaf in flat_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in pathk)
        arr = data[key]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"checkpoint/model shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, index["step"], index.get("extra", {})


def latest_step(path: str) -> int | None:
    idx = os.path.join(path, "index.json")
    if not os.path.exists(idx):
        return None
    with open(idx) as f:
        return json.load(f)["step"]
