"""Optimizers: AdamW (dtype-configurable moments — deepseek's bf16 memory
plan, DESIGN.md §5) and Adafactor (factored second moment) for the largest
cells.  Pure-pytree implementation: states shard exactly like their params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), grads), g


# ------------------------------------------------------------------ #
# AdamW
# ------------------------------------------------------------------ #

def adamw_init(params, run_cfg):
    dt = jnp.dtype(run_cfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, run_cfg):
    b1, b2, eps = run_cfg.beta1, run_cfg.beta2, run_cfg.eps
    lr, wd = run_cfg.learning_rate, run_cfg.weight_decay
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        u = (m32 / corr1) / (jnp.sqrt(v32 / corr2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (u + wd * p32)
        return (p_new.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ------------------------------------------------------------------ #
# Adafactor (factored second moments; beyond-paper memory lever)
# ------------------------------------------------------------------ #

def adafactor_init(params, run_cfg):
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(factored, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, run_cfg):
    lr = run_cfg.learning_rate
    step = state["step"] + 1
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
    eps = 1e-30

    def upd(p, g, f):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if p.ndim >= 2:
            vr = f["vr"] * decay + g2.mean(-1) * (1 - decay)
            vc = f["vc"] * decay + g2.mean(-2) * (1 - decay)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1, keepdims=True)[..., None], eps))
            u = g32 / jnp.sqrt(denom + eps)
            newf = {"vr": vr, "vc": vc}
        else:
            v = f["v"] * decay + g2 * (1 - decay)
            u = g32 / jnp.sqrt(v + eps)
            newf = {"v": v}
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), newf

    leaves_p, tdef = jax.tree.flatten(params)
    leaves_g = tdef.flatten_up_to(grads)
    leaves_f = tdef.flatten_up_to(state["f"])
    new_p, new_f = [], []
    for p, g, f in zip(leaves_p, leaves_g, leaves_f):
        a, b = upd(p, g, f)
        new_p.append(a)
        new_f.append(b)
    return (jax.tree.unflatten(tdef, new_p),
            {"f": jax.tree.unflatten(tdef, new_f), "step": step})


def init(params, run_cfg):
    if run_cfg.optimizer == "adamw":
        return adamw_init(params, run_cfg)
    if run_cfg.optimizer == "adafactor":
        return adafactor_init(params, run_cfg)
    raise ValueError(run_cfg.optimizer)


def update(params, grads, state, run_cfg):
    if run_cfg.optimizer == "adamw":
        return adamw_update(params, grads, state, run_cfg)
    return adafactor_update(params, grads, state, run_cfg)
