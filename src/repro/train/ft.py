"""Fault tolerance: step watchdog (straggler detection), failure-injection
hooks, and the checkpoint/restart/elastic-resume driver logic.

At 1000+ nodes the failure model is: slow chip (straggler), dead host
(restart from checkpoint, possibly on fewer pods), and data-loss-free resume
(deterministic data replay, repro.train.data).  What can be *executed* here
(single host) is the control logic — the tests inject failures and assert:

* the watchdog flags steps exceeding k·median latency,
* a crashed run restarts from the last checkpoint and replays the exact
  batch sequence (bitwise metric match),
* a run checkpointed on the 2-pod mesh resumes on the 1-pod mesh (elastic
  downsize) with identical loss trajectory.

On a real cluster the same watchdog feeds the coordinator that evicts the
straggler and triggers the elastic resume path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class Watchdog:
    """Flags steps slower than ``threshold``× the running median."""

    threshold: float = 3.0
    window: int = 32
    _lat: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        lat = sorted(self._lat[-self.window:])
        flagged = False
        if len(lat) >= 5:
            median = lat[len(lat) // 2]
            if seconds > self.threshold * median:
                self.stragglers.append((step, seconds, median))
                flagged = True
        self._lat.append(seconds)
        return flagged


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: raise at given steps.

    One-shot per scheduled step (a real node failure does not re-occur on
    the replayed step after restart)."""

    fail_at: tuple = ()
    kind: str = "crash"

    def __post_init__(self):
        self._pending = set(self.fail_at)

    def maybe_fail(self, step: int):
        if step in self._pending:
            self._pending.discard(step)
            raise RuntimeError(f"injected {self.kind} at step {step}")


def run_with_restarts(make_step_fn: Callable, init_state: Callable,
                      n_steps: int, ckpt_dir: str, ckpt_every: int = 10,
                      injector: Optional[FailureInjector] = None,
                      watchdog: Optional[Watchdog] = None,
                      max_restarts: int = 3):
    """Training driver: run → crash → restore → replay, up to max_restarts.

    make_step_fn() -> (step_fn, data_fn); step_fn(state, batch) -> (state,
    metrics).  Returns (final_state, history, n_restarts).
    """
    from repro.train import checkpoint as ckpt

    restarts = 0
    history = []
    while True:
        try:
            step_fn, data_fn = make_step_fn()
            start = ckpt.latest_step(ckpt_dir)
            if start is None:
                state, start = init_state(), 0
            else:
                state, start, _ = ckpt.restore(ckpt_dir, init_state())
                start += 1
            for step in range(start, n_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, data_fn(step))
                dt = time.perf_counter() - t0
                if watchdog is not None:
                    watchdog.observe(step, dt)
                history.append((step, metrics))
                if step % ckpt_every == ckpt_every - 1:
                    ckpt.save(ckpt_dir, state, step)
            return state, history, restarts
        except RuntimeError as e:
            if "injected" not in str(e) or restarts >= max_restarts:
                raise
            restarts += 1
