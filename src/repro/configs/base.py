"""Config schema: model architecture, input-shape cells, run options."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | vlm | audio

    # trunk
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 256
    vocab_pad_multiple: int = 128
    qkv_bias: bool = False
    mlp_type: str = "gated_silu"    # gated_silu | relu2
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # sliding-window attention (None = full)
    window: Optional[int] = None

    # MoE
    n_experts: int = 0
    top_k: int = 2
    n_shared_experts: int = 0
    first_k_dense: int = 0
    moe_ff: Optional[int] = None     # expert intermediate (defaults d_ff)
    dense_ff: Optional[int] = None   # d_ff of the first_k_dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    moe_groups: int = 1              # dispatch groups (launcher: = data shards)

    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # Mamba2 / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    shared_attn_every: int = 0       # zamba2: shared attn block cadence

    # xLSTM
    slstm_every: int = 0             # 1 sLSTM per this many blocks (0 = none)
    xlstm_proj_factor: float = 2.0

    # multi-token prediction (deepseek)
    mtp: bool = False

    # modality frontends (stubs: embeddings arrive via input_specs)
    n_img_tokens: int = 0            # vlm: patch-embedding positions
    n_cond_tokens: int = 0           # audio: cross-attn conditioning length
    cross_attn: bool = False
    embeds_input: bool = False       # inputs are frame embeddings, not tokens

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # training
    remat: str = "full"              # full | dots | none
    carry_barrier: bool = False      # pin layer-scan carries (defeats the
    # CPU-XLA whole-stack convert hoist; §Perf B5)

    def __post_init__(self):
        if self.head_dim is None:
            self.head_dim = self.d_model // self.n_heads
        if self.moe_ff is None:
            self.moe_ff = self.d_ff
        if self.dense_ff is None:
            self.dense_ff = self.d_ff

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid state or bounded SWA window)."""
        return self.ssm_state > 0 or self.family == "ssm" or self.window is not None

    def n_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS roofline math)."""
        from repro.models.lm import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        from repro.models.lm import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) column: seq_len × global_batch × step kind."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    needs_subquadratic: bool = False


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode",
                           needs_subquadratic=True),
}


@dataclasses.dataclass
class RunConfig:
    """Trainer/server runtime options."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # adamw | adafactor
    opt_state_dtype: str = "float32"  # float32 | bfloat16 (deepseek memory plan)
    comm_backend: str = "gspmd"      # gspmd | jmpi | hostbridge
    grad_compression_bits: int = 0   # 0 = off, 8 or 16
    # Compressed/bucketed gradient sync (repro.distributed.overlap):
    grad_compression: str = ""       # "" | int8_ef | topk_ef (registry lowering)
    grad_buckets: int = 1            # gradient-sync buckets (bucketed path)
    overlap_grad_sync: bool = False  # issue all bucket iallreduces, one waitall
    # Collective-algorithm registry knobs (repro.core.registry):
    collective_policy: str = ""      # path to a tuner-emitted policy JSON
    collective_algorithm: str = ""   # force the grad-allreduce algorithm
    microbatch: int = 0              # 0 = no grad accumulation
    seed: int = 0
