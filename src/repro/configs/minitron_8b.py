"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf].

Nemotron lineage: squared-ReLU (non-gated) MLP, huge sentencepiece vocab.
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16384, vocab_size=256000, mlp_type="relu2",
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-tiny", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8, mlp_type="relu2",
    )
