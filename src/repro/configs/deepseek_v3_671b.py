"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 (expert)
vocab=129280, MoE 1 shared + 256 routed top-8, MLA, MTP
[arXiv:2412.19437; hf].

MLA: q_lora 1536 / kv_lora 512 / qk_nope 128 / qk_rope 64 / v 128; decode
uses the latent-absorbed path (576 B-of-bf16 per token per layer cache).
First 3 layers dense (d_ff 18432).  EP: 256 experts / 16-wide model axis =
16 experts per shard.  Memory plan (DESIGN.md §5): bf16 optimizer moments,
no fp32 master (stochastic-rounding note) ⇒ 6 B/param ≈ 4.0 TB state.
MLA is *full* attention ⇒ long_500k skipped (assignment policy).
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, dense_ff=18432, vocab_size=129280,
        n_experts=256, top_k=8, n_shared_experts=1, first_k_dense=3,
        mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        mtp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-tiny", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, dense_ff=128, vocab_size=256, vocab_pad_multiple=8,
        n_experts=4, top_k=2, n_shared_experts=1, first_k_dense=1,
        mla=True, q_lora_rank=32, kv_lora_rank=24,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        mtp=True,
    )
