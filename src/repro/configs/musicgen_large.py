"""musicgen-large [audio]: 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Frontend stub (assignment): inputs are precomputed EnCodec frame embeddings
(sum of the 4 codebook embeddings); text conditioning enters via cross-attn
to a 256-token stub sequence.  Single 2048-way head as assigned (the real
model carries 4 parallel codebook heads — deviation noted in DESIGN.md §4).
Full attention → long_500k skipped.
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048, vocab_pad_multiple=128,
        mlp_type="gelu",  # musicgen: non-gated GELU FFN
        embeds_input=True, cross_attn=True, n_cond_tokens=256,
        tie_embeddings=False,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-tiny", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=64, vocab_pad_multiple=8,
        embeds_input=True, cross_attn=True, n_cond_tokens=8,
    )
