"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attn blocks
[arXiv:2411.15242; hf].

The shared transformer block (GQA 32H + 8192 MLP) is applied with *shared
weights* after every 6 Mamba2 layers (LoRA per-application specialization
omitted — DESIGN.md §4).  SSM ⇒ runs long_500k.
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_conv=4, ssm_headdim=64, ssm_expand=2,
        shared_attn_every=6,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-tiny", family="hybrid",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        ssm_state=16, ssm_conv=4, ssm_headdim=16, ssm_expand=2,
        shared_attn_every=3,
    )
