"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517; unverified].

d_ff=0: projections live inside the blocks (mLSTM up/down ×2, sLSTM post-MLP
4/3).  sLSTM every 8th block (7:1 mLSTM:sLSTM).  O(1) state ⇒ runs long_500k.
sLSTM's recurrence is inherently sequential (lax.scan over time) — noted in
the roofline analysis.
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=8, xlstm_proj_factor=2.0,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-tiny", family="ssm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=256, vocab_pad_multiple=8,
        slstm_every=2, xlstm_proj_factor=2.0,
    )
