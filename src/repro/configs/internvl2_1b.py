"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821; hf].

Vision frontend stub (assignment): ``input_specs`` provides 256 precomputed,
pre-projected patch embeddings that prepend the token embeddings.  vocab
151655 pads to 151680 (×128) for TP divisibility; 14 heads replicate across
the model axis (DESIGN.md §4 fallback).
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151655, vocab_pad_multiple=128,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
        n_img_tokens=256,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-tiny", family="vlm",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        qkv_bias=True, tie_embeddings=True, n_img_tokens=8,
    )
