"""Architecture registry: ``--arch <id>`` resolution."""

from repro.configs import (deepseek_v3_671b, h2o_danube_3_4b, internvl2_1b,
                           minitron_8b, mixtral_8x22b, musicgen_large,
                           qwen2_1p5b, xlstm_350m, yi_6b, zamba2_1p2b)
from repro.configs.base import SHAPES, ModelConfig, RunConfig, ShapeCell

__all__ = ["SHAPES", "ModelConfig", "RunConfig", "ShapeCell",
           "ARCHS", "get", "get_tiny"]

ARCHS = {
    "musicgen-large": musicgen_large,
    "zamba2-1.2b": zamba2_1p2b,
    "qwen2-1.5b": qwen2_1p5b,
    "minitron-8b": minitron_8b,
    "yi-6b": yi_6b,
    "h2o-danube-3-4b": h2o_danube_3_4b,
    "mixtral-8x22b": mixtral_8x22b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "xlstm-350m": xlstm_350m,
    "internvl2-1b": internvl2_1b,
}


def get_config(arch: str) -> ModelConfig:
    return ARCHS[arch].get_config()


def get_tiny(arch: str) -> ModelConfig:
    return ARCHS[arch].tiny()


def arch_names() -> list[str]:
    return list(ARCHS)
