"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088; hf].

8 experts < 16-wide model axis ⇒ expert-TP sharding (each expert's FF dim
shards over model; experts replicated) — DESIGN.md §4.  SWA ⇒ long_500k runs
with a bounded ring cache.
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768,
        n_experts=8, top_k=2, window=4096,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-tiny", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        n_experts=4, top_k=2, window=16,
    )
