"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias, tied embeddings [arXiv:2407.10671; hf].

12 heads are not divisible by the 16-wide model axis → attention weights
replicate across TP, MLP/vocab shard (DESIGN.md §4 fallback rule).
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, qkv_bias=True,
        rope_theta=1e6, tie_embeddings=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-tiny", family="dense",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=1,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8,
        qkv_bias=True, tie_embeddings=True,
    )
