"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

SWA window 4096 ⇒ bounded KV cache ⇒ runs long_500k (ring cache).
head_dim = 3840/32 = 120 (not 128-aligned; noted for the MXU in the kernel
BlockSpec discussion).
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab_size=32000, window=4096,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-tiny", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vocab_pad_multiple=8, window=16,
    )
