"""Mamba2 SSD (state-space dual) chunked-scan Pallas kernel.

The SSD form turns the selective-scan recurrence into per-chunk matmuls
(MXU work) plus an O(n_chunks) state carry.  Grid = (B, H, n_chunks) with
the chunk axis innermost & sequential: the (P, N) state lives in VMEM
scratch across chunk steps — the inter-chunk recurrence never touches HBM.

Per grid step the VMEM working set at L=chunk=128, P=64, N=128:
x (L·P) + B,C (2·L·N) + dt (L) + masks (L·L) + state (P·N fp32)
≈ (128·64 + 2·128·128 + 128·128)·4B + 64·128·4B ≈ 0.4 MiB — small; the
kernel is compute-dense (three L×L / L×N / L×P matmuls per chunk).

Numerics follow repro.models.ssm.ssd_chunked exactly (fp32 segment sums,
exp-of-negative decays), so kernel↔model↔oracle agree to float tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.core import compat


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, hout_ref,
            state_ref, *, chunk, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    L = chunk
    x = x_ref[0, 0].astype(jnp.float32)                   # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                 # (L,) as (L,1)? ->
    dt = dt.reshape(L)
    Bm = b_ref[0].astype(jnp.float32)                     # (L, N)
    Cm = c_ref[0].astype(jnp.float32)                     # (L, N)
    A = a_ref[0, 0]                                       # scalar (negative)
    D = d_ref[0, 0]

    dA = dt * A                                           # (L,)
    seg = jnp.cumsum(dA)                                  # (L,)
    rel = seg[:, None] - seg[None, :]                     # (L, L)
    tril = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    M = jnp.where(tril, jnp.exp(rel), 0.0)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L,L)
    W = G * M * dt[None, :]
    y_intra = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # carried-state contribution: exp(seg_t) · C_t · h_prev^T  -> (L, P)
    ch = jax.lax.dot_general(Cm, state_ref[...],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, P)
    y = y_intra + jnp.exp(seg)[:, None] * ch + D * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h = exp(seg_L)·h + Σ_u exp(seg_L − seg_u)·dt_u·x_u⊗B_u
    segL = seg[L - 1]
    wk = jnp.exp(segL - seg) * dt                         # (L,)
    xw = x * wk[:, None]                                  # (L, P)
    upd = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(segL) + upd

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = state_ref[...]


def mamba2_ssd_bhlp(x, dt, B, C, A, D, *, chunk=128, interpret=False):
    """x: (b,H,S,P); dt: (b,H,S); B,C: (b,S,N); A,D: (H,).

    Returns (y (b,H,S,P), h_final (b,H,P,N)). fp32 state math.
    """
    b, H, s, P = x.shape
    N = B.shape[-1]
    L = min(chunk, s)
    nc = -(-s // L)
    if s % L:
        pad = nc * L - s
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_kernel, chunk=L, n_chunks=nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bi, h, c: (bi, h, c, 0)),
            pl.BlockSpec((1, 1, L), lambda bi, h, c: (bi, h, c)),
            pl.BlockSpec((1, L, N), lambda bi, h, c: (bi, c, 0)),
            pl.BlockSpec((1, L, N), lambda bi, h, c: (bi, c, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, c: (h, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bi, h, c: (bi, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, c: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, nc * L, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, A.reshape(H, 1), D.reshape(H, 1))
    return y[:, :, :s], hout
