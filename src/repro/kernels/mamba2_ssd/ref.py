"""Pure-jnp oracle: sequential (non-chunked) selective-scan recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, B, C, A, D):
    """x: (b,H,S,P); dt: (b,H,S); B,C: (b,S,N); A,D: (H,).

    h_t = exp(dt_t·A)·h_{t−1} + dt_t·x_t⊗B_t ;  y_t = C_t·h_t + D·x_t.
    Returns (y (b,H,S,P), h_final (b,H,P,N)).
    """
    b, H, s, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp          # (b,H,P), (b,H), (b,N), (b,N)
        decay = jnp.exp(dtt * A[None, :])                  # (b,H)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    xs = (jnp.moveaxis(x, 2, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 2, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    h0 = jnp.zeros((b, H, P, N), jnp.float32)
    hf, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 2) + D[None, :, None, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), hf
