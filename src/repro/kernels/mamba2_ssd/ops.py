"""jit'd wrapper for the SSD kernel (model layout (B,S,H,P) adapters)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_ssd.kernel import mamba2_ssd_bhlp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x, dt, B, C, A, D, *, chunk=128, interpret=None):
    """x: (b,S,H,P); dt: (b,S,H); B,C: (b,S,N); A,D: (H,).

    Returns (y (b,S,H,P), h_final (b,H,P,N)).
    """
    it = (not _on_tpu()) if interpret is None else interpret
    xt = jnp.moveaxis(x, 2, 1)          # (b,H,S,P)
    dtt = jnp.moveaxis(dt, 2, 1)        # (b,H,S)
    y, hf = mamba2_ssd_bhlp(xt, dtt, B, C, A, D, chunk=chunk, interpret=it)
    return jnp.moveaxis(y, 1, 2), hf
