from repro.kernels.mamba2_ssd.ops import mamba2_ssd

__all__ = ["mamba2_ssd"]
