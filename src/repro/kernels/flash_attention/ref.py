"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, n_kv_heads, window=None, scale=None):
    """q: (B,H,S,D); k/v: (B,KH,T,D) — naive masked softmax attention."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, kh, g, s, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) * sc
    qpos = (t - s) + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jnp.exp(logits - logits.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    o = jnp.einsum("bkgst,bktd->bkgsd", w, vf)
    return o.reshape(b, h, s, d).astype(q.dtype)
