"""Flash attention (forward) Pallas TPU kernel: causal, GQA, sliding window.

Tiling: grid = (B·H, n_q_blocks, n_kv_blocks); the kv axis is the innermost
(sequential) grid dim so the online-softmax state lives in VMEM scratch
across kv steps.  Per grid step the VMEM working set is

    q (bq·D) + k,v (2·bk·D) + acc (bq·D fp32) + m,l (2·bq·MINLANE fp32)

≈ (512·128·2 + 2·512·128·2 + 512·128·4 + 2·512·128·4)B ≈ 0.9 MiB at the
default bq=bk=512, D=128 — comfortably under the ~16 MiB v5e VMEM, leaving
headroom for double buffering.  Block shapes keep the MXU-aligned 128 lane
dim; bq/bk are multiples of 8 (sublane).  GQA is handled in the index maps
(kv head = q head // group) — K/V are never physically expanded.

Fully-masked kv blocks (beyond the causal diagonal or left of the sliding
window) are skipped with pl.when: the MXU does no work for them, matching
the causal-optimal FLOP count of the XLA twin
(repro.models.attention.blockwise_sdpa).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.core import compat

NEG_INF = -2.0 ** 30
MINLANE = 128  # lane-aligned second dim for the m/l scratch


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, bq, bk, n_kv, seq_len, window, diag_offset):
    """One (bh, qi, ki) grid step."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # query rows qi*bq..+bq attend keys <= row + diag_offset (and window)
    q_lo = qi * bq + diag_offset
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    in_causal = k_lo <= q_hi
    in_window = True if window is None else (ki + 1) * bk - 1 >= q_lo - window + 1

    @pl.when(jnp.logical_and(in_causal, in_window))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                              # (bq,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, n_kv_heads, window=None, scale=None,
                         bq=512, bk=512, interpret=False):
    """q: (B,H,S,D); k/v: (B,KH,T,D). Returns (B,H,S,D).

    Causal with diagonal offset T−S (so S<T suffix-decode works).
    """
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // n_kv_heads
    bq = min(bq, s)
    bk = min(bk, t)
    nq, nk = -(-s // bq), -(-t // bk)
    if s % bq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - s), (0, 0)))
    if t % bk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - t), (0, 0)))
    qf = q.reshape(b * h, nq * bq, d)
    kf = k.reshape(b * kh, nk * bk, d)
    vf = v.reshape(b * kh, nk * bk, d)
    sc = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _kernel, scale=sc, bq=bq, bk=bk, n_kv=nk, seq_len=t,
        window=window, diag_offset=t - s)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qi, ki, g=g, kh=kh: (
                             (bh // (g * kh)) * kh + (bh % (g * kh)) // g,
                             ki, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bh, qi, ki, g=g, kh=kh: (
                             (bh // (g * kh)) * kh + (bh % (g * kh)) // g,
                             ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, MINLANE), jnp.float32),
            pltpu.VMEM((bq, MINLANE), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, nq * bq, d)[:, :, :s]
