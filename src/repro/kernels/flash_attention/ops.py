"""jit'd wrapper: model layout (B,S,H,D) ⇄ kernel layout (B,H,S,D); CPU
containers run the kernel body under interpret=True automatically."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_kv_heads", "window", "bq",
                                             "bk", "interpret"))
def flash_attention(q, k, v, *, n_kv_heads, window=None, bq=512, bk=512,
                    interpret=None):
    """q: (B,S,H,D); k/v: (B,T,KH,D). Returns (B,S,H,D)."""
    it = (not _on_tpu()) if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    ot = flash_attention_bhsd(qt, kt, vt, n_kv_heads=n_kv_heads,
                              window=window, bq=bq, bk=bk, interpret=it)
    return jnp.swapaxes(ot, 1, 2)
