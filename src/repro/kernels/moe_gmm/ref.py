"""Pure-jnp oracle for the grouped matmul kernel."""

import jax.numpy as jnp


def moe_gmm_ref(x, w, n_valid=None):
    """x: (E,C,D); w: (E,D,F); n_valid: (E,) -> (E,C,F), invalid rows 0."""
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if n_valid is not None:
        mask = jnp.arange(x.shape[1])[None, :, None] < n_valid[:, None, None]
        y = jnp.where(mask, y, 0.0)
    return y.astype(x.dtype)
