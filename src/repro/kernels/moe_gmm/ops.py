"""jit'd wrapper for the grouped matmul kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.kernel import moe_gmm_ecd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bc", "bf", "interpret"))
def moe_gmm(x, w, n_valid=None, *, bc=128, bf=128, interpret=None):
    """Grouped per-expert matmul. x: (E,C,D); w: (E,D,F) -> (E,C,F)."""
    it = (not _on_tpu()) if interpret is None else interpret
    return moe_gmm_ecd(x, w, n_valid, bc=bc, bf=bf, interpret=it)
