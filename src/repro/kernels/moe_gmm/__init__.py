from repro.kernels.moe_gmm.ops import moe_gmm

__all__ = ["moe_gmm"]
