"""Grouped (per-expert) matmul Pallas kernel — the MoE compute hot spot.

After capacity dispatch the expert computation is E independent matmuls
y[e] = x[e] @ w[e], x: (E, C, D), w: (E, D, F).  A plain XLA batched dot
treats E as a batch dim and tiles (C, F) generically; the kernel instead
makes the expert dim the outermost (parallel) grid axis so one expert's
weight panel streams through VMEM exactly once per (C-tile row), with
MXU-aligned (bc × D)·(D × bf) dots.

VMEM per grid step at bc = bf = 128, D = 7168 (deepseek experts):
x (128·7168·2B) + w (7168·128·2B) + y (128·128·4B) ≈ 3.7 MiB — double-
bufferable in the ~16 MiB v5e VMEM.  A capacity mask zeroes the padded
rows so dropped-token slots never contribute garbage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core import compat


def _kernel(x_ref, w_ref, nvalid_ref, y_ref, *, bc):
    ci = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)                     # (bc, D)
    w = w_ref[0].astype(jnp.float32)                     # (D, bf)
    y = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    rows = ci * bc + jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
    valid = rows < nvalid_ref[0, 0]
    y_ref[0] = jnp.where(valid, y, 0.0).astype(y_ref.dtype)


def moe_gmm_ecd(x, w, n_valid=None, *, bc=128, bf=128, interpret=False):
    """x: (E, C, D); w: (E, D, F); n_valid: (E,) valid rows per expert
    (None = all).  Returns (E, C, F) with invalid rows zeroed."""
    e, c, d = x.shape
    f = w.shape[-1]
    bc = min(bc, c)
    bf = min(bf, f)
    nc, nf = -(-c // bc), -(-f // bf)
    if c % bc:
        x = jnp.pad(x, ((0, 0), (0, nc * bc - c), (0, 0)))
    if f % bf:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, nf * bf - f)))
    nv = (jnp.full((e,), c, jnp.int32) if n_valid is None
          else n_valid.astype(jnp.int32)).reshape(e, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bc=bc),
        grid=(e, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda ei, ci, fi: (ei, ci, 0)),
            pl.BlockSpec((1, d, bf), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, 1), lambda ei, ci, fi: (ei, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ei, ci, fi: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, nc * bc, nf * bf), x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(x, w, nv)
    return out[:, :c, :f]
