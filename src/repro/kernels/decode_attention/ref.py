"""Pure-jnp oracle for the decode attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attention_ref(q, k, v, valid_mask, *, scale=None):
    """q: (B,KH,G,D); k/v: (B,KH,S,D); valid_mask: (S,) shared across the
    batch, or (B,S) per sequence (continuous batching)."""
    d = q.shape[-1]
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sc
    vm = valid_mask[None] if valid_mask.ndim == 1 else valid_mask
    s = jnp.where(vm[:, None, None, :] > 0, s, NEG_INF)
    w = jnp.exp(s - s.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    o = jnp.einsum("bkgt,bktd->bkgd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)
