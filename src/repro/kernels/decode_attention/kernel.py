"""Single-token decode attention Pallas kernel (memory-bound regime).

Decode attends one query token per sequence against a long KV cache: the
working set is the cache itself, so the kernel's job is to stream K/V
through VMEM exactly once at full HBM bandwidth while the online-softmax
state stays resident.

Tiling: grid = (B·KH, n_kv_blocks); one q block holds the G = H/KH query
heads of one kv group (rows ≤ 8 sublanes for small G — padded by Mosaic),
K/V blocks are (bk, D) slabs; slot-validity (ring caches, partially filled
caches) arrives as a precomputed int8 mask — (1, S) shared across the
batch, or (B, S) per sequence for paged/continuous batching — so the
kernel needs no scalar prefetch.  VMEM per step ≈ bk·D·2·2B + G·D·4B ≈ 0.27 MiB at bk=1024,
D=128 — double-buffering the K/V stream dominates, as it should for a
bandwidth-bound kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.core import compat

NEG_INF = -2.0 ** 30
MINLANE = 128


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                      # (G, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = mask_ref[0] > 0                               # (bk,)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, valid_mask, *, scale=None, bk=1024,
                             interpret=False):
    """q: (B,KH,G,D) one token per sequence; k/v: (B,KH,S,D);
    valid_mask: (S,) bool/int — which cache slots may be attended — or
    (B,S) with one validity row per sequence (paged/continuous batching,
    where slots advance at per-sequence positions).
    Returns (B,KH,G,D)."""
    b, kh, g, d = q.shape
    s = k.shape[2]
    bk = min(bk, s)
    nk = -(-s // bk)
    per_seq = valid_mask.ndim == 2
    if s % bk:
        pad = nk * bk - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        valid_mask = jnp.pad(valid_mask.astype(jnp.int8),
                             ((0, 0), (0, pad)) if per_seq else (0, pad))
    qf = q.reshape(b * kh, g, d)
    kf = k.reshape(b * kh, nk * bk, d)
    vf = v.reshape(b * kh, nk * bk, d)
    maskf = valid_mask.astype(jnp.int8).reshape(b if per_seq else 1, nk * bk)
    # grid axis 0 is b*kh with kh minor, so sequence = bh // kh
    mask_idx = ((lambda bh, ki: (bh // kh, ki)) if per_seq
                else (lambda bh, ki: (0, ki)))
    sc = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(_kernel, scale=sc, n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * kh, nk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk), mask_idx),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, MINLANE), jnp.float32),
            pltpu.VMEM((g, MINLANE), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, maskf)
    return out.reshape(b, kh, g, d)
