"""jit'd wrapper for decode attention (model cache layout adapters)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_grouped


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_kv_heads", "bk", "interpret"))
def decode_attention(q, k, v, valid_mask, *, n_kv_heads, bk=1024,
                     interpret=None):
    """q: (B,1,H,D) single new token; k/v cache: (B,S,KH,D);
    valid_mask: (S,) shared across the batch, or (B,S) per sequence
    (paged/continuous batching). Returns (B,1,H,D)."""
    it = (not _on_tpu()) if interpret is None else interpret
    b, _, h, d = q.shape
    kh = n_kv_heads
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, d)
    kt = jnp.swapaxes(k, 1, 2)                           # (B,KH,S,D)
    vt = jnp.swapaxes(v, 1, 2)
    o = decode_attention_grouped(qg, kt, vt, valid_mask, bk=bk, interpret=it)
    return o.reshape(b, 1, h, d)
