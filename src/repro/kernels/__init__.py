"""Pallas TPU kernels for the compute hot spots.

Each kernel package: <name>/kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), <name>/ops.py (jit'd wrapper, model-layout adapters, interpret-mode
fallback on CPU), <name>/ref.py (pure-jnp oracle used by the allclose tests).

TPU is the compile target; on this CPU container every kernel is validated
with interpret=True (the kernel body executes in Python with real data).
The XLA-native model paths (repro.models.*) implement the same contracts —
tests cross-check kernel vs model vs oracle.
"""
