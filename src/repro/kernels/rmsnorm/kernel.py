"""Fused RMSNorm Pallas kernel.

One HBM round-trip: read a (rows × D) tile, compute the fp32 row RMS,
scale, write back — versus the naive lowering's separate square/mean/rsqrt/
mul passes.  Grid over row blocks; D stays whole in the lane dim (model
dims here are ≤ 8192 ⇒ ≤ 32 KiB·rows of VMEM per tile at bf16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(x, scale, *, eps=1e-5, block_rows=256, interpret=False):
    """x: (R, D); scale: (D,). Returns (R, D)."""
    r, d = x.shape
    br = min(block_rows, r)
    nr = -(-r // br)
    if r % br:
        x = jnp.pad(x, ((0, nr * br - r), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, d), x.dtype),
        interpret=interpret,
    )(x, scale.reshape(1, d))
    return out[:r]
