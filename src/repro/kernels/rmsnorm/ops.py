"""jit'd wrapper for fused RMSNorm (arbitrary leading dims)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_rows


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fused(x, scale, *, eps=1e-5, block_rows=256, interpret=None):
    """x: (..., D); scale: (D,)."""
    it = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    out = rmsnorm_rows(x.reshape(-1, shape[-1]), scale, eps=eps,
                       block_rows=block_rows, interpret=it)
    return out.reshape(shape)
