from repro.kernels.rmsnorm.ops import rmsnorm_fused

__all__ = ["rmsnorm_fused"]
