"""Pure-jnp oracle for the fused RMSNorm kernel."""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)
