"""Test/bench harness utilities.

Multi-rank behaviour needs emulated devices, but
``--xla_force_host_platform_device_count`` is process-global and must never
leak into the main test process (smoke tests and benches see exactly 1
device).  Case modules therefore execute in a subprocess with the flag set
only there, run every ``case_*`` function, and report a per-case PASS/FAIL
transcript back to the parent.

Speed: the pytest wrappers call :func:`assert_case`, which runs the whole
case module ONCE per (module, device-count) — the transcript is cached and
each parametrized test just asserts its own case's slice.  That keeps
per-case reporting while paying the subprocess + jax-import cost once per
module instead of once per case.  Every case also runs under a per-case
SIGALRM timeout (default 120 s, ``REPRO_CASE_TIMEOUT`` to override) so one
hung case fails loudly instead of eating the blanket subprocess timeout.

Property-based testing: :func:`property_testing` returns hypothesis's
``(given, settings, strategies)`` when the real library is installed and a
minimal deterministic shim otherwise (seeded rng, ``max_examples`` draws,
first falsifying example reported) — the container image does not ship
hypothesis and nothing may be pip-installed there.
"""

from __future__ import annotations

import functools
import os
import subprocess
import sys

PER_CASE_TIMEOUT = int(os.environ.get("REPRO_CASE_TIMEOUT", "120"))


def child_env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    # keep child import path identical to parent
    env["PYTHONPATH"] = os.pathsep.join(p for p in (
        os.path.join(_repo_root(), "src"), env.get("PYTHONPATH", "")) if p)
    return env


def _repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(here))  # src/repro -> repo root


# Child runner: everything (incl. tracebacks) goes to stdout so the parent
# can attribute output lines to cases by position.
_RUNNER = r"""
import signal, sys, traceback
mod_name = sys.argv[1]
only = sys.argv[2] if len(sys.argv) > 2 and sys.argv[2] != "-" else None
per_case = int(sys.argv[3]) if len(sys.argv) > 3 else 0


class CaseTimeout(Exception):
    pass


def _alarm(signum, frame):
    raise CaseTimeout(f"case exceeded {per_case}s")


import importlib
mod = importlib.import_module(mod_name)
cases = [n for n in dir(mod) if n.startswith("case_")]
if only:
    cases = [c for c in cases if c == only]
failed = []
for name in sorted(cases):
    try:
        if per_case > 0 and hasattr(signal, "SIGALRM"):
            signal.signal(signal.SIGALRM, _alarm)
            signal.alarm(per_case)
        try:
            getattr(mod, name)()
        finally:
            if per_case > 0 and hasattr(signal, "SIGALRM"):
                signal.alarm(0)
        print(f"PASS {name}", flush=True)
    except CaseTimeout as e:
        failed.append(name)
        print(f"FAIL {name} (timeout: {e})", flush=True)
    except Exception:
        failed.append(name)
        print(f"FAIL {name}", flush=True)
        traceback.print_exc(file=sys.stdout)
        sys.stdout.flush()
sys.exit(1 if failed else 0)
"""


def _run_child(module: str, n_devices: int, only: str | None,
               timeout: int, per_case_timeout: int):
    return subprocess.run(
        [sys.executable, "-c", _RUNNER, module, only or "-",
         str(per_case_timeout)],
        env=child_env(n_devices), capture_output=True, text=True,
        timeout=timeout, cwd=_repo_root())


def run_cases(module: str, n_devices: int = 8, only: str | None = None,
              timeout: int = 900,
              per_case_timeout: int = PER_CASE_TIMEOUT) -> str:
    """Run all (or ``only`` one) case_* functions of ``module`` under N
    emulated devices, in a fresh subprocess.

    Returns the child transcript; raises AssertionError (with transcript) on
    any failure so pytest shows exactly which cases broke.  Prefer
    :func:`assert_case` in parametrized wrappers — it shares one subprocess
    across the whole module.
    """
    proc = _run_child(module, n_devices, only, timeout, per_case_timeout)
    transcript = proc.stdout + proc.stderr
    assert proc.returncode == 0, (
        f"case module {module} failed under {n_devices} devices:\n{transcript}")
    return transcript


@functools.lru_cache(maxsize=None)
def module_results(module: str, n_devices: int = 8, timeout: int = 900,
                   per_case_timeout: int = PER_CASE_TIMEOUT
                   ) -> dict[str, tuple[bool, str]]:
    """Run the whole case module once; return {case: (passed, log)}.

    Cached per (module, n_devices) for the life of the test process: the
    first parametrized test pays the subprocess, the rest read the cache —
    including module-level timeouts (cached as a failure, so a hung module
    costs the 900 s budget once, not once per parametrized test).
    """
    try:
        proc = _run_child(module, n_devices, None, timeout, per_case_timeout)
    except subprocess.TimeoutExpired as e:
        out = (e.stdout or b"")
        out = out.decode() if isinstance(out, bytes) else out
        return {"__timeout__": (
            False, f"case module {module} exceeded {timeout}s under "
                   f"{n_devices} devices; partial transcript:\n{out}")}
    results: dict[str, tuple[bool, str]] = {}
    current: str | None = None
    buf: list[str] = []
    for line in proc.stdout.splitlines():
        if line.startswith("PASS ") or line.startswith("FAIL "):
            if current is not None:
                ok, log = results[current]
                results[current] = (ok, "\n".join(buf))
            passed = line.startswith("PASS ")
            current = line.split()[1]
            results[current] = (passed, line)
            buf = [line]
        else:
            buf.append(line)
    if current is not None:
        ok, _ = results[current]
        results[current] = (ok, "\n".join(buf))
    if not results and proc.returncode != 0:
        # import-time crash: attribute the whole transcript to every lookup
        results["__import__"] = (
            False, f"case module {module} crashed under {n_devices} "
                   f"devices:\n{proc.stdout}{proc.stderr}")
    return results


def assert_case(module: str, case: str, n_devices: int = 8) -> None:
    """Assert one case of ``module`` passed (module runs once, cached)."""
    results = module_results(module, n_devices)
    for sentinel in ("__import__", "__timeout__"):
        if sentinel in results:
            raise AssertionError(results[sentinel][1])
    assert case in results, (
        f"case {case} not found in {module} under {n_devices} devices; "
        f"known: {sorted(results)}")
    passed, log = results[case]
    assert passed, (f"case {case} of {module} failed under {n_devices} "
                    f"devices:\n{log}")


# ---------------------------------------------------------------------------
# hypothesis-or-shim
# ---------------------------------------------------------------------------

class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _Strategies:
    """The subset of hypothesis.strategies the test-suite uses."""

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def _shim_settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def _shim_given(**kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def run():
            import numpy as np
            rng = np.random.default_rng(0)
            n = getattr(run, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 20))
            for _ in range(n):
                draw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(**draw)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on {draw!r}: {e}") from e
        return run
    return deco


def property_testing():
    """(given, settings, strategies) — hypothesis if installed, shim else.

    The shim draws ``max_examples`` deterministic examples (seeded rng) and
    reports the first falsifying draw; no shrinking, kwargs-style ``given``
    only — exactly the surface the case modules use.
    """
    try:
        from hypothesis import given, settings, strategies
        return given, settings, strategies
    except ImportError:
        return _shim_given, _shim_settings, _Strategies
