"""Test/bench harness utilities.

Multi-rank behaviour needs emulated devices, but
``--xla_force_host_platform_device_count`` is process-global and must never
leak into the main test process (smoke tests and benches see exactly 1
device).  ``run_cases`` therefore executes a *case module* in a subprocess
with the flag set only there, runs every ``case_*`` function, and reports a
per-case PASS/FAIL transcript back to the parent.
"""

from __future__ import annotations

import os
import subprocess
import sys


def child_env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    # keep child import path identical to parent
    env["PYTHONPATH"] = os.pathsep.join(p for p in (
        os.path.join(_repo_root(), "src"), env.get("PYTHONPATH", "")) if p)
    return env


def _repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(here))  # src/repro -> repo root


_RUNNER = r"""
import sys, traceback
mod_name = sys.argv[1]
only = sys.argv[2] if len(sys.argv) > 2 and sys.argv[2] != "-" else None
import importlib
mod = importlib.import_module(mod_name)
cases = [n for n in dir(mod) if n.startswith("case_")]
if only:
    cases = [c for c in cases if c == only]
failed = []
for name in sorted(cases):
    try:
        getattr(mod, name)()
        print(f"PASS {name}", flush=True)
    except Exception:
        failed.append(name)
        print(f"FAIL {name}", flush=True)
        traceback.print_exc()
sys.exit(1 if failed else 0)
"""


def run_cases(module: str, n_devices: int = 8, only: str | None = None,
              timeout: int = 900) -> str:
    """Run all case_* functions of ``module`` under N emulated devices.

    Returns the child transcript; raises AssertionError (with transcript) on
    any failure so pytest shows exactly which cases broke.
    """
    proc = subprocess.run(
        [sys.executable, "-c", _RUNNER, module, only or "-"],
        env=child_env(n_devices), capture_output=True, text=True,
        timeout=timeout, cwd=_repo_root())
    transcript = proc.stdout + proc.stderr
    assert proc.returncode == 0, (
        f"case module {module} failed under {n_devices} devices:\n{transcript}")
    return transcript
