"""Paged KV cache: fixed-size blocks, per-sequence block tables.

The device side is one flat pool of ``n_blocks * block_size`` token rows
per layer (``models.cache.init_paged_kv_cache``) shared by every in-flight
sequence.  This module is the host side: a free-list allocator over the
blocks and per-slot block tables — the irregular, index-driven structure
the PR-5 datatype layer was built to express.  A slot's table *is* an
``MPI_Type_indexed`` view of the pool (``core.datatypes.block_table``):
``seq_datatype`` returns that view, ``extract`` packs it into the dense
per-sequence K/V the equivalence oracle compares, and the engine's gather
rows are derived from the same table, pinned against the datatype's own
indices by ``tests/cases_serve.py`` so the two can never drift.

Block 0 is the scratch block: idle decode slots and prefill pad rows write
there (never attended), so every device step keeps a static shape with no
re-padding.  Admission is conservative — a request is admitted only when
the blocks for its whole lifetime (prompt + max_new - 1 written positions)
are free and reserved up front — so a running sequence can never hit a
mid-flight OOM and nothing needs preemption.
"""

from __future__ import annotations

import numpy as np

from repro.core import datatypes as dt
from repro.models import lm as lm_lib


class PagedKVCache:
    """Device pool + host allocator + per-slot block tables."""

    def __init__(self, cfg, n_blocks, block_size, max_slots, max_pages):
        """Build the pool and an empty allocator.

        Args:
            cfg: model config (GQA families only — see
                ``lm.init_paged_cache``).
            n_blocks: total pool blocks including the reserved scratch
                block 0 (so ``n_blocks - 1`` are allocatable).
            block_size: token rows per block.
            max_slots: concurrent sequence slots (the decode batch width).
            max_pages: table length per slot; ``max_pages * block_size``
                is the gathered KV length every step attends over.
        Raises:
            ValueError: fewer than 2 blocks (nothing left after scratch).
            NotImplementedError: the family's cache cannot be paged.
        """
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (block 0 is scratch), "
                             f"got {n_blocks}")
        self.cfg = cfg
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.max_slots = int(max_slots)
        self.max_pages = int(max_pages)
        self.pool = lm_lib.init_paged_cache(cfg, n_blocks, block_size)
        # LIFO free list over blocks 1..n_blocks-1; 0 in a table = scratch
        self._free = list(range(self.n_blocks - 1, 0, -1))
        self.tables = np.zeros((self.max_slots, self.max_pages), np.int32)
        self.n_tokens = np.zeros((self.max_slots,), np.int32)
        self.version = 0      # bumped on every table change (gather caching)

    # ------------------------------------------------------------------ #
    # allocator
    # ------------------------------------------------------------------ #

    @property
    def free_blocks(self) -> int:
        """Blocks currently available for allocation."""
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` token rows."""
        return -(-int(n_tokens) // self.block_size)

    def can_alloc(self, n_tokens: int) -> bool:
        """Whether ``n_tokens`` rows fit in the free list and one table."""
        need = self.blocks_for(n_tokens)
        return need <= self.free_blocks and need <= self.max_pages

    def alloc_slot(self, slot: int, n_tokens: int) -> None:
        """Reserve the blocks for a sequence of ``n_tokens`` total rows.

        Called once at admission with the request's whole lifetime
        (prompt + max_new - 1), so later writes can never run out.

        Raises:
            ValueError: the slot is already occupied or space is short
                (the scheduler must check :meth:`can_alloc` first).
        """
        if self.n_tokens[slot]:
            raise ValueError(f"slot {slot} already holds "
                             f"{self.n_tokens[slot]} tokens")
        if not self.can_alloc(n_tokens):
            raise ValueError(
                f"cannot allocate {n_tokens} tokens "
                f"({self.blocks_for(n_tokens)} blocks; "
                f"{self.free_blocks} free, {self.max_pages} pages/slot)")
        need = self.blocks_for(n_tokens)
        for p in range(need):
            self.tables[slot, p] = self._free.pop()
        self.n_tokens[slot] = int(n_tokens)
        self.version += 1

    def free_slot(self, slot: int) -> None:
        """Recycle a finished sequence's blocks and zero its table."""
        for p in range(self.blocks_for(int(self.n_tokens[slot]))):
            self._free.append(int(self.tables[slot, p]))
        self.tables[slot] = 0
        self.n_tokens[slot] = 0
        self.version += 1

    def reset(self) -> None:
        """Recycle every block and clear all tables (pool arrays kept —
        validity is positional, so stale contents are never attended)."""
        self._free = list(range(self.n_blocks - 1, 0, -1))
        self.tables[:] = 0
        self.n_tokens[:] = 0
        self.version += 1

    # ------------------------------------------------------------------ #
    # step-array helpers (host-built, fed to the jitted device steps)
    # ------------------------------------------------------------------ #

    def write_index(self, slot: int, pos: int) -> int:
        """Flat pool row where position ``pos`` of ``slot`` lives."""
        return (int(self.tables[slot, pos // self.block_size])
                * self.block_size + pos % self.block_size)

    def scratch_index(self, i: int) -> int:
        """A scratch-block row for idle/pad writes (block 0, wrapped)."""
        return int(i) % self.block_size

    def gather_row(self, slot: int) -> np.ndarray:
        """(max_pages * block_size,) pool rows in position order.

        Row ``j`` of the gathered KV holds position ``j``; unallocated
        table entries point at scratch and are masked by position.
        """
        bs = self.block_size
        return (self.tables[slot][:, None] * bs
                + np.arange(bs, dtype=np.int32)[None, :]).reshape(-1)

    # ------------------------------------------------------------------ #
    # datatype view + dense-extraction oracle
    # ------------------------------------------------------------------ #

    def seq_datatype(self, slot: int, n_tokens: int,
                     row_elems: int = 1) -> dt.Indexed:
        """The slot's block table as an ``indexed`` datatype over the pool.

        See ``core.datatypes.block_table`` — this is the per-sequence
        non-contiguous view the engine's gather indices are derived from.
        """
        pages = self.blocks_for(n_tokens)
        return dt.block_table(self.tables[slot, :pages], self.block_size,
                              n_tokens, row_elems=row_elems)

    def extract(self, slot: int, n_tokens: int) -> dict:
        """Dense per-sequence K/V, packed through the datatype view.

        Returns {"k": (L, n_tokens, KH, D), "v": ...} — bitwise what a
        dense linear cache would hold for this sequence, which is exactly
        what the paged-vs-dense oracle asserts against.
        """
        out = {}
        for name in ("k", "v"):
            arr = np.asarray(self.pool["main"][name])     # (L, P, KH, D)
            row = int(np.prod(arr.shape[2:]))
            view = self.seq_datatype(slot, n_tokens, row_elems=row)
            layers = [np.asarray(view.pack(arr[li])).reshape(
                (n_tokens,) + arr.shape[2:]) for li in range(arr.shape[0])]
            out[name] = np.stack(layers)
        return out
