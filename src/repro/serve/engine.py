"""Serving engines: padded fixed-batch (baseline) and continuous batching.

:class:`Engine` is the padded fixed-batch baseline: requests are padded
into one batch, prefilled once, then decoded in lockstep to the longest
request — finished sequences keep burning decode steps and the whole batch
restarts between rounds.  It is kept as the bench strawman and for the
fixed-shape dry-run cells.  Post-EOS positions are masked to ``eos_id``
and the output is always the documented ``(B, max_new_tokens)`` width.

:class:`ContinuousEngine` is the real serving engine (ROADMAP item 1):
a FIFO request queue with conservative admission control
(``serve.scheduler``), chunked prefill interleaved with decode steps, a
paged/block KV cache with per-sequence block tables expressed as
``indexed`` datatype views (``serve.paged_cache``), and slot recycling
the moment a sequence finishes — no re-padding, no full-batch restarts.
Every device step has a static shape (one compile for prefill, one for
decode, caches donated), so the steady state is allocation-free; idle
slots write to the scratch block and are masked, never re-traced.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import cache as cache_lib
from repro.models import lm as lm_lib
from repro.serve.paged_cache import PagedKVCache
from repro.serve.scheduler import DECODE, Request, Scheduler


@dataclasses.dataclass
class ServeConfig:
    """Serving knobs shared by both engines.

    The first three fields are the padded engine's whole surface; the rest
    size the continuous engine's paged cache and batching.
    """

    max_prompt: int = 64
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stops early
    # --- continuous engine ---
    block_size: int = 16        # token rows per KV block
    n_blocks: int = 64          # pool blocks incl. the scratch block 0
    max_slots: int = 8          # concurrent sequences (decode batch width)
    prefill_chunk: int = 16     # prompt tokens per prefill chunk row
    prefill_batch: int = 4      # prompts sharing one chunked-prefill
    #                             dispatch per engine step
    prefill_patience: int = 2   # decode-priority: steps a partial prefill
    #                             batch may wait to fill before dispatching
    max_seq: int | None = None  # per-sequence KV capacity (default
    #                             max_prompt + max_new_tokens)


class Engine:
    """Padded fixed-batch engine (the continuous engine's baseline)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self._prefill = jax.jit(
            lambda p, b: lm_lib.prefill(p, cfg, b,
                                        serve_cfg.max_prompt
                                        + serve_cfg.max_new_tokens))
        self._decode = jax.jit(
            lambda p, b, c, t: lm_lib.decode_step(p, cfg, b, c, t),
            donate_argnums=(2,))

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for the synthetic benches). Returns (B, max_new_tokens) int32;
        positions strictly after a sequence's first EOS are masked to
        ``eos_id``, and the early-exit path (every sequence finished) pads
        the result back to the full documented width."""
        b, s = prompts.shape
        width = self.sc.max_new_tokens
        eos = self.sc.eos_id
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        token = jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1)
        out = [np.asarray(token)[:, 0]]
        alive = np.ones((b,), bool) if eos < 0 else out[0] != eos
        for i in range(width - 1):
            if eos >= 0 and not alive.any():
                break
            t = s + i
            logits, caches = self._decode(self.params, {"tokens": token},
                                          caches, t)
            token = jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1)
            tok_np = np.asarray(token)[:, 0]
            out.append(tok_np)
            if eos >= 0:
                alive &= tok_np != eos
        res = np.stack(out, axis=1).astype(np.int32)
        if res.shape[1] < width:            # early exit: pad to contract
            pad = np.full((b, width - res.shape[1]), eos, np.int32)
            res = np.concatenate([res, pad], axis=1)
        if eos >= 0:                        # mask strictly-post-EOS output
            is_eos = res == eos
            first = np.where(is_eos.any(1), is_eos.argmax(1), width)
            res = np.where(np.arange(width)[None, :] > first[:, None],
                           eos, res)
        return res


class ContinuousEngine:
    """Continuous-batching engine over a paged KV cache.

    Lifecycle: :meth:`submit` requests (optionally with a future
    ``arrival`` step), then :meth:`run` — or drive :meth:`step` manually.
    Each step admits what fits, prefills one chunk each of up to
    ``prefill_batch`` admitted prompts (one batched dispatch), and decodes
    every in-flight sequence one token; sequences
    finish independently (EOS or their own ``max_new_tokens``) and their
    slot + blocks recycle immediately.  :meth:`generate` wraps the loop in
    the padded engine's ``(B, width)`` output contract so the two are
    drop-in comparable.
    """

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.max_seq = serve_cfg.max_seq or (serve_cfg.max_prompt
                                             + serve_cfg.max_new_tokens)
        max_pages = -(-self.max_seq // serve_cfg.block_size)
        self.cache = PagedKVCache(cfg, serve_cfg.n_blocks,
                                  serve_cfg.block_size,
                                  serve_cfg.max_slots, max_pages)
        self.s_max = max_pages * serve_cfg.block_size
        self.sched = Scheduler(serve_cfg.max_slots)

        # The hot steps transfer one tiny int array each; everything else
        # (write rows, validity mask, argmax) is derived *inside* the
        # compiled block.  Key identity: a slot's cached gather row maps
        # position -> flat pool row, so ``write = gather[pos]`` — the
        # block table never has to cross the host boundary per step.
        s_max, vocab = self.s_max, cfg.vocab_size
        bs = serve_cfg.block_size

        def _decode_fn(p, td, c, gather):
            # td (B, 2) int32: [input token, position t] per slot (-1 = no
            # active decode: write to scratch, mask everything).
            pos = td[:, 1]
            live = pos >= 0
            write = jnp.where(
                live,
                jnp.take_along_axis(
                    gather, jnp.maximum(pos, 0)[:, None], axis=1)[:, 0],
                jnp.arange(td.shape[0], dtype=jnp.int32) % bs)
            step = {"pos": pos, "write": write, "gather": gather,
                    "mask": cache_lib.paged_valid_mask(
                        pos, s_max, cfg.window)}
            logits, c = lm_lib.decode_step_paged(
                p, cfg, {"tokens": td[:, :1]}, c, step)
            return jnp.argmax(logits[:, 0, :vocab], axis=-1), c

        def _prefill_fn(p, tokens, c, cr, gather):
            # tokens (K, C) chunk rows; cr (K, 3) int32: [chunk start c0,
            # real rows, slot] per prefilling request (0, 0, 0 = unused
            # row — its pos is all -1 so whatever it gathers is masked).
            g = jnp.take(gather, cr[:, 2], axis=0)        # (K, s_max)
            j = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
            rows = cr[:, :1] + j                          # (K, C) positions
            real = j < cr[:, 1:2]
            pos = jnp.where(real, rows, -1)
            write = jnp.where(
                real,
                jnp.take_along_axis(
                    g, jnp.clip(rows, 0, s_max - 1), axis=1),
                j % bs)
            step = {"pos": pos, "write": write, "gather": g,
                    "mask": cache_lib.paged_valid_mask(
                        pos, s_max, cfg.window)}
            logits, c = lm_lib.prefill_chunk_paged(p, cfg,
                                                   {"tokens": tokens},
                                                   c, step)
            return jnp.argmax(logits[..., :vocab], axis=-1), c

        self._decode = jax.jit(_decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(_prefill_fn, donate_argnums=(2,))
        self._gather_dev = None         # (max_slots, s_max) device cache
        self._tables_version = -1
        self._prefill_wait = 0
        self._now = 0
        self._next_rid = 0
        self.results: dict[int, np.ndarray] = {}
        self.latency: dict[int, float] = {}
        self.stats = {"steps": 0, "prefill_chunks": 0, "decode_steps": 0,
                      "emitted": 0, "peak_active": 0}

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #

    def submit(self, prompt, max_new_tokens=None, arrival=None) -> int:
        """Queue one prompt (1-D int tokens); returns the request id.

        Raises:
            ValueError: the request can never be served (prompt longer
                than ``max_prompt``, lifetime KV beyond ``max_seq``, or
                more blocks than the whole pool) — admission control
                rejects at submit so the queue can always drain.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        mnt = (self.sc.max_new_tokens if max_new_tokens is None
               else int(max_new_tokens))
        if mnt < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {mnt}")
        if len(prompt) < 1 or len(prompt) > self.sc.max_prompt:
            raise ValueError(f"prompt length {len(prompt)} outside "
                             f"[1, {self.sc.max_prompt}]")
        total = len(prompt) + mnt - 1
        if total > self.max_seq:
            raise ValueError(f"lifetime {total} tokens exceeds "
                             f"max_seq {self.max_seq}")
        if self.cache.blocks_for(total) > self.cache.n_blocks - 1:
            raise ValueError(f"request needs {self.cache.blocks_for(total)} "
                             f"blocks; pool has {self.cache.n_blocks - 1}")
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(Request(rid, prompt, mnt,
                                  arrival=int(arrival or 0)))
        return rid

    # ------------------------------------------------------------------ #
    # the serving loop
    # ------------------------------------------------------------------ #

    def step(self) -> list[tuple[int, int]]:
        """One engine tick: admit → one batched prefill chunk → one decode
        batch.

        Returns the ``(rid, token)`` pairs emitted this step.
        """
        now = self._now
        self._now += 1
        self.stats["steps"] += 1
        wall = time.perf_counter()
        for req in self.sched.queue:
            if req.arrival <= now and not req.arrived_wall:
                req.arrived_wall = wall
        emitted: list[tuple[int, int]] = []

        def _reserve(slot: int, n_tokens: int) -> bool:
            # atomic check+reserve: same-step admissions debit the free
            # list immediately, so a later candidate can't pass a stale
            # can_alloc and then blow up in alloc_slot mid-flight
            if not self.cache.can_alloc(n_tokens):
                return False
            self.cache.alloc_slot(slot, n_tokens)
            return True

        for req in self.sched.admissible(now, _reserve):
            if not req.arrived_wall:
                req.arrived_wall = wall
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        len(self.sched.active))
        dec = self.sched.decoding()
        pres = self.sched.prefills(self.sc.prefill_batch)
        if pres and (len(pres) >= self.sc.prefill_batch or not dec
                     or self._prefill_wait >= self.sc.prefill_patience):
            self._prefill_batch(pres, emitted)
            self._prefill_wait = 0
            dec = self.sched.decoding()     # fresh finishers decode now
        elif pres:
            self._prefill_wait += 1         # decode-priority: let a
            #                                 partial batch accumulate
        if dec:
            self._decode_batch(dec, emitted)
        return emitted

    def run(self, max_steps: int = 100_000) -> dict[int, np.ndarray]:
        """Drive :meth:`step` until the queue and slots drain.

        Returns {rid: (n_generated,) int32} for everything completed.
        """
        while not self.sched.idle:
            if self.stats["steps"] >= max_steps:
                raise RuntimeError(f"serving loop exceeded {max_steps} steps")
            self.step()
        return dict(self.results)

    def generate(self, prompts: np.ndarray, max_new_tokens=None,
                 arrivals=None) -> np.ndarray:
        """Batch convenience with the padded engine's output contract.

        prompts: (B, S) int32.  Returns (B, width) int32 where width is
        ``max_new_tokens`` (default ``sc.max_new_tokens``); sequences ending
        on EOS are padded with ``eos_id`` (bitwise what the fixed engine
        returns after its post-EOS masking).
        """
        width = (self.sc.max_new_tokens if max_new_tokens is None
                 else int(max_new_tokens))
        rids = [self.submit(p, width,
                            arrival=None if arrivals is None else arrivals[i])
                for i, p in enumerate(np.asarray(prompts, np.int32))]
        self.run()
        pad = self.sc.eos_id if self.sc.eos_id >= 0 else 0
        out = np.full((len(rids), width), pad, np.int32)
        for i, rid in enumerate(rids):
            toks = self.results[rid]
            out[i, :len(toks)] = toks
        return out

    def reset(self) -> None:
        """Drop all requests/results and recycle every block.

        Keeps the pool arrays and compiled steps — pool contents need no
        zeroing because validity is positional and tables start empty.
        """
        self.cache.reset()
        self.sched = Scheduler(self.sc.max_slots)
        self._tables_version = -1
        self._prefill_wait = 0
        self._now = 0
        self._next_rid = 0
        self.results = {}
        self.latency = {}
        self.stats = {k: 0 for k in self.stats}

    # ------------------------------------------------------------------ #
    # device steps
    # ------------------------------------------------------------------ #

    def _gather(self):
        """Device-resident (max_slots, s_max) gather matrix.

        Rebuilt (one host→device transfer) only when a block table changed
        since the last step — in steady-state decode it is reused as-is.
        """
        if self._tables_version != self.cache.version:
            bs = self.cache.block_size
            rows = (self.cache.tables[:, :, None] * bs
                    + np.arange(bs, dtype=np.int32)).reshape(
                        self.sc.max_slots, -1)
            self._gather_dev = jnp.asarray(rows)
            self._tables_version = self.cache.version
        return self._gather_dev

    def _prefill_batch(self, reqs: list[Request], emitted: list) -> None:
        cache, sc = self.cache, self.sc
        K, C = sc.prefill_batch, sc.prefill_chunk
        tokens = np.zeros((K, C), np.int32)
        cr = np.zeros((K, 3), np.int32)
        reals = []
        for i, req in enumerate(reqs):
            c0 = req.cursor
            real = min(C, req.prompt_len - c0)
            reals.append(real)
            tokens[i, :real] = req.prompt[c0:c0 + real]
            cr[i] = (c0, real, req.slot)
        toks, cache.pool = self._prefill(
            self.params, jnp.asarray(tokens), cache.pool,
            jnp.asarray(cr), self._gather())
        self.stats["prefill_chunks"] += len(reqs)
        toks_np = None
        for i, req in enumerate(reqs):
            req.cursor += reals[i]
            if req.cursor == req.prompt_len:
                if toks_np is None:
                    toks_np = np.asarray(toks)
                req.state = DECODE
                self._emit(req, int(toks_np[i, reals[i] - 1]), emitted)

    def _decode_batch(self, reqs: list[Request], emitted: list) -> None:
        cache, B = self.cache, self.sc.max_slots
        td = np.full((B, 2), -1, np.int32)
        td[:, 0] = 0
        for req in reqs:
            td[req.slot] = (req.tokens[-1],
                            req.prompt_len + len(req.tokens) - 1)
        toks, cache.pool = self._decode(
            self.params, jnp.asarray(td), cache.pool, self._gather())
        self.stats["decode_steps"] += 1
        toks = np.asarray(toks)
        for req in reqs:
            self._emit(req, int(toks[req.slot]), emitted)

    def _emit(self, req: Request, tok: int, emitted: list) -> None:
        req.tokens.append(tok)
        emitted.append((req.rid, tok))
        self.stats["emitted"] += 1
        eos = self.sc.eos_id
        if ((eos >= 0 and tok == eos)
                or len(req.tokens) >= req.max_new_tokens):
            req.finished_wall = time.perf_counter()
            self.latency[req.rid] = req.finished_wall - req.arrived_wall
            self.results[req.rid] = np.asarray(req.tokens, np.int32)
            self.cache.free_slot(req.slot)
            self.sched.release(req)
