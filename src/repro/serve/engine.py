"""Batched serving engine: prefill + decode loop over the step builders.

Continuous-batching-lite: requests are padded into a fixed batch, prefilled
once, then decoded step-by-step with greedy sampling; finished sequences
(EOS or max_tokens) are masked out.  The decode step donates its caches so
the loop is allocation-free after warmup.  The same ``build_decode_step``
is what the dry-run lowers for the decode_32k / long_500k cells.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm as lm_lib


@dataclasses.dataclass
class ServeConfig:
    max_prompt: int = 64
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stops early


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self._prefill = jax.jit(
            lambda p, b: lm_lib.prefill(p, cfg, b,
                                        serve_cfg.max_prompt
                                        + serve_cfg.max_new_tokens))
        self._decode = jax.jit(
            lambda p, b, c, t: lm_lib.decode_step(p, cfg, b, c, t),
            donate_argnums=(2,))

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for the synthetic benches). Returns (B, max_new_tokens) int32."""
        b, s = prompts.shape
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        token = jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1)
        out = [np.asarray(token)[:, 0]]
        alive = np.ones((b,), bool)
        for i in range(self.sc.max_new_tokens - 1):
            t = s + i
            logits, caches = self._decode(self.params, {"tokens": token},
                                          caches, t)
            token = jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1)
            tok_np = np.asarray(token)[:, 0]
            if self.sc.eos_id >= 0:
                alive &= tok_np != self.sc.eos_id
                if not alive.any():
                    out.append(tok_np)
                    break
            out.append(tok_np)
        return np.stack(out, axis=1)
