"""Request queue + admission control for the continuous-batching engine.

FIFO with head-of-line admission: a request is admitted the first step at
or after its ``arrival`` when (a) a sequence slot is free and (b) the paged
cache can reserve its whole lifetime's blocks up front.  Head-of-line
blocking is deliberate — skipping ahead would starve long requests under
pressure; the queue drains in submission order.

The scheduler owns request *state* transitions (queued → prefill → decode
→ done) and slot assignment; the engine owns the clock, the device steps,
and when to call :meth:`Scheduler.admissible`.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


@dataclasses.dataclass
class Request:
    """One generation request and its serving state.

    ``prompt`` is a 1-D int32 token array; ``arrival`` is the engine-step
    clock tick at which the request becomes visible to admission (0 =
    immediately).  The remaining fields are engine-owned bookkeeping.
    """

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: int = 0
    state: str = QUEUED
    slot: int = -1
    cursor: int = 0                     # prompt tokens already prefilled
    tokens: list = dataclasses.field(default_factory=list)
    arrived_wall: float = 0.0
    finished_wall: float = 0.0

    @property
    def prompt_len(self) -> int:
        """Prompt length in tokens."""
        return int(len(self.prompt))

    @property
    def total_kv_tokens(self) -> int:
        """KV rows written over the request's lifetime.

        Prompt positions 0..S-1 plus one row per decode *input* token —
        the last generated token is emitted but never written back.
        """
        return self.prompt_len + max(self.max_new_tokens - 1, 0)


class Scheduler:
    """FIFO queue + slot assignment over ``max_slots`` sequence slots."""

    def __init__(self, max_slots: int):
        """Create an empty scheduler with ``max_slots`` sequence slots."""
        self.max_slots = int(max_slots)
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self._free_slots = list(range(self.max_slots - 1, -1, -1))

    @property
    def idle(self) -> bool:
        """True when nothing is queued or in flight."""
        return not self.queue and not self.active

    @property
    def free_slots(self) -> int:
        """Currently unoccupied sequence slots."""
        return len(self._free_slots)

    def submit(self, req: Request) -> None:
        """Append a request to the FIFO queue."""
        self.queue.append(req)

    def admissible(self, now: int, try_reserve) -> list[Request]:
        """Admit head-of-line requests that have arrived and fit.

        Args:
            now: the engine-step clock.
            try_reserve: callable ``(slot, n_tokens) -> bool`` that must
                atomically check *and* reserve the whole request lifetime's
                blocks (the engine passes the paged cache's reservation).
                Reserving inside the loop — rather than checking first and
                allocating after — is what keeps multiple same-step
                admissions from racing a stale free count.
        Returns:
            Admitted requests (state set to ``prefill``, slot assigned,
            blocks reserved); stops at the first request that has not
            arrived or does not fit (FIFO — no skipping ahead).
        """
        admitted = []
        while self.queue and self._free_slots:
            req = self.queue[0]
            if req.arrival > now:
                break
            slot = self._free_slots[-1]
            if not try_reserve(slot, req.total_kv_tokens):
                break
            self.queue.popleft()
            req.slot = self._free_slots.pop()
            req.state = PREFILL
            self.active[req.slot] = req
            admitted.append(req)
        return admitted

    def release(self, req: Request) -> None:
        """Return a finished request's slot to the free pool."""
        req.state = DONE
        del self.active[req.slot]
        self._free_slots.append(req.slot)

    def next_prefill(self) -> Request | None:
        """Oldest admitted request still consuming its prompt, if any."""
        pres = self.prefills(1)
        return pres[0] if pres else None

    def prefills(self, limit: int) -> list[Request]:
        """Up to ``limit`` oldest admitted requests still in prefill.

        These share one batched chunked-prefill dispatch (rid order, so a
        long prompt keeps its chunks in submission order across steps).
        """
        cands = sorted((r for r in self.active.values()
                        if r.state == PREFILL), key=lambda r: r.rid)
        return cands[:int(limit)]

    def decoding(self) -> list[Request]:
        """Active requests in the decode phase, slot-ordered."""
        return sorted((r for r in self.active.values() if r.state == DECODE),
                      key=lambda r: r.slot)
