"""Domain-decomposition halo exchange on top of jmpi (paper §3 substrate).

py-pde and PyMPDATA-MPI both reduce their distributed needs to one
primitive: exchange boundary strips with grid neighbours, then run the local
stencil.  ``halo_exchange_2d`` implements exactly that — and, since the
topology subsystem landed, it no longer computes neighbour ranks at all: the
solver attaches a Cartesian topology once (``world.cart_create((rows,
cols), periods=(True, True))``) and each decomposed axis is one MPI-3
``neighbor_alltoall`` on the ``cart_sub`` sub-grid — the send-up/send-down
strip pair is exactly the collective's slot layout, so what used to be two
hand-rolled ``sendrecv`` ring permutations per axis is one first-class
registry collective (``xla_native`` shifts or the p2p-fused ``ring``
lowering, policy's choice).

The decomposition layout is the Cartesian grid: decompose along axis 0,
axis 1, or both, by building the mesh with the matching axis sizes
(paper Fig. 3's layout study = benchmarks/bench_mpdata.py); degenerate
(size-1) dims wrap locally, matching the periodic self-neighbour.

Persistent plans: a PDE time loop re-exchanges the SAME strip signature
every step, so the exchange rides ``cart.neighbor_alltoall_init`` plans —
topology and algorithm are validated and frozen once per (shape, dtype,
comm) and the process-global plan cache serves every later step/trace
(MPI_Neighbor_alltoall_init semantics; see ``repro.core.plans``).

Halo slabs are **subarray datatypes** (``repro.core.datatypes.face``): the
boundary faces are described declaratively per (axis, side, width) — the
MPI ``MPI_Type_create_subarray`` idiom — and the datatype's ``pack``
materializes each strip at the transfer boundary; no manual slicing at
the call sites.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core as jmpi
from repro.core import datatypes


def _exchange_axis(sub: "jmpi.CartComm | None", field, axis: int,
                   halo: int, algorithm=None):
    """One decomposed axis as a persistent neighbor_alltoall over the
    axis' two face datatypes.

    Args:
        sub: 1-D periodic CartComm along the axis (None = axis not
            decomposed → periodic local wrap).
        field: the local block (halo strips are its boundary faces).
        axis: the decomposed array axis (0 = rows, 1 = cols).
        halo: face width.
        algorithm: registry entry to freeze into the plan (None = policy).
    Returns:
        ``(from_minus, from_plus)`` — the halo strips received from the
        −1 / +1 neighbours.
    """
    lo = datatypes.face(field.shape, axis, "lo", halo, dtype=field.dtype)
    hi = datatypes.face(field.shape, axis, "hi", halo, dtype=field.dtype)
    if sub is None:
        return hi.pack(field), lo.pack(field)  # periodic self-wrap
    send = jnp.stack([lo.pack(field), hi.pack(field)])
    plan = sub.neighbor_alltoall_init(
        jax.ShapeDtypeStruct(send.shape, send.dtype), algorithm=algorithm)
    _, recv = jmpi.wait(plan.start(send))
    return recv[0], recv[1]


def halo_exchange_2d(field, cart: "jmpi.CartComm", halo: int = 1, *,
                     algorithm=None):
    """Pad ``field`` (local block) with periodic neighbour strips.

    Args:
        field: the local ``(n, m)`` block.
        cart: 2-D periodic :class:`~repro.core.topology.CartComm` from
            ``world.cart_create((rows, cols), periods=(True, True))`` —
            dim 0 decomposes rows, dim 1 columns; size-1 dims wrap locally.
        halo: strip width.
        algorithm: neighbor-collective registry entry to freeze into the
            exchange plans (None = the active policy's choice).
    Returns:
        The ``(n + 2·halo, m + 2·halo)`` padded block; the column phase
        includes the fresh halo rows so corners resolve.
    Raises:
        ValueError: ``cart`` is not 2-dimensional.
    """
    if cart.ndims != 2:
        raise ValueError(f"halo_exchange_2d needs a 2-D CartComm, got "
                         f"{cart.ndims}-D dims={cart.dims}")
    h = halo
    sub_r = cart.cart_sub((True, False)) if cart.dims[0] > 1 else None
    sub_c = cart.cart_sub((False, True)) if cart.dims[1] > 1 else None

    # --- axis 0 (rows): 'lo' face to the -1 neighbour, 'hi' to the +1 ----
    top_halo, bot_halo = _exchange_axis(sub_r, field, 0, h, algorithm)
    field = jnp.concatenate([top_halo, field, bot_halo], axis=0)

    # --- axis 1 (cols): faces of the row-padded block, so corners resolve -
    left_halo, right_halo = _exchange_axis(sub_c, field, 1, h, algorithm)
    return jnp.concatenate([left_halo, field, right_halo], axis=1)


def global_sum(field, *comms: "jmpi.Communicator | None"):
    """Global Σfield across the decomposition — the PDE diagnostics reduce
    (mass conservation, residual norms).

    The local partial sum is a scalar, so the collective-algorithm policy
    routes this through its latency-optimal small-payload entry
    (recursive_doubling under the built-in table) rather than the
    bandwidth schedule the field itself would get — the per-payload
    selection the registry exists for.

    Args:
        field: the local block to sum.
        comms: one communicator per decomposed axis (None entries skipped;
            no live comm → local sum only).
    Returns:
        The scalar global sum (same value on every rank).

    Uses an explicit fresh token (control-flow safe): diagnostics typically
    run right after a ``fori_loop``/``scan`` time loop, and the ambient
    token set inside that loop's trace must not be consumed outside it.
    """
    total = jnp.sum(field)
    for comm in comms:
        if comm is not None and comm.size() > 1:
            plan = comm.allreduce_init(
                jax.ShapeDtypeStruct(total.shape, total.dtype))
            _, total = jmpi.wait(plan.start(total, token=jmpi.new_token()))
    return total


def laplacian(c_halo, dx: float = 1.0, halo: int = 1):
    """5-point Laplacian of the interior of a halo-padded block.

    Args:
        c_halo: halo-padded ``(n + 2·halo, m + 2·halo)`` block.
        dx: grid spacing.
        halo: pad width of the input.
    Returns:
        The ``(n, m)`` interior Laplacian.
    """
    h = halo
    n = c_halo.shape[0] - 2 * h
    m = c_halo.shape[1] - 2 * h
    c = c_halo[h:h + n, h:h + m]
    up = c_halo[h - 1:h - 1 + n, h:h + m]
    dn = c_halo[h + 1:h + 1 + n, h:h + m]
    lf = c_halo[h:h + n, h - 1:h - 1 + m]
    rt = c_halo[h:h + n, h + 1:h + 1 + m]
    return (up + dn + lf + rt - 4.0 * c) / (dx * dx)
