"""Domain-decomposition halo exchange on top of jmpi (paper §3 substrate).

py-pde and PyMPDATA-MPI both reduce their distributed needs to one
primitive: exchange boundary strips with grid neighbours, then run the local
stencil.  ``halo_exchange_2d`` implements exactly that with jmpi
``sendrecv`` ring permutations over the mesh axes — JIT-resident, so the
whole PDE step (stencil + communication) is one compiled block, which is the
paper's point.

The decomposition layout is the communicator layout: decompose along axis 0,
axis 1, or both, by building the mesh with the matching axis sizes
(paper Fig. 3's layout study = benchmarks/bench_mpdata.py).

Persistent plans: a PDE time loop re-exchanges the SAME strip signature
every step, so the exchange rides ``comm.sendrecv_init`` plans — the
(src → dst) pattern is validated and frozen once per (shape, dtype, comm)
and the process-global plan cache serves every later step/trace
(MPI_Send_init semantics; see ``repro.core.plans``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core as jmpi


def _planned_exchange(comm: jmpi.Communicator, strip, pairs):
    """One persistent-plan hop: strip moves along the frozen pattern."""
    plan = comm.sendrecv_init(jax.ShapeDtypeStruct(strip.shape, strip.dtype),
                              pairs=pairs)
    _, out = jmpi.wait(plan.start(strip))
    return out


def halo_exchange_2d(field, comm_rows: jmpi.Communicator | None,
                     comm_cols: jmpi.Communicator | None, halo: int = 1):
    """Pad ``field`` (local block) with periodic neighbour strips.

    comm_rows: communicator along the row-decomposed axis (axis 0) — ranks
    above/below; comm_cols: along axis 1 — ranks left/right.  Either may be
    None (axis not decomposed → wrap locally).
    Returns (n + 2·halo, m + 2·halo).
    """
    h = halo

    # --- axis 0 (rows): send bottom strip down / top strip up -------------
    if comm_rows is not None and comm_rows.size() > 1:
        down = comm_rows.ring_perm(+1)
        up = comm_rows.ring_perm(-1)
        top_halo = _planned_exchange(comm_rows, field[-h:, :], down)  # from above
        bot_halo = _planned_exchange(comm_rows, field[:h, :], up)     # from below
    else:
        top_halo = field[-h:, :]
        bot_halo = field[:h, :]
    field = jnp.concatenate([top_halo, field, bot_halo], axis=0)

    # --- axis 1 (cols): include the fresh halo rows so corners resolve ----
    if comm_cols is not None and comm_cols.size() > 1:
        right = comm_cols.ring_perm(+1)
        left = comm_cols.ring_perm(-1)
        left_halo = _planned_exchange(comm_cols, field[:, -h:], right)
        right_halo = _planned_exchange(comm_cols, field[:, :h], left)
    else:
        left_halo = field[:, -h:]
        right_halo = field[:, :h]
    return jnp.concatenate([left_halo, field, right_halo], axis=1)


def global_sum(field, *comms: "jmpi.Communicator | None"):
    """Global Σfield across the decomposition — the PDE diagnostics reduce
    (mass conservation, residual norms).

    The local partial sum is a scalar, so the collective-algorithm policy
    routes this through its latency-optimal small-payload entry
    (recursive_doubling under the built-in table) rather than the
    bandwidth schedule the field itself would get — the per-payload
    selection the registry exists for.  ``comms``: one communicator per
    decomposed axis (None entries skipped; no live comm → local sum).

    Uses an explicit fresh token (control-flow safe): diagnostics typically
    run right after a ``fori_loop``/``scan`` time loop, and the ambient
    token set inside that loop's trace must not be consumed outside it.
    """
    total = jnp.sum(field)
    for comm in comms:
        if comm is not None and comm.size() > 1:
            plan = comm.allreduce_init(
                jax.ShapeDtypeStruct(total.shape, total.dtype))
            _, total = jmpi.wait(plan.start(total, token=jmpi.new_token()))
    return total


def laplacian(c_halo, dx: float = 1.0, halo: int = 1):
    """5-point Laplacian of the interior of a halo-padded block."""
    h = halo
    n = c_halo.shape[0] - 2 * h
    m = c_halo.shape[1] - 2 * h
    c = c_halo[h:h + n, h:h + m]
    up = c_halo[h - 1:h - 1 + n, h:h + m]
    dn = c_halo[h + 1:h + 1 + n, h:h + m]
    lf = c_halo[h:h + n, h - 1:h - 1 + m]
    rt = c_halo[h:h + n, h + 1:h + 1 + m]
    return (up + dn + lf + rt - 4.0 * c) / (dx * dx)
