"""Cahn–Hilliard with chemical reactions — the py-pde example (paper §3.1).

    ∂t c = ∇²(c³ − c − ∇²c) − k·(c − c₀)          (paper Eq. 1)

Domain decomposition follows py-pde's scheme: each rank owns a sub-grid,
virtual boundary points come from neighbours via halo exchange, and the
whole time loop runs inside ONE jit/shard_map program (communication
included) — numba-mpi's raison d'être.  Two halo exchanges per step (one
before each Laplacian).  ``benchmarks/bench_halo.py`` reproduces the paper's
Fig. 2 strong-scaling measurement with this solver.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.pde.stencil import global_sum, halo_exchange_2d, laplacian


def _step(c, *, dt, dx, k, c0, cart):
    ch = halo_exchange_2d(c, cart, halo=1)
    lap_c = laplacian(ch, dx)
    mu = c * c * c - c - lap_c
    muh = halo_exchange_2d(mu, cart, halo=1)
    dc = laplacian(muh, dx) - k * (c - c0)
    return c + dt * dc


def make_solver(mesh, decomposition=(1, -1), *, dt=1e-3, dx=1.0, k=0.01,
                c0=0.5, inner_steps=100, diagnostics: bool = False):
    """Build a jit-compiled multi-rank solver over ``mesh``.

    decomposition: (rows, cols) rank-grid; -1 = "rest of the ranks" (the
    py-pde convention from paper Listing 7's ``decomposition=[2, -1]``).
    Returns run(c_global, n_outer) -> c_global after n_outer·inner_steps.

    ``diagnostics=True``: run() additionally returns the global Σc after the
    block — a scalar jmpi allreduce inside the same compiled program, routed
    by the collective-algorithm policy to its small-payload entry while the
    halo strips stay on their ppermute path (per-payload selection).
    """
    n_dev = int(np.prod(mesh.devices.shape))
    rows, cols = decomposition
    if rows == -1:
        rows = n_dev // cols
    if cols == -1:
        cols = n_dev // rows
    assert rows * cols == n_dev, (rows, cols, n_dev)
    axes = mesh.axis_names
    assert mesh.devices.shape == (rows, cols) or len(axes) == 2, \
        "mesh must be 2-D (rows, cols)"

    out_specs = (P(axes[0], axes[1]), P()) if diagnostics \
        else P(axes[0], axes[1])

    @jmpi.spmd(mesh, in_specs=P(axes[0], axes[1]), out_specs=out_specs)
    def run_block(c_local):
        world = jmpi.world()
        cart = world.cart_create((rows, cols), periods=(True, True))
        step = functools.partial(_step, dt=dt, dx=dx, k=k, c0=c0, cart=cart)
        c = jax.lax.fori_loop(0, inner_steps, lambda i, c: step(c), c_local)
        if diagnostics:
            return c, global_sum(c, world)
        return c

    def run(c_global, n_outer=1):
        mass = None
        for _ in range(n_outer):
            out = run_block(c_global)
            c_global, mass = out if diagnostics else (out, None)
        return (c_global, mass) if diagnostics else c_global

    return run


def reference_step(c, dt=1e-3, dx=1.0, k=0.01, c0=0.5):
    """Single-device oracle (periodic roll stencil) for correctness tests."""
    def lap(a):
        return (jnp.roll(a, 1, 0) + jnp.roll(a, -1, 0) + jnp.roll(a, 1, 1)
                + jnp.roll(a, -1, 1) - 4 * a) / (dx * dx)
    mu = c ** 3 - c - lap(c)
    return c + dt * (lap(mu) - k * (c - c0))
