"""MPDATA 2-D advection — the PyMPDATA-MPI example (paper §3.2).

Solves ∂t ψ + ∇·(u ψ) = 0 (homogeneous advection, G=1, μ=0 — the
"hello-world" setup of the paper's Fig. 3) with the two-pass MPDATA scheme:
a donor-cell upwind pass followed by ``n_iters−1`` antidiffusive corrective
passes (Smolarkiewicz velocities) on proper face-centred Courant fields.
Periodic boundaries via jmpi halo exchange; the full time loop (all passes +
communication) is one JIT-compiled block.

The decomposition axis is a *user choice* exactly as PyMPDATA-MPI exposes it
(paper Fig. 3 compares layouts): build the mesh (r, c) and the solver
decomposes rows over the first axis and columns over the second.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.pde.stencil import halo_exchange_2d


def _flux(psi_l, psi_r, c):
    """Donor-cell flux through a face with Courant number c."""
    return jnp.maximum(c, 0.0) * psi_l + jnp.minimum(c, 0.0) * psi_r


def _advect(psi_h, cx_f, cy_f):
    """One upwind pass on a halo-1 padded block.

    cx_f: (n, m+1) Courant at x-faces (col j−1/2 .. m−1/2);
    cy_f: (n+1, m) Courant at y-faces.  Returns the interior update (n, m).
    """
    n, m = psi_h.shape[0] - 2, psi_h.shape[1] - 2
    c = psi_h[1:1 + n, 1:1 + m]
    up = psi_h[0:n, 1:1 + m]
    dn = psi_h[2:2 + n, 1:1 + m]
    lf = psi_h[1:1 + n, 0:m]
    rt = psi_h[1:1 + n, 2:2 + m]
    fx_r = _flux(c, rt, cx_f[:, 1:])
    fx_l = _flux(lf, c, cx_f[:, :-1])
    fy_d = _flux(c, dn, cy_f[1:, :])
    fy_u = _flux(up, c, cy_f[:-1, :])
    return c - (fx_r - fx_l) - (fy_d - fy_u)


def _antidiff(psi_h, cx, cy, eps=1e-10):
    """Smolarkiewicz antidiffusive face Courant fields from a halo-1 padded
    (positive-definite) field, for constant first-pass Courants (cx, cy)."""
    n, m = psi_h.shape[0] - 2, psi_h.shape[1] - 2
    row = psi_h[1:1 + n, :]                      # (n, m+2)
    ax = (row[:, 1:] - row[:, :-1]) / (row[:, 1:] + row[:, :-1] + eps)
    col = psi_h[:, 1:1 + m]                      # (n+2, m)
    ay = (col[1:, :] - col[:-1, :]) / (col[1:, :] + col[:-1, :] + eps)
    cx2 = (jnp.abs(cx) - cx * cx) * ax           # (n, m+1)
    cy2 = (jnp.abs(cy) - cy * cy) * ay           # (n+1, m)
    return cx2, cy2


def _mpdata_step(psi, cx, cy, n_iters, exchange):
    ph = exchange(psi)
    n, m = psi.shape
    cx_f = jnp.full((n, m + 1), cx)
    cy_f = jnp.full((n + 1, m), cy)
    out = _advect(ph, cx_f, cy_f)
    for _ in range(n_iters - 1):
        oh = exchange(out)
        cx2, cy2 = _antidiff(oh, cx, cy)
        out = _advect(oh, cx2, cy2)
    return out


def make_solver(mesh, *, courant=(0.2, 0.2), n_iters=2, inner_steps=50):
    """Multi-rank MPDATA solver: run(psi_global, n_outer) -> psi_global."""
    axes = mesh.axis_names
    rows, cols = mesh.devices.shape

    @jmpi.spmd(mesh, in_specs=P(axes[0], axes[1]),
               out_specs=P(axes[0], axes[1]))
    def run_block(psi):
        world = jmpi.world()
        cart = world.cart_create((rows, cols), periods=(True, True))
        exchange = lambda f: halo_exchange_2d(f, cart, halo=1)
        cx, cy = courant
        return jax.lax.fori_loop(
            0, inner_steps,
            lambda i, p: _mpdata_step(p, cx, cy, n_iters, exchange), psi)

    def run(psi_global, n_outer=1):
        for _ in range(n_outer):
            psi_global = run_block(psi_global)
        return psi_global

    return run


def reference_step(psi, courant=(0.2, 0.2), n_iters=2):
    """Single-device periodic oracle (jnp.roll halos)."""
    def pad(a):
        a = jnp.concatenate([a[-1:], a, a[:1]], axis=0)
        return jnp.concatenate([a[:, -1:], a, a[:, :1]], axis=1)
    def exchange(f):
        return pad(f)
    cx, cy = courant
    return _mpdata_step(psi, cx, cy, n_iters, exchange)
