"""Persistent communication plans — MPI-4 ``MPI_<Collective>_init`` (and the
MPI-1 persistent p2p ``MPI_Send_init`` family) for jmpi.

A :class:`Plan` is created once per (collective, payload signature,
communicator): ``comm.allreduce_init(shape_dtype) -> Plan`` resolves the
registry's trace-time algorithm choice ONCE and freezes it; every
``plan.start(x) -> Request`` then re-issues the frozen lowering with zero
registry/policy work — the hot-path dispatch cost of a collective inside a
step loop drops to a token tie plus the kernel itself.  Completion flows
through the same unified Request model as p2p and the i* collectives
(``wait``/``waitall``/``test*``).

Plans are cached process-globally, keyed on
``(collective, algorithm, shape, dtype, comm, group size, static kwargs)``:
a re-trace of the same program (new jit call, new shard_map trace)
re-requests the same key and gets the SAME Plan object back.  A second
fast-path key adds the registry's *selection epoch* (bumped on every
``set_policy``/``set_algorithm``/override change), so a repeat ``*_init``
under unchanged selection state skips ``registry.select`` entirely — no
policy-table scan, no supports predicates; the cache-hit counter is how
``benchmarks/bench_collectives.py --persistent`` shows plan reuse.  Plans
hold only static metadata (algorithm, shapes, python ints), never tracers,
so sharing across traces is safe.

Typical hot-loop use (inside a ``jmpi.spmd`` trace)::

    plan = comm.allreduce_init(jax.ShapeDtypeStruct(g.shape, g.dtype))
    for _ in range(steps):                  # unrolled or per-trace step
        status, g = jmpi.wait(plan.start(g))
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datatypes as datatypes_lib
from repro.core import registry
from repro.core import token as token_lib
from repro.core.comm import Communicator, resolve
from repro.core.operators import Operator
from repro.core.p2p import Request
from repro.core.token import ERR_TRUNCATE, SUCCESS

__all__ = [
    "Plan", "collective_init", "allreduce_init", "bcast_init", "scatter_init",
    "gather_init", "allgather_init", "alltoall_init", "reduce_scatter_init",
    "scatterv_init", "gatherv_init", "allgatherv_init", "alltoallv_init",
    "barrier_init", "sendrecv_init", "neighbor_allgather_init",
    "neighbor_alltoall_init", "neighbor_alltoallv_init",
    "plan_cache_stats", "plan_cache_clear",
]


def _as_struct(shape_dtype) -> jax.ShapeDtypeStruct:
    """Accept a ShapeDtypeStruct, a concrete array, or a (shape, dtype) pair."""
    if isinstance(shape_dtype, jax.ShapeDtypeStruct):
        return shape_dtype
    if isinstance(shape_dtype, tuple) and len(shape_dtype) == 2 \
            and not hasattr(shape_dtype, "dtype"):
        return jax.ShapeDtypeStruct(tuple(shape_dtype[0]),
                                    jnp.dtype(shape_dtype[1]))
    return jax.ShapeDtypeStruct(tuple(shape_dtype.shape),
                                jnp.dtype(shape_dtype.dtype))


_pack = datatypes_lib.pack_payload


@dataclasses.dataclass(frozen=True)
class Plan:
    """A frozen, re-startable communication operation (MPI persistent request
    analogue).  ``start(x)`` issues one instance and returns a Request;
    ``issue_fn(val, tok) -> (out, tok)`` is the bound lowering (algorithm +
    communicator + static kwargs resolved at init time).

    Payload handling rides the derived-datatype layer
    (:mod:`repro.core.datatypes`) — the same pipeline as the blocking and
    nonblocking paths: ``datatype`` is the frozen send-side layout
    (``datatype.pack(x)`` materializes the wire message; None = the default
    ``pack_payload``), ``recv`` is the completion adapter riding the
    Request (``scatter_into`` protocol — slot splitting, view scatter),
    and ``status`` is the statically-known request status (ERR_TRUNCATE
    for a sendrecv plan whose receive layout is smaller than the message).
    """

    collective: str                      # "allreduce" … "sendrecv" | "barrier"
    algorithm: str                       # frozen registry entry ("ppermute" for p2p)
    shape: tuple                         # payload signature the plan accepts
    dtype: Any
    comm: Communicator
    issue_fn: Callable[..., Any] = dataclasses.field(compare=False, repr=False)
    datatype: Optional[datatypes_lib.Datatype] = dataclasses.field(
        default=None, compare=False, repr=False)
    recv: Any = dataclasses.field(default=None, compare=False, repr=False)
    status: int = SUCCESS
    #: The issue closure runs synchronously on the host (persistent-channel
    #: lowering): the transfer is complete when it returns, so start/wait
    #: skip the token tie/advance jnp ops — there is nothing for XLA to
    #: order, and those per-call dispatches would dominate the µs-scale
    #: channel itself.
    host: bool = dataclasses.field(default=False, compare=False)

    def start(self, x=None, *, token=None, tag: int = 0) -> Request:
        """Issue one instance of the planned op (MPI_Start analogue).

        Args:
            x: the payload — array/View matching the frozen signature (slot
                list for vector plans; omitted for barrier plans).
            token: explicit ordering token; None uses the ambient chain.
            tag: tag recorded on the returned Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        Raises:
            ValueError: payload shape/dtype does not match the frozen
                signature (build a new plan with ``*_init``).
        """
        tok = token if token is not None else token_lib.ambient().get()
        explicit = token is not None
        if self.collective == "barrier":
            val = None
        else:
            if self.host and self.datatype is None \
                    and not (hasattr(x, "pack") and callable(x.pack)):
                val = np.asarray(x)  # host path: forces the jnp value, no jnp
            else:
                val = _pack(x, self.datatype)
            if tuple(val.shape) != self.shape or \
                    jnp.dtype(val.dtype) != jnp.dtype(self.dtype):
                raise ValueError(
                    f"plan {self.collective}/{self.algorithm} is frozen for "
                    f"shape={self.shape} dtype={jnp.dtype(self.dtype).name}; "
                    f"got shape={tuple(val.shape)} "
                    f"dtype={jnp.dtype(val.dtype).name} — build a new plan "
                    f"with *_init for the new signature")
            if not self.host:
                tok, val = token_lib.tie(tok, val)
        out, tok = self.issue_fn(val, tok)
        new_tok = tok if self.host else token_lib.advance(tok, out)
        if not explicit:
            token_lib.ambient().set(new_tok)
        return Request(value=out, token=new_tok, tag=tag, recv=self.recv,
                       used_ambient=not explicit, status=self.status,
                       host=self.host)

    def describe(self) -> str:
        """One-line human-readable summary (collective, algorithm, frozen
        signature, axes).

        Returns:
            The description string.
        """
        return (f"Plan({self.collective}, algorithm={self.algorithm}, "
                f"shape={self.shape}, dtype={jnp.dtype(self.dtype).name}, "
                f"axes={self.comm.axes})")


# ---------------------------------------------------------------------------
# Process-global plan cache: *_init with an already-seen signature returns
# the SAME Plan (no re-selection, no rebuild) — observable via the stats.
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict = {}
_STATS = {"hits": 0, "misses": 0}
_BACKEND_STATS: dict = {}


def _backend_key(comm) -> tuple:
    """The backend/transport identity folded into every plan cache key.

    A plan frozen under the emulated backend must never be served to a
    multiproc communicator (its issue closure captures the comm's wire), so
    the cache key carries ``(backend, transport_kind)`` — the latter
    distinguishes shm from socket multiproc comms that otherwise compare
    equal.
    """
    return (getattr(comm, "backend", "emulated"),
            getattr(comm, "transport_kind", None))


def _count(backend: str, outcome: str) -> None:
    _STATS[outcome] += 1
    per = _BACKEND_STATS.setdefault(backend, {"hits": 0, "misses": 0})
    per[outcome] += 1


def plan_cache_stats() -> dict:
    """{'hits', 'misses', 'size', 'by_backend'} — cumulative *_init calls
    served from / added to the plan cache; ``by_backend`` splits the same
    counters per transport backend (``{"emulated": {"hits": ..}, ...}``)."""
    return dict(_STATS, size=len(_PLAN_CACHE),
                by_backend={b: dict(c) for b, c in _BACKEND_STATS.items()})


def plan_cache_clear() -> None:
    """Empty the process-global plan cache and zero the hit/miss stats
    (tests and benchmarks isolating cache behaviour)."""
    _PLAN_CACHE.clear()
    _STATS["hits"] = 0
    _STATS["misses"] = 0
    _BACKEND_STATS.clear()


def _cached(key, build: Callable[[], Plan], backend: str = "emulated") -> Plan:
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _count(backend, "hits")
        return plan
    _count(backend, "misses")
    plan = build()
    _PLAN_CACHE[key] = plan
    return plan


def _cached_selected(sig, algorithm, select_fn, build_fn,
                     backend: str = "emulated") -> Plan:
    """Two-level lookup for plans whose build needs ``registry.select``.

    ``sig`` must capture everything the selection *and* the built closure
    depend on besides the registry state — shape, dtype, comm (identity AND
    group size: the same axis names can span different mesh sizes across
    traces in one process), backend/transport identity, and static kwargs.
    Fast path: (sig, requested algorithm, selection epoch) — a hit skips
    select() entirely; the epoch is bumped by every policy/override change,
    so the skip is sound.  Slow path: run select(), then dedupe on
    (sig, resolved name).
    """
    pre_key = ("sel", sig, algorithm, registry.selection_epoch())
    plan = _PLAN_CACHE.get(pre_key)
    if plan is not None:
        _count(backend, "hits")
        return plan
    algo = select_fn()
    key = ("plan", sig, algo.name)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _count(backend, "hits")
    else:
        _count(backend, "misses")
        plan = build_fn(algo)
        _PLAN_CACHE[key] = plan
    _PLAN_CACHE[pre_key] = plan
    return plan


# ---------------------------------------------------------------------------
# Collective plans
# ---------------------------------------------------------------------------

def collective_init(op_name: str, shape_dtype, *,
                    comm: Communicator | None = None,
                    algorithm: Optional[str] = None, **kw) -> Plan:
    """Build (or fetch from cache) a persistent plan for registry collective
    ``op_name``.  The algorithm is resolved ONCE — explicit ``algorithm=`` >
    process override > active policy table — and frozen into the plan, so
    later policy changes do not retarget an existing plan (MPI persistent
    semantics: the plan IS the frozen schedule); they do invalidate the
    selection fast path, so a fresh ``*_init`` re-selects."""
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    bk = _backend_key(comm)
    sig = (op_name, tuple(val.shape), str(jnp.dtype(val.dtype)), comm,
           comm.size(), bk, tuple(sorted(kw.items())))

    def select():
        return registry.select(op_name, val, comm, algorithm=algorithm, **kw)

    def build(algo):
        fn = algo.fn
        # Transport-backed comms may bind a persistent-channel issue
        # closure: fixed (shape, dtype) channels negotiated once, right
        # here at init time — the MPI-4 persistent-collective intent.
        # The hook is duck-typed (core never imports transport); None
        # falls back to re-issuing the frozen kernel.
        factory = getattr(comm, "persistent_issue_factory", None)
        issue = factory(op_name, algo.name, tuple(val.shape),
                        str(jnp.dtype(val.dtype)), dict(kw)) \
            if factory is not None else None
        host = issue is not None
        if issue is None:
            def issue(v, t):
                return fn(v, t, comm, **kw)

        return Plan(collective=op_name, algorithm=algo.name,
                    shape=tuple(val.shape), dtype=jnp.dtype(val.dtype),
                    comm=comm, issue_fn=issue, host=host)

    return _cached_selected(sig, algorithm, select, build, backend=bk[0])


def allreduce_init(shape_dtype, op: Operator = Operator.SUM, *,
                   comm: Communicator | None = None,
                   algorithm: Optional[str] = None) -> Plan:
    """MPI_Allreduce_init analogue."""
    return collective_init("allreduce", shape_dtype, comm=comm,
                           algorithm=algorithm, op=op)


def bcast_init(shape_dtype, root: int = 0, *,
               comm: Communicator | None = None,
               algorithm: Optional[str] = None) -> Plan:
    """MPI_Bcast_init analogue."""
    return collective_init("bcast", shape_dtype, comm=comm,
                           algorithm=algorithm, root=root)


def scatter_init(shape_dtype, root: int = 0, *,
                 comm: Communicator | None = None,
                 algorithm: Optional[str] = None) -> Plan:
    """MPI_Scatter_init analogue: frozen bcast + static per-rank slice.

    The group size is baked into the frozen chunk slice, so it is part of
    the cache signature (via ``sig``) — the same shape/axes under a
    different mesh size builds a fresh plan."""
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    n = comm.size()
    if val.shape[0] % n:
        raise ValueError(f"scatter payload axis0={val.shape[0]} not divisible "
                         f"by comm size {n}")
    bk = _backend_key(comm)
    sig = ("scatter", tuple(val.shape), str(jnp.dtype(val.dtype)), comm, n,
           bk, root)

    def select():
        return registry.select("bcast", val, comm, algorithm=algorithm,
                               root=root)

    def build(balgo):
        chunk = val.shape[0] // n
        fn = balgo.fn

        def issue(v, t):
            full, t = fn(v, t, comm, root=root)
            out = jax.lax.dynamic_slice_in_dim(full, comm.rank() * chunk,
                                               chunk, axis=0)
            return out, t

        return Plan(collective="scatter", algorithm=balgo.name,
                    shape=tuple(val.shape), dtype=jnp.dtype(val.dtype),
                    comm=comm, issue_fn=issue)

    return _cached_selected(sig, algorithm, select, build, backend=bk[0])


def allgather_init(shape_dtype, *, comm: Communicator | None = None,
                   algorithm: Optional[str] = None) -> Plan:
    """MPI_Allgather_init analogue."""
    return collective_init("allgather", shape_dtype, comm=comm,
                           algorithm=algorithm)


def gather_init(shape_dtype, root: int = 0, *,
                comm: Communicator | None = None,
                algorithm: Optional[str] = None) -> Plan:
    """MPI_Gather_init analogue (allgather lowering, root-only contract)."""
    del root
    return allgather_init(shape_dtype, comm=comm, algorithm=algorithm)


def alltoall_init(shape_dtype, *, comm: Communicator | None = None,
                  split_axis: int = 0, concat_axis: int = 0,
                  algorithm: Optional[str] = None) -> Plan:
    """MPI_Alltoall_init analogue."""
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    if len(comm.axes) != 1:
        raise ValueError("alltoall currently requires a single-axis "
                         "communicator (split the comm first)")
    if val.shape[split_axis] % comm.size():
        raise ValueError(f"alltoall axis {split_axis} size "
                         f"{val.shape[split_axis]} not divisible by comm "
                         f"size {comm.size()}")
    return collective_init("alltoall", val, comm=comm, algorithm=algorithm,
                           split_axis=split_axis, concat_axis=concat_axis)


def reduce_scatter_init(shape_dtype, op: Operator = Operator.SUM, *,
                        comm: Communicator | None = None,
                        algorithm: Optional[str] = None) -> Plan:
    """MPI_Reduce_scatter_init analogue."""
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    if val.shape[0] % comm.size():
        raise ValueError(f"reduce_scatter axis0={val.shape[0]} not divisible "
                         f"by comm size {comm.size()}")
    return collective_init("reduce_scatter", val, comm=comm,
                           algorithm=algorithm, op=op)


def scatterv_init(shape_dtype, counts, root: int = 0, *,
                  comm: Communicator | None = None,
                  algorithm: Optional[str] = None) -> Plan:
    """MPI_Scatterv_init analogue (ragged chunks, padded-buffer SPMD form).

    Args:
        shape_dtype: root's full ``(sum(counts), ...)`` buffer signature.
        counts: static per-rank row counts (frozen into the plan).
        root: static scattering rank.
        comm: communicator (None = ambient WORLD).
        algorithm: registry entry to freeze (``xla_native`` | ``linear``).
    Returns:
        A cached :class:`Plan`; ``start(x)`` completes with
        ``(max(counts), ...)`` (``counts[rank]`` valid rows).
    Raises:
        ValueError: bad counts or a signature/counts mismatch.
    """
    from repro.core import vcollectives
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    counts = vcollectives._validate_scatterv(comm, val, counts)
    return collective_init("scatterv", val, comm=comm, algorithm=algorithm,
                           counts=counts, root=root)


def gatherv_init(shape_dtype, counts, root: int = 0, *,
                 comm: Communicator | None = None,
                 algorithm: Optional[str] = None) -> Plan:
    """MPI_Gatherv_init analogue (valid-at-root contract).

    Args:
        shape_dtype: the local padded ``(max(counts), ...)`` signature.
        counts: static per-rank row counts (frozen into the plan).
        root: rank at which the result is contractually valid.
        comm: communicator (None = ambient WORLD).
        algorithm: registry entry to freeze (``xla_native`` | ``ring``).
    Returns:
        A cached :class:`Plan`; ``start(x)`` completes with
        ``(sum(counts), ...)``.
    Raises:
        ValueError: bad counts or a signature/counts mismatch.
    """
    from repro.core import vcollectives
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    counts = vcollectives._validate_gatherv(comm, val, counts)
    return collective_init("gatherv", val, comm=comm, algorithm=algorithm,
                           counts=counts, root=root)


def allgatherv_init(shape_dtype, counts, *, comm: Communicator | None = None,
                    algorithm: Optional[str] = None) -> Plan:
    """MPI_Allgatherv_init analogue.

    Args:
        shape_dtype: the local padded ``(max(counts), ...)`` signature.
        counts: static per-rank row counts (frozen into the plan).
        comm: communicator (None = ambient WORLD).
        algorithm: registry entry to freeze (``xla_native`` | ``ring``).
    Returns:
        A cached :class:`Plan`; ``start(x)`` completes with
        ``(sum(counts), ...)`` on every rank.
    Raises:
        ValueError: bad counts or a signature/counts mismatch.
    """
    from repro.core import vcollectives
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    counts = vcollectives._validate_gatherv(comm, val, counts)
    return collective_init("allgatherv", val, comm=comm, algorithm=algorithm,
                           counts=counts)


def alltoallv_init(shape_dtype, counts, *, comm: Communicator | None = None,
                   algorithm: Optional[str] = None) -> Plan:
    """MPI_Alltoallv_init analogue (n×n static counts matrix).

    Args:
        shape_dtype: the ``(n, max(counts), ...)`` stacked-slot signature.
        counts: static n×n matrix ``counts[src][dst]`` (frozen).
        comm: communicator (None = ambient WORLD).
        algorithm: registry entry to freeze (``xla_native`` | ``pairwise``).
    Returns:
        A cached :class:`Plan`; ``start(x)`` completes with the same-shape
        stack (slot ``s`` valid for ``counts[s][rank]`` rows).
    Raises:
        ValueError: bad counts matrix or a signature/counts mismatch.
    """
    from repro.core import vcollectives
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    counts = vcollectives._validate_alltoallv(comm, val, counts)
    return collective_init("alltoallv", val, comm=comm, algorithm=algorithm,
                           counts=counts)


def barrier_init(*, comm: Communicator | None = None) -> Plan:
    """MPI_Barrier_init analogue: ``plan.start()`` takes no payload."""
    comm = resolve(comm)
    bk = _backend_key(comm)
    key = ("barrier", "psum_probe", (), "float32", comm, comm.size(), bk)

    def build():
        def issue(v, t):
            probe = comm._barrier_probe(t)
            return probe, t

        return Plan(collective="barrier", algorithm="psum_probe", shape=(),
                    dtype=jnp.float32, comm=comm, issue_fn=issue)

    return _cached(key, build, backend=bk[0])


# ---------------------------------------------------------------------------
# Persistent neighborhood collectives (MPI_Neighbor_*_init): the halo-
# exchange workhorses — topology + algorithm frozen once per signature.
# ---------------------------------------------------------------------------

def _require_cart(comm):
    from repro.core.topology import _require_cart as req
    return req(comm)


def neighbor_allgather_init(shape_dtype, *, comm: Communicator | None = None,
                            algorithm: Optional[str] = None) -> Plan:
    """MPI_Neighbor_allgather_init analogue.

    Args:
        shape_dtype: per-rank payload signature.
        comm: a :class:`~repro.core.topology.CartComm` (None = ambient).
        algorithm: registry entry to freeze; None → policy choice.
    Returns:
        A cached :class:`Plan`; ``start(x)`` completes with
        ``(2·ndims, *shape)``.
    Raises:
        TypeError: the communicator carries no Cartesian topology.
    """
    comm = _require_cart(resolve(comm))
    return collective_init("neighbor_allgather", shape_dtype, comm=comm,
                           algorithm=algorithm)


def neighbor_alltoall_init(shape_dtype, *, comm: Communicator | None = None,
                           algorithm: Optional[str] = None) -> Plan:
    """MPI_Neighbor_alltoall_init analogue.

    Args:
        shape_dtype: the stacked ``(2·ndims, ...)`` send-slot signature.
        comm: a :class:`~repro.core.topology.CartComm` (None = ambient).
        algorithm: registry entry to freeze; None → policy choice.
    Returns:
        A cached :class:`Plan`; ``start(x)`` completes with the same shape.
    Raises:
        TypeError: no Cartesian topology; ValueError: axis 0 != 2·ndims.
    """
    comm = _require_cart(resolve(comm))
    val = _as_struct(shape_dtype)
    if len(val.shape) < 1 or val.shape[0] != 2 * comm.ndims:
        raise ValueError(
            f"neighbor_alltoall payload axis 0 must be 2*ndims = "
            f"{2 * comm.ndims}, got shape {tuple(val.shape)}")
    return collective_init("neighbor_alltoall", val, comm=comm,
                           algorithm=algorithm)


def neighbor_alltoallv_init(shape_dtypes, *, comm: Communicator | None = None,
                            algorithm: Optional[str] = None) -> Plan:
    """MPI_Neighbor_alltoallv_init analogue: vector per-slot signatures.

    The slot shapes are static kwargs of the frozen kernel; ``start(xs)``
    takes the slot *list* (packed to one flat buffer — the plan's frozen
    signature) and the Request completes with the received slot list
    (mirror-slot shapes, see :func:`repro.core.topology.recv_slot_shapes`).

    Args:
        shape_dtypes: sequence of 2·ndims per-slot signatures (shared
            dtype; shapes may differ per slot).
        comm: a :class:`~repro.core.topology.CartComm` (None = ambient).
        algorithm: registry entry to freeze; None → policy choice.
    Returns:
        A cached :class:`Plan` with slot pack/unpack adapters attached.
    Raises:
        TypeError: no Cartesian topology; ValueError: wrong slot count or
            mixed slot dtypes.
    """
    from repro.core import topology
    comm = _require_cart(resolve(comm))
    structs = [_as_struct(s) for s in shape_dtypes]
    dtype = topology.check_slots(comm, structs)
    shapes = tuple(tuple(s.shape) for s in structs)
    send_dt = datatypes_lib.slots(shapes, dtype)
    recv_dt = datatypes_lib.slots(topology.recv_slot_shapes(shapes), dtype)
    flat = send_dt.struct()
    bk = _backend_key(comm)
    sig = ("neighbor_alltoallv", tuple(flat.shape), str(jnp.dtype(flat.dtype)),
           comm, comm.size(), bk, shapes)

    def select():
        return registry.select("neighbor_alltoallv", flat, comm,
                               algorithm=algorithm, slot_shapes=shapes)

    def build(algo):
        fn = algo.fn

        def issue(v, t):
            return fn(v, t, comm, slot_shapes=shapes)

        return Plan(collective="neighbor_alltoallv", algorithm=algo.name,
                    shape=tuple(flat.shape), dtype=jnp.dtype(flat.dtype),
                    comm=comm, issue_fn=issue, datatype=send_dt,
                    recv=recv_dt.bind(None))

    return _cached_selected(sig, algorithm, select, build, backend=bk[0])


# ---------------------------------------------------------------------------
# Persistent p2p (MPI_Send_init/MPI_Recv_init family): the halo-exchange
# workhorse — the (src, dst) pattern is validated and frozen once.
# ---------------------------------------------------------------------------

def sendrecv_init(shape_dtype, pairs=None, *, perm=None, dest=None,
                  source=None, comm: Communicator | None = None,
                  recv_into=None) -> Plan:
    """Persistent fused send+recv along a static (src → dst) pattern.

    The permutation is validated (rank range, injectivity) at init and
    frozen; ``plan.start(strip)`` is one token-tied ppermute.

    ``recv_into``: a View / bound datatype the received message scatters
    into at completion (the same receive pipeline as ``sendrecv``); when
    its layout is statically smaller than the frozen message signature,
    every Request the plan starts carries ERR_TRUNCATE — computed once at
    init from the static shapes, the persistent analogue of the direct
    path's check.  Plans with a receive adapter are not cached (the
    adapter binds a specific target buffer).
    """
    comm = resolve(comm)
    val = _as_struct(shape_dtype)
    from repro.core.p2p import _resolve_perm
    p = tuple(tuple(pr) for pr in _resolve_perm(comm, pairs, perm, dest,
                                                source))
    bk = _backend_key(comm)
    key = ("sendrecv", "ppermute", tuple(val.shape),
           str(jnp.dtype(val.dtype)), comm, comm.size(), bk, p)
    recv = datatypes_lib.recv_adapter(recv_into)
    rcount = datatypes_lib.adapter_count(recv)
    status = SUCCESS
    if rcount is not None and rcount < int(np.prod(val.shape, dtype=int)):
        status = ERR_TRUNCATE

    def build():
        perm_list = [tuple(pr) for pr in p]
        # Same duck-typed seam as collective_init: a transport-backed
        # comm negotiates fixed-signature channels with the frozen
        # pattern's peers once, at init — plan.start then writes payload
        # straight into channel memory.  The algorithm name records
        # which path was frozen.
        factory = getattr(comm, "persistent_sendrecv_factory", None)
        issue = factory(tuple(val.shape), str(jnp.dtype(val.dtype)),
                        perm_list) if factory is not None else None
        algo_name = "channel" if issue is not None else "ppermute"
        host = issue is not None
        if issue is None:
            def issue(v, t):
                out = comm._ppermute(v, perm_list)
                return out, t

        return Plan(collective="sendrecv", algorithm=algo_name,
                    shape=tuple(val.shape), dtype=jnp.dtype(val.dtype),
                    comm=comm, issue_fn=issue, recv=recv, status=status,
                    host=host)

    if recv is not None:
        return build()
    return _cached(key, build, backend=bk[0])
