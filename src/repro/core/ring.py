"""Hand-scheduled ring collectives (chunked ppermute) — the overlap lever.

XLA lowers ``psum`` to its own collective schedule; on TPU that is usually
optimal for *standalone* reductions, but it exposes no seam for overlapping
the reduction with producer/consumer compute.  These ring variants split the
payload into ``size`` chunks and run the classic two-phase schedule
(reduce-scatter ring, then allgather ring) as 2·(n−1) explicit ppermute steps.
Because each step is an independent dataflow node, XLA's latency-hiding
scheduler can overlap chunk k's permute with chunk k±1's add — and, when the
caller interleaves matmul flops between steps (see
``repro.distributed.overlap.collective_matmul``), comm hides under compute.

Operator coverage: the accumulate-and-forward steps use the shared operator
algebra (``repro.core.operators``), so ring allreduce/reduce_scatter honor
the full six-operator surface (SUM/PROD/MIN/MAX/LAND/LOR) — identical
results to the xla_native kernels, tested against the numpy oracle.

Registered in the collective-algorithm registry as the ``ring`` entries for
allreduce / allgather / reduce_scatter; pick them per call
(``jmpi.allreduce(x, algorithm="ring")``), globally
(``jmpi.set_algorithm("allreduce", "ring")``), or let the policy table route
bandwidth-bound payloads here.  The back-compat public wrappers ride the
persistent-plan path (``repro.core.plans``) — the ``ring`` choice frozen
into a cached Plan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import operators as op_lib
from repro.core import registry
from repro.core import token as token_lib
from repro.core.comm import Communicator, resolve
from repro.core.operators import Operator


def _split(x, n):
    pad = (-x.shape[0]) % n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x.reshape(n, -1, *x.shape[1:]), pad


def _unrolled(step, n_steps, carry):
    """Unroll the ring so every permute is a distinct HLO op (overlappable).

    A fori_loop would serialize steps behind a loop counter; rings are short
    (n−1 ≤ 15 on a 16-wide axis) so full unroll is the right trade.
    """
    for i in range(n_steps):
        carry = step(i, carry)
    return carry


def _dynamic_set(chunks, value, idx):
    return jax.lax.dynamic_update_index_in_dim(chunks, value, idx, axis=0)


# ===========================================================================
# Registry kernels
# ===========================================================================

@registry.register("allreduce", "ring")
def _ring_allreduce_kernel(val, tok, comm, *, op=None):
    """Bandwidth-optimal allreduce: 2·(n−1) chunk steps, 2·(n−1)/n · |x| bytes
    per link — same wire cost as XLA's psum, but overlappable chunk-by-chunk.
    All six Operators: accumulate-and-forward uses the operator's combiner
    with its identity element seeding the accumulator."""
    op = Operator.SUM if op is None else op
    combine, pre, post = op_lib.combiner(op)
    n = comm.size()
    orig_dtype = val.dtype
    work = pre(val) if pre is not None else val
    if n == 1:
        out = post(work, orig_dtype) if post is not None else work
        return out, tok
    orig_shape = work.shape
    flat = work.reshape(work.shape[0], -1) if work.ndim > 1 \
        else work.reshape(-1, 1)
    chunks, pad = _split(flat, n)  # (n, chunk, rest)
    ident = op_lib.identity_scalar(op, chunks.dtype)
    rank = comm.rank()
    fwd = comm.ring_perm(+1)

    # Phase 1: reduce-scatter ring. After n-1 steps, rank r holds the full
    # reduction of chunk (r+1) mod n.
    def rs_step(i, carry):
        chunks, acc, tok = carry
        # which chunk to send at step i: (rank - i) mod n
        idx = (rank - i) % n
        send = jax.lax.dynamic_index_in_dim(chunks, idx, axis=0, keepdims=False)
        send = combine(send, acc)
        tok, send = token_lib.tie(tok, send)
        recv = jax.lax.ppermute(send, comm.axes, fwd)
        tok = token_lib.advance(tok, recv)
        return chunks, recv, tok

    acc = jnp.full_like(chunks[0], ident)
    chunks, acc, tok = _unrolled(rs_step, n - 1, (chunks, acc, tok))
    # acc now holds the reduction of chunk (rank+1)%n minus own contribution.
    own_idx = (rank - (n - 1)) % n
    own = jax.lax.dynamic_index_in_dim(chunks, own_idx, axis=0, keepdims=False)
    full_chunk = combine(acc, own)  # rank r owns reduced chunk (r+1)%n

    # Phase 2: allgather ring: circulate the reduced chunks n-1 steps.
    def ag_step(i, carry):
        chunks, cur, tok = carry
        tok, cur = token_lib.tie(tok, cur)
        nxt = jax.lax.ppermute(cur, comm.axes, fwd)
        tok = token_lib.advance(tok, nxt)
        idx = (rank - i) % n  # chunk id that just arrived
        chunks = jax.lax.dynamic_update_index_in_dim(chunks, nxt, idx, axis=0)
        return chunks, nxt, tok

    out_chunks = jnp.zeros_like(chunks)
    own_slot = (rank + 1) % n
    out_chunks = _dynamic_set(out_chunks, full_chunk, own_slot)
    out_chunks, _, tok = _unrolled(ag_step, n - 1, (out_chunks, full_chunk, tok))

    flat_out = out_chunks.reshape(-1, flat.shape[-1])
    if pad:
        flat_out = flat_out[:flat.shape[0]]
    out = flat_out.reshape(orig_shape)
    out = post(out, orig_dtype) if post is not None else out.astype(orig_dtype)
    return out, tok


@registry.register("allgather", "ring")
def _ring_allgather_kernel(val, tok, comm):
    """Allgather as n−1 ppermute steps; axis-0 concatenation, tiled layout."""
    n = comm.size()
    if n == 1:
        return val, tok
    rank = comm.rank()
    fwd = comm.ring_perm(+1)
    cur = val
    slots = jnp.zeros((n,) + val.shape, val.dtype)
    slots = jax.lax.dynamic_update_index_in_dim(slots, cur, rank, axis=0)
    for i in range(n - 1):
        tok, cur = token_lib.tie(tok, cur)
        cur = jax.lax.ppermute(cur, comm.axes, fwd)
        tok = token_lib.advance(tok, cur)
        src = (rank - (i + 1)) % n
        slots = jax.lax.dynamic_update_index_in_dim(slots, cur, src, axis=0)
    out = slots.reshape((n * val.shape[0],) + val.shape[1:])
    return out, tok


@registry.register("reduce_scatter", "ring")
def _ring_reduce_scatter_kernel(val, tok, comm, *, op=None):
    """Reduce-scatter as the ring's phase 1 plus one final alignment hop:
    n−1 accumulate-and-forward chunk steps leave rank r with reduced chunk
    (r+1) mod n; a last forward permute homes chunk r on rank r.  Honors all
    six Operators via the shared combiner algebra."""
    op = Operator.SUM if op is None else op
    combine, pre, post = op_lib.combiner(op)
    n = comm.size()
    orig_dtype = val.dtype
    work = pre(val) if pre is not None else val
    if n == 1:
        out = post(work, orig_dtype) if post is not None else work
        return out, tok
    rank = comm.rank()
    fwd = comm.ring_perm(+1)
    chunks = work.reshape(n, work.shape[0] // n, *work.shape[1:])
    ident = op_lib.identity_scalar(op, chunks.dtype)

    def rs_step(i, carry):
        acc, tok = carry
        idx = (rank - i) % n
        send = jax.lax.dynamic_index_in_dim(chunks, idx, axis=0, keepdims=False)
        send = combine(send, acc)
        tok, send = token_lib.tie(tok, send)
        recv = jax.lax.ppermute(send, comm.axes, fwd)
        tok = token_lib.advance(tok, recv)
        return recv, tok

    acc = jnp.full_like(chunks[0], ident)
    acc, tok = _unrolled(rs_step, n - 1, (acc, tok))
    own_idx = (rank - (n - 1)) % n
    own = jax.lax.dynamic_index_in_dim(chunks, own_idx, axis=0, keepdims=False)
    full_chunk = combine(acc, own)    # reduced chunk (rank+1) mod n
    tok, full_chunk = token_lib.tie(tok, full_chunk)
    out = jax.lax.ppermute(full_chunk, comm.axes, fwd)   # home chunk r → rank r
    tok = token_lib.advance(tok, out)
    out = post(out, orig_dtype) if post is not None else out.astype(orig_dtype)
    return out, tok


# ===========================================================================
# Back-compat public wrappers (pre-registry API, used by benches/tests) —
# now persistent-plan clients: the ``ring`` choice is frozen into a cached
# Plan, so hot loops re-start the same plan instead of re-dispatching.
# ===========================================================================

def ring_allreduce(x, *, comm: Communicator | None = None, token=None):
    """``jmpi.allreduce(x, algorithm="ring")`` under the original name."""
    from repro.core import plans
    from repro.core import views as views_lib
    from repro.core.p2p import wait
    comm = resolve(comm)
    val = views_lib.pack(x)
    plan = plans.allreduce_init(jax.ShapeDtypeStruct(val.shape, val.dtype),
                                comm=comm, algorithm="ring")
    req = plan.start(val, token=token)
    status, out = wait(req)
    if token is not None:
        return status, out, req.token
    return status, out


def ring_allgather(x, *, comm: Communicator | None = None, token=None):
    """``jmpi.allgather(x, algorithm="ring")`` under the original name."""
    from repro.core import plans
    from repro.core import views as views_lib
    from repro.core.p2p import wait
    comm = resolve(comm)
    val = views_lib.pack(x)
    plan = plans.allgather_init(jax.ShapeDtypeStruct(val.shape, val.dtype),
                                comm=comm, algorithm="ring")
    req = plan.start(val, token=token)
    status, out = wait(req)
    if token is not None:
        return status, out, req.token
    return status, out
