"""Communicators — process groups over mesh-axis subsets.

numba-mpi v1.0 exposes only ``MPI_COMM_WORLD`` (non-default communicators are
named future work in the paper §4).  We implement the full abstraction: a
``Communicator`` names an ordered subset of the enclosing ``shard_map`` mesh
axes; ranks are row-major linearized over those axes (first axis slowest),
matching the ``jax.lax.ppermute`` tuple-axis linearization.  Devices that
share coordinates on the *other* mesh axes form independent groups — exactly
MPI's ``Comm_split`` semantics, obtained for free from named-axis SPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.core import token as token_lib


@dataclasses.dataclass(frozen=True)
class Communicator:
    """A process group spanning the named mesh axes (row-major rank order)."""

    axes: tuple[str, ...]

    def __post_init__(self):
        if not self.axes:
            raise ValueError("Communicator needs at least one mesh axis")

    # -- topology (static; trace-time) ------------------------------------
    def size(self) -> int:
        """Number of ranks. Static Python int (psum of a literal)."""
        return int(jax.lax.psum(1, self.axes))

    def axis_sizes(self) -> tuple[int, ...]:
        return tuple(int(jax.lax.psum(1, a)) for a in self.axes)

    # -- identity (traced; per-device) -------------------------------------
    def rank(self) -> jax.Array:
        """This device's rank within the group (traced int32)."""
        return jax.lax.axis_index(self.axes)

    def coords(self) -> tuple[jax.Array, ...]:
        return tuple(jax.lax.axis_index(a) for a in self.axes)

    # -- derived communicators ---------------------------------------------
    def split(self, axes: Sequence[str]) -> "Communicator":
        """Sub-communicator over a subset of this group's axes.

        MPI ``Comm_split`` with color = coordinates on the dropped axes.
        """
        axes = tuple(axes)
        missing = [a for a in axes if a not in self.axes]
        if missing:
            raise ValueError(f"axes {missing} not part of communicator {self.axes}")
        return Communicator(axes)

    # -- permutation builders (static, for p2p) -----------------------------
    def ring_perm(self, shift: int = 1) -> list[tuple[int, int]]:
        """src→dst pairs for a cyclic shift by ``shift`` (MPI_Cart_shift)."""
        n = self.size()
        return [(i, (i + shift) % n) for i in range(n)]

    def pairwise_perm(self, pairs: Sequence[tuple[int, int]],
                      bidirectional: bool = False) -> list[tuple[int, int]]:
        """Explicit (src, dst) pairs; validates ranks and injectivity."""
        n = self.size()
        perm = list(pairs)
        if bidirectional:
            perm += [(d, s) for (s, d) in pairs]
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        for r in srcs + dsts:
            if not (0 <= r < n):
                raise ValueError(f"rank {r} out of range for comm of size {n}")
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            raise ValueError("permutation must be injective (one message per "
                             "rank per ppermute); split into multiple calls")
        return perm

    def neighbor_perm(self, fn: Callable[[int], int | None]) -> list[tuple[int, int]]:
        """Build a permutation from a dest-function evaluated per static rank."""
        perm = []
        for src in range(self.size()):
            dst = fn(src)
            if dst is not None:
                perm.append((src, int(dst)))
        return self.pairwise_perm(perm)


# --------------------------------------------------------------------------
# Ambient "world" — set by ``repro.core.spmd`` so call sites can write
# ``jmpi.rank()`` exactly as in the paper's listings.
# --------------------------------------------------------------------------
_WORLD: list[Communicator | None] = [None]


def set_world(comm: Communicator | None) -> None:
    _WORLD[0] = comm


def world() -> Communicator:
    if _WORLD[0] is None:
        raise RuntimeError(
            "No ambient communicator: call jmpi ops inside a repro.core.spmd-"
            "wrapped function, or pass comm= explicitly.")
    return _WORLD[0]


def resolve(comm: Communicator | None) -> Communicator:
    return comm if comm is not None else world()


def spmd(mesh, in_specs, out_specs, axis_names: tuple[str, ...] | None = None,
         check_vma: bool = False, jit: bool = True):
    """``mpiexec`` analogue: wrap a function in jit(shard_map) + install WORLD.

    Inside the wrapped function, ``jmpi.rank()/size()`` and every collective
    default to a communicator spanning all mesh axes (row-major), and a fresh
    ambient ordering token is installed — mirroring numba-mpi's import-time
    MPI_Init. The whole body is ONE XLA program: compute *and* communication
    JIT-resident, which is the paper's point (``jit=False`` opts into eager
    shard_map — the per-op-dispatch mode, for debugging only; it is the
    moral equivalent of running numba-mpi with NUMBA_DISABLE_JIT).
    """
    def deco(fn):
        names = axis_names if axis_names is not None else tuple(mesh.axis_names)

        def body(*args, **kwargs):
            prev = _WORLD[0]
            set_world(Communicator(names))
            token_lib.reset_ambient()
            try:
                return fn(*args, **kwargs)
            finally:
                set_world(prev)
                token_lib.reset_ambient()

        wrapped = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_vma=check_vma)
        return jax.jit(wrapped) if jit else wrapped

    return deco
