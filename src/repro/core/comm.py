"""Communicators — process groups over mesh-axis subsets, and (jmpi 2.0)
the center of the API: every v1.0 routine and every beyond-paper collective
is a ``Communicator`` method.

numba-mpi v1.0 exposes only ``MPI_COMM_WORLD`` (non-default communicators are
named future work in the paper §4).  We implement the full abstraction: a
``Communicator`` names an ordered subset of the enclosing ``shard_map`` mesh
axes; ranks are row-major linearized over those axes (first axis slowest),
matching the ``jax.lax.ppermute`` tuple-axis linearization.  Devices that
share coordinates on the *other* mesh axes form independent groups — exactly
MPI's ``Comm_split`` semantics, obtained for free from named-axis SPMD.

jmpi 2.0 method surface (module-level functions remain as thin wrappers that
resolve the ambient WORLD and delegate here — no v1.0 call site breaks)::

    comm = jmpi.world()                 # or Communicator(("data",)), .split()
    status, y = comm.allreduce(x)       # blocking collective
    req = comm.iallreduce(x)            # MPI-3 nonblocking -> Request
    plan = comm.allreduce_init(jax.ShapeDtypeStruct(x.shape, x.dtype))
    req = plan.start(x)                 # MPI-4 persistent -> Request
    status, y = jmpi.wait(req)          # one unified completion model

The method bodies import their implementation modules lazily: collectives /
p2p / plans all import this module for ``resolve``, so eager imports here
would cycle.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import jax

from repro.core import compat
from repro.core import token as token_lib
from repro.core.operators import Operator

_DUP_CONTEXTS = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class Communicator:
    """A process group spanning the named mesh axes (row-major rank order).

    ``context`` distinguishes :meth:`dup` clones (MPI_Comm_dup semantics: a
    duplicated communicator is a distinct communication context — it hashes
    and compares separately, so e.g. persistent plans built on the dup are
    cached independently of the original's).
    """

    axes: tuple[str, ...]
    context: int = 0

    def __post_init__(self):
        if not self.axes:
            raise ValueError("Communicator needs at least one mesh axis")

    # -- topology (static; trace-time) ------------------------------------
    def size(self) -> int:
        """Number of ranks. Static Python int (psum of a literal)."""
        return int(jax.lax.psum(1, self.axes))

    def axis_sizes(self) -> tuple[int, ...]:
        return tuple(int(jax.lax.psum(1, a)) for a in self.axes)

    # -- identity (traced; per-device) -------------------------------------
    def rank(self) -> jax.Array:
        """This device's rank within the group (traced int32)."""
        return jax.lax.axis_index(self.axes)

    def coords(self) -> tuple[jax.Array, ...]:
        return tuple(jax.lax.axis_index(a) for a in self.axes)

    # -- derived communicators ---------------------------------------------
    def split(self, axes: Sequence[str]) -> "Communicator":
        """Sub-communicator over a subset of this group's axes.

        MPI ``Comm_split`` with color = coordinates on the dropped axes.
        """
        axes = tuple(axes)
        missing = [a for a in axes if a not in self.axes]
        if missing:
            raise ValueError(f"axes {missing} not part of communicator {self.axes}")
        # Inherit the communication context: a dup's sub-communicators stay
        # distinct from the original's (their plans/caches are independent),
        # while re-derived splits of the SAME parent compare equal.
        return Communicator(axes, self.context)

    def dup(self) -> "Communicator":
        """MPI_Comm_dup: same group, fresh communication context (distinct
        identity — plans/caches keyed on the dup are independent)."""
        return dataclasses.replace(self, context=next(_DUP_CONTEXTS))

    # -- permutation builders (static, for p2p) -----------------------------
    def ring_perm(self, shift: int = 1) -> list[tuple[int, int]]:
        """src→dst pairs for a cyclic shift by ``shift`` (MPI_Cart_shift)."""
        n = self.size()
        return [(i, (i + shift) % n) for i in range(n)]

    def pairwise_perm(self, pairs: Sequence[tuple[int, int]],
                      bidirectional: bool = False) -> list[tuple[int, int]]:
        """Explicit (src, dst) pairs; validates ranks and injectivity."""
        n = self.size()
        perm = list(pairs)
        if bidirectional:
            perm += [(d, s) for (s, d) in pairs]
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        for r in srcs + dsts:
            if not (0 <= r < n):
                raise ValueError(f"rank {r} out of range for comm of size {n}")
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            raise ValueError("permutation must be injective (one message per "
                             "rank per ppermute); split into multiple calls")
        return perm

    def neighbor_perm(self, fn: Callable[[int], int | None]) -> list[tuple[int, int]]:
        """Build a permutation from a dest-function evaluated per static rank."""
        perm = []
        for src in range(self.size()):
            dst = fn(src)
            if dst is not None:
                perm.append((src, int(dst)))
        return self.pairwise_perm(perm)

    # ======================================================================
    # jmpi 2.0 — every routine as a communicator method.  Lazy imports:
    # collectives/p2p/plans import this module (resolve), so the delegation
    # must bind at call time.
    # ======================================================================

    # -- blocking collectives (v1.0 surface) -------------------------------
    def allreduce(self, x, op: Operator = Operator.SUM, *, token=None,
                  algorithm=None):
        from repro.core import collectives as c
        return c.allreduce(x, op, comm=self, token=token, algorithm=algorithm)

    def bcast(self, x, root: int = 0, *, token=None, algorithm=None):
        from repro.core import collectives as c
        return c.bcast(x, root, comm=self, token=token, algorithm=algorithm)

    def scatter(self, x, root: int = 0, *, token=None, algorithm=None):
        from repro.core import collectives as c
        return c.scatter(x, root, comm=self, token=token, algorithm=algorithm)

    def gather(self, x, root: int = 0, *, token=None, algorithm=None):
        from repro.core import collectives as c
        return c.gather(x, root, comm=self, token=token, algorithm=algorithm)

    def allgather(self, x, *, token=None, algorithm=None):
        from repro.core import collectives as c
        return c.allgather(x, comm=self, token=token, algorithm=algorithm)

    def alltoall(self, x, *, token=None, split_axis: int = 0,
                 concat_axis: int = 0, algorithm=None):
        from repro.core import collectives as c
        return c.alltoall(x, comm=self, token=token, split_axis=split_axis,
                          concat_axis=concat_axis, algorithm=algorithm)

    def reduce_scatter(self, x, op: Operator = Operator.SUM, *, token=None,
                       algorithm=None):
        from repro.core import collectives as c
        return c.reduce_scatter(x, op, comm=self, token=token,
                                algorithm=algorithm)

    def barrier(self, *, token=None):
        from repro.core import collectives as c
        return c.barrier(comm=self, token=token)

    # -- nonblocking collectives (MPI-3 i* -> Request) ---------------------
    def iallreduce(self, x, op: Operator = Operator.SUM, *, token=None,
                   algorithm=None, tag: int = 0):
        from repro.core import collectives as c
        return c.iallreduce(x, op, comm=self, token=token,
                            algorithm=algorithm, tag=tag)

    def ibcast(self, x, root: int = 0, *, token=None, algorithm=None,
               tag: int = 0):
        from repro.core import collectives as c
        return c.ibcast(x, root, comm=self, token=token, algorithm=algorithm,
                        tag=tag)

    def iscatter(self, x, root: int = 0, *, token=None, algorithm=None,
                 tag: int = 0):
        from repro.core import collectives as c
        return c.iscatter(x, root, comm=self, token=token,
                          algorithm=algorithm, tag=tag)

    def igather(self, x, root: int = 0, *, token=None, algorithm=None,
                tag: int = 0):
        from repro.core import collectives as c
        return c.igather(x, root, comm=self, token=token, algorithm=algorithm,
                         tag=tag)

    def iallgather(self, x, *, token=None, algorithm=None, tag: int = 0):
        from repro.core import collectives as c
        return c.iallgather(x, comm=self, token=token, algorithm=algorithm,
                            tag=tag)

    def ialltoall(self, x, *, token=None, split_axis: int = 0,
                  concat_axis: int = 0, algorithm=None, tag: int = 0):
        from repro.core import collectives as c
        return c.ialltoall(x, comm=self, token=token, split_axis=split_axis,
                           concat_axis=concat_axis, algorithm=algorithm,
                           tag=tag)

    def ireduce_scatter(self, x, op: Operator = Operator.SUM, *, token=None,
                        algorithm=None, tag: int = 0):
        from repro.core import collectives as c
        return c.ireduce_scatter(x, op, comm=self, token=token,
                                 algorithm=algorithm, tag=tag)

    def ibarrier(self, *, token=None, tag: int = 0):
        from repro.core import collectives as c
        return c.ibarrier(comm=self, token=token, tag=tag)

    # -- point-to-point ----------------------------------------------------
    def send(self, x, dest: int, *, source: int, tag: int = 0, token=None):
        from repro.core import p2p
        return p2p.send(x, dest, source=source, tag=tag, comm=self,
                        token=token)

    def recv(self, x, source: int, *, dest: int, tag: int = 0, token=None):
        from repro.core import p2p
        return p2p.recv(x, source, dest=dest, tag=tag, comm=self, token=token)

    def sendrecv(self, x, pairs=None, *, perm=None, dest=None, source=None,
                 tag: int = 0, token=None, recv_into=None):
        from repro.core import p2p
        return p2p.sendrecv(x, pairs, perm=perm, dest=dest, source=source,
                            tag=tag, comm=self, token=token,
                            recv_into=recv_into)

    def isend(self, x, dest: int, *, source: int, tag: int = 0, token=None):
        from repro.core import p2p
        return p2p.isend(x, dest, source=source, tag=tag, comm=self,
                         token=token)

    def irecv(self, x, source: int, *, dest: int, tag: int = 0, token=None):
        from repro.core import p2p
        return p2p.irecv(x, source, dest=dest, tag=tag, comm=self,
                         token=token)

    def isendrecv(self, x, pairs=None, *, perm=None, dest=None, source=None,
                  tag: int = 0, token=None, recv_into=None):
        from repro.core import p2p
        return p2p.isendrecv(x, pairs, perm=perm, dest=dest, source=source,
                             tag=tag, comm=self, token=token,
                             recv_into=recv_into)

    # -- persistent plans (MPI-4 *_init -> Plan) ---------------------------
    def allreduce_init(self, shape_dtype, op: Operator = Operator.SUM, *,
                       algorithm=None):
        from repro.core import plans
        return plans.allreduce_init(shape_dtype, op, comm=self,
                                    algorithm=algorithm)

    def bcast_init(self, shape_dtype, root: int = 0, *, algorithm=None):
        from repro.core import plans
        return plans.bcast_init(shape_dtype, root, comm=self,
                                algorithm=algorithm)

    def scatter_init(self, shape_dtype, root: int = 0, *, algorithm=None):
        from repro.core import plans
        return plans.scatter_init(shape_dtype, root, comm=self,
                                  algorithm=algorithm)

    def gather_init(self, shape_dtype, root: int = 0, *, algorithm=None):
        from repro.core import plans
        return plans.gather_init(shape_dtype, root, comm=self,
                                 algorithm=algorithm)

    def allgather_init(self, shape_dtype, *, algorithm=None):
        from repro.core import plans
        return plans.allgather_init(shape_dtype, comm=self,
                                    algorithm=algorithm)

    def alltoall_init(self, shape_dtype, *, split_axis: int = 0,
                      concat_axis: int = 0, algorithm=None):
        from repro.core import plans
        return plans.alltoall_init(shape_dtype, comm=self,
                                   split_axis=split_axis,
                                   concat_axis=concat_axis,
                                   algorithm=algorithm)

    def reduce_scatter_init(self, shape_dtype, op: Operator = Operator.SUM,
                            *, algorithm=None):
        from repro.core import plans
        return plans.reduce_scatter_init(shape_dtype, op, comm=self,
                                         algorithm=algorithm)

    def barrier_init(self):
        from repro.core import plans
        return plans.barrier_init(comm=self)

    def sendrecv_init(self, shape_dtype, pairs=None, *, perm=None, dest=None,
                      source=None):
        from repro.core import plans
        return plans.sendrecv_init(shape_dtype, pairs, perm=perm, dest=dest,
                                   source=source, comm=self)


# --------------------------------------------------------------------------
# Ambient "world" — set by ``repro.core.spmd`` so call sites can write
# ``jmpi.rank()`` exactly as in the paper's listings.
# --------------------------------------------------------------------------
_WORLD: list[Communicator | None] = [None]


def set_world(comm: Communicator | None) -> None:
    _WORLD[0] = comm


def world() -> Communicator:
    if _WORLD[0] is None:
        raise RuntimeError(
            "No ambient communicator: call jmpi ops inside a repro.core.spmd-"
            "wrapped function, or pass comm= explicitly.")
    return _WORLD[0]


def resolve(comm: Communicator | None) -> Communicator:
    return comm if comm is not None else world()


def spmd(mesh, in_specs, out_specs, axis_names: tuple[str, ...] | None = None,
         check_vma: bool = False, jit: bool = True):
    """``mpiexec`` analogue: wrap a function in jit(shard_map) + install WORLD.

    Inside the wrapped function, ``jmpi.rank()/size()`` and every collective
    default to a communicator spanning all mesh axes (row-major), and a fresh
    ambient ordering token is installed — mirroring numba-mpi's import-time
    MPI_Init. The whole body is ONE XLA program: compute *and* communication
    JIT-resident, which is the paper's point (``jit=False`` opts into eager
    shard_map — the per-op-dispatch mode, for debugging only; it is the
    moral equivalent of running numba-mpi with NUMBA_DISABLE_JIT).
    """
    def deco(fn):
        names = axis_names if axis_names is not None else tuple(mesh.axis_names)

        def body(*args, **kwargs):
            prev = _WORLD[0]
            set_world(Communicator(names))
            token_lib.reset_ambient()
            try:
                return fn(*args, **kwargs)
            finally:
                set_world(prev)
                token_lib.reset_ambient()

        wrapped = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_vma=check_vma)
        return jax.jit(wrapped) if jit else wrapped

    return deco
