"""Communicators — process groups over mesh-axis subsets, and (jmpi 2.0)
the center of the API: every v1.0 routine and every beyond-paper collective
is a ``Communicator`` method.

numba-mpi v1.0 exposes only ``MPI_COMM_WORLD`` (non-default communicators are
named future work in the paper §4).  We implement the full abstraction: a
``Communicator`` names an ordered subset of the enclosing ``shard_map`` mesh
axes; ranks are row-major linearized over those axes (first axis slowest),
matching the ``jax.lax.ppermute`` tuple-axis linearization.  Devices that
share coordinates on the *other* mesh axes form independent groups — exactly
MPI's ``Comm_split`` semantics, obtained for free from named-axis SPMD.

jmpi 2.0 method surface (module-level functions remain as thin wrappers that
resolve the ambient WORLD and delegate here — no v1.0 call site breaks)::

    comm = jmpi.world()                 # or Communicator(("data",)), .split()
    status, y = comm.allreduce(x)       # blocking collective
    req = comm.iallreduce(x)            # MPI-3 nonblocking -> Request
    plan = comm.allreduce_init(jax.ShapeDtypeStruct(x.shape, x.dtype))
    req = plan.start(x)                 # MPI-4 persistent -> Request
    status, y = jmpi.wait(req)          # one unified completion model

The method bodies import their implementation modules lazily: collectives /
p2p / plans all import this module for ``resolve``, so eager imports here
would cycle.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import jax

from repro.core import compat
from repro.core import token as token_lib
from repro.core.operators import Operator

_DUP_CONTEXTS = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class Communicator:
    """A process group spanning the named mesh axes (row-major rank order).

    ``context`` distinguishes :meth:`dup` clones (MPI_Comm_dup semantics: a
    duplicated communicator is a distinct communication context — it hashes
    and compares separately, so e.g. persistent plans built on the dup are
    cached independently of the original's).
    """

    axes: tuple[str, ...]
    context: int = 0

    #: Which transport backend this communicator's ops execute on.  The
    #: emulated backend runs inside one process over shard_map mesh axes;
    #: ``repro.transport.endpoint.MultiprocComm`` overrides this (plain class
    #: attribute, not a dataclass field) together with the ``_ppermute`` /
    #: ``_barrier_probe`` wire hooks below.
    backend = "emulated"

    def __post_init__(self):
        if not self.axes:
            raise ValueError("Communicator needs at least one mesh axis")

    # -- topology (static; trace-time) ------------------------------------
    def size(self) -> int:
        """Number of ranks. Static Python int (psum of a literal)."""
        return int(jax.lax.psum(1, self.axes))

    def axis_sizes(self) -> tuple[int, ...]:
        """Per-axis extents of the group.

        Returns:
            One static Python int per mesh axis, in axis order.
        """
        return tuple(int(jax.lax.psum(1, a)) for a in self.axes)

    # -- identity (traced; per-device) -------------------------------------
    def rank(self) -> jax.Array:
        """This device's rank within the group (traced int32)."""
        return jax.lax.axis_index(self.axes)

    def coords(self) -> tuple[jax.Array, ...]:
        """This device's per-axis mesh coordinates.

        Returns:
            One traced int32 scalar per mesh axis (``rank()`` is their
            row-major combination).
        """
        return tuple(jax.lax.axis_index(a) for a in self.axes)

    # -- derived communicators ---------------------------------------------
    def split(self, axes: Sequence[str]) -> "Communicator":
        """Sub-communicator over a subset of this group's axes.

        MPI ``Comm_split`` with color = coordinates on the dropped axes:
        devices agreeing on every dropped axis form one group.

        Args:
            axes: the mesh axes the sub-communicator spans (must be a
                subset of this group's axes; order defines rank order).
        Returns:
            A plain :class:`Communicator` inheriting this one's context
            (re-derived splits of the same parent compare equal).
        Raises:
            ValueError: an axis is not part of this communicator.
        """
        axes = tuple(axes)
        missing = [a for a in axes if a not in self.axes]
        if missing:
            raise ValueError(f"axes {missing} not part of communicator {self.axes}")
        # Inherit the communication context: a dup's sub-communicators stay
        # distinct from the original's (their plans/caches are independent),
        # while re-derived splits of the SAME parent compare equal.
        return Communicator(axes, self.context)

    def dup(self) -> "Communicator":
        """MPI_Comm_dup: same group, fresh communication context.

        Returns:
            A clone that hashes/compares distinct from the original, so
            plans and caches keyed on the dup are independent.  Subclass
            state (e.g. a :class:`~repro.core.topology.CartComm`'s
            topology) is preserved.
        """
        return dataclasses.replace(self, context=next(_DUP_CONTEXTS))

    def cart_create(self, dims: Sequence[int],
                    periods: Sequence[bool] | None = None,
                    reorder: bool = False):
        """Attach a Cartesian topology (MPI_Cart_create).

        Args:
            dims: grid extents, one per dimension; ``prod(dims)`` must
                equal :meth:`size` and each dim must factor as a
                consecutive run of this communicator's mesh axes.
            periods: per-dim periodicity (default all False, as in MPI).
            reorder: accepted and ignored (rank order is fixed by the mesh
                under SPMD).
        Returns:
            A :class:`~repro.core.topology.CartComm` over the same group
            with ``cart_coords``/``cart_rank``/``cart_shift``/``cart_sub``
            and the neighborhood collectives.
        Raises:
            ValueError: ill-formed ``dims``/``periods`` or a grid that
                does not factor the mesh axes.
        """
        from repro.core import topology
        return topology.cart_create(dims, periods, reorder, comm=self)

    # -- permutation builders (static, for p2p) -----------------------------
    def ring_perm(self, shift: int = 1) -> list[tuple[int, int]]:
        """Static src→dst pairs of a cyclic shift.

        Args:
            shift: ring displacement (positive = towards higher ranks).
        Returns:
            The full-group pair list for ``sendrecv``/``ppermute`` (the
        periodic special case of
        :meth:`~repro.core.topology.CartComm.cart_shift_perm`).
        """
        n = self.size()
        return [(i, (i + shift) % n) for i in range(n)]

    def pairwise_perm(self, pairs: Sequence[tuple[int, int]],
                      bidirectional: bool = False) -> list[tuple[int, int]]:
        """Validate explicit (src, dst) pairs as a p2p pattern.

        Args:
            pairs: static (src, dst) rank pairs.
            bidirectional: also add every reversed pair.
        Returns:
            The validated pair list.
        Raises:
            ValueError: a rank out of range, or a src/dst repeated (one
                message per rank per ppermute — split into multiple calls).
        """
        n = self.size()
        perm = list(pairs)
        if bidirectional:
            perm += [(d, s) for (s, d) in pairs]
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        for r in srcs + dsts:
            if not (0 <= r < n):
                raise ValueError(f"rank {r} out of range for comm of size {n}")
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            raise ValueError("permutation must be injective (one message per "
                             "rank per ppermute); split into multiple calls")
        return perm

    def neighbor_perm(self, fn: Callable[[int], int | None]) -> list[tuple[int, int]]:
        """Build a permutation from a dest-function evaluated per static rank.

        Args:
            fn: maps each static src rank to its dst rank, or None for "no
                message from this rank".
        Returns:
            The validated (src, dst) pair list.
        Raises:
            ValueError: the resulting pattern is out of range or
                non-injective.
        """
        perm = []
        for src in range(self.size()):
            dst = fn(src)
            if dst is not None:
                perm.append((src, int(dst)))
        return self.pairwise_perm(perm)

    # -- wire hooks (backend dispatch points; underscore = not API surface) --
    def _ppermute(self, payload, perm):
        """Execute one (src, dst) permutation step on this backend's wire.

        The single point every p2p transfer and persistent sendrecv plan
        funnels through: the emulated backend lowers to ``lax.ppermute``
        over the mesh axes; a multiproc communicator overrides this with a
        real inter-process exchange.  Ranks absent from ``perm``'s dst set
        receive zeros (both backends).
        """
        return jax.lax.ppermute(payload, self.axes, perm)

    def _barrier_probe(self, tok):
        """Synchronize the group and return the post-barrier probe value.

        The barrier primitive behind ``barrier``/``ibarrier``/
        ``barrier_init``: emulated = a 1-element psum of the ordering token
        (XLA schedules nothing past it before all ranks contribute);
        multiproc = a wire-level dissemination barrier.
        """
        return jax.lax.psum(tok, self.axes)

    # ======================================================================
    # jmpi 2.0 — every routine as a communicator method.  Lazy imports:
    # collectives/p2p/plans import this module (resolve), so the delegation
    # must bind at call time.
    # ======================================================================

    # -- blocking collectives (v1.0 surface) -------------------------------
    # Shared conventions (documented once): ``x`` is an array/View/bound
    # datatype with static shape; ``datatype=`` packs ``x`` through an
    # explicit derived datatype (repro.core.datatypes); ``token=None``
    # threads the ambient ordering chain and an explicit token is returned
    # back (``(status, value, token)``); ``algorithm`` forces a registry
    # entry by name, else the active policy table chooses at trace time.

    def allreduce(self, x, op: Operator = Operator.SUM, *, token=None,
                  algorithm=None, datatype=None):
        """Reduce ``x`` with ``op`` across the group (MPI_Allreduce).

        Args:
            x: payload array/View.
            op: reduction :class:`Operator` (default SUM).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
        Returns:
            ``(status, value)`` — every rank holds the full reduction.
        """
        from repro.core import collectives as c
        return c.allreduce(x, op, comm=self, token=token, algorithm=algorithm,
                           datatype=datatype)

    def bcast(self, x, root: int = 0, *, token=None, algorithm=None,
              datatype=None):
        """Broadcast ``root``'s value to every rank (MPI_Bcast).

        Args:
            x: payload array/View (contents ignored off-root).
            root: static broadcasting rank.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
        Returns:
            ``(status, value)`` — root's payload on every rank.
        """
        from repro.core import collectives as c
        return c.bcast(x, root, comm=self, token=token, algorithm=algorithm,
                       datatype=datatype)

    def scatter(self, x, root: int = 0, *, token=None, algorithm=None,
                datatype=None):
        """Deal equal axis-0 chunks of ``root``'s buffer (MPI_Scatter).

        Args:
            x: payload whose axis 0 is divisible by the group size.
            root: static scattering rank.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry forced on the underlying bcast.
        Returns:
            ``(status, chunk)`` — rank i's is the i-th chunk.
        Raises:
            ValueError: axis 0 not divisible by the group size.
        """
        from repro.core import collectives as c
        return c.scatter(x, root, comm=self, token=token, algorithm=algorithm,
                         datatype=datatype)

    def gather(self, x, root: int = 0, *, token=None, algorithm=None,
               datatype=None):
        """Concatenate every rank's buffer, valid at ``root`` (MPI_Gather).

        Args:
            x: per-rank payload (identical static shape).
            root: rank at which the result is contractually valid (the
                SPMD lowering materializes it everywhere).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
        Returns:
            ``(status, stacked)`` — axis-0 concatenation in rank order.
        """
        from repro.core import collectives as c
        return c.gather(x, root, comm=self, token=token, algorithm=algorithm,
                        datatype=datatype)

    def allgather(self, x, *, token=None, algorithm=None, datatype=None):
        """Concatenate every rank's buffer on every rank (MPI_Allgather).

        Args:
            x: per-rank payload (identical static shape).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
        Returns:
            ``(status, stacked)`` — axis-0 concatenation in rank order.
        """
        from repro.core import collectives as c
        return c.allgather(x, comm=self, token=token, algorithm=algorithm,
                           datatype=datatype)

    def alltoall(self, x, *, token=None, split_axis: int = 0,
                 concat_axis: int = 0, algorithm=None, datatype=None):
        """Transpose chunks across ranks (MPI_Alltoall).

        Args:
            x: payload whose ``split_axis`` is divisible by the group size.
            token: explicit ordering token; None uses the ambient chain.
            split_axis: axis carved into per-destination chunks.
            concat_axis: axis along which received chunks concatenate.
            algorithm: registry entry to force; None → policy choice.
        Returns:
            ``(status, value)`` — chunk j from every rank, concatenated.
        Raises:
            ValueError: multi-axis communicator or non-divisible payload.
        """
        from repro.core import collectives as c
        return c.alltoall(x, comm=self, token=token, split_axis=split_axis,
                          concat_axis=concat_axis, algorithm=algorithm,
                          datatype=datatype)

    def reduce_scatter(self, x, op: Operator = Operator.SUM, *, token=None,
                       algorithm=None, datatype=None):
        """Reduce then deal axis-0 chunks (MPI_Reduce_scatter_block).

        Args:
            x: payload whose axis 0 is divisible by the group size.
            op: reduction :class:`Operator` (xla_native is SUM-only; other
                operators need an algorithm that declares them).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
        Returns:
            ``(status, chunk)`` — this rank's reduced chunk.
        Raises:
            ValueError: non-divisible payload or an unsupported
                (algorithm, Operator) pair.
        """
        from repro.core import collectives as c
        return c.reduce_scatter(x, op, comm=self, token=token,
                                algorithm=algorithm, datatype=datatype)

    def scatterv(self, x, counts, root: int = 0, *, token=None,
                 algorithm=None, datatype=None):
        """Deal ragged axis-0 chunks of ``root``'s buffer (MPI_Scatterv).

        Args:
            x: root's ``(sum(counts), ...)`` buffer.
            counts: static per-rank row counts (padded-buffer SPMD form).
            root: static scattering rank.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            datatype: optional derived datatype packing ``x``.
        Returns:
            ``(status, chunk)`` — ``(max(counts), ...)`` with this rank's
            ``counts[rank]`` valid rows, zeros beyond.
        Raises:
            ValueError: bad counts or a payload/counts mismatch.
        """
        from repro.core import vcollectives as v
        return v.scatterv(x, counts, root, comm=self, token=token,
                          algorithm=algorithm, datatype=datatype)

    def gatherv(self, x, counts, root: int = 0, *, token=None,
                algorithm=None, datatype=None):
        """Gather ragged per-rank prefixes, valid at ``root`` (MPI_Gatherv).

        Args:
            x: local ``(max(counts), ...)`` padded buffer.
            counts: static per-rank row counts.
            root: rank at which the result is contractually valid.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            datatype: optional derived datatype packing ``x``.
        Returns:
            ``(status, stacked)`` — the ``(sum(counts), ...)``
            concatenation of valid prefixes in rank order.
        Raises:
            ValueError: bad counts or a payload/counts mismatch.
        """
        from repro.core import vcollectives as v
        return v.gatherv(x, counts, root, comm=self, token=token,
                         algorithm=algorithm, datatype=datatype)

    def allgatherv(self, x, counts, *, token=None, algorithm=None,
                   datatype=None):
        """Ragged allgather on every rank (MPI_Allgatherv).

        Args:
            x: local ``(max(counts), ...)`` padded buffer.
            counts: static per-rank row counts.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            datatype: optional derived datatype packing ``x``.
        Returns:
            ``(status, stacked)`` — ``(sum(counts), ...)`` on every rank.
        Raises:
            ValueError: bad counts or a payload/counts mismatch.
        """
        from repro.core import vcollectives as v
        return v.allgatherv(x, counts, comm=self, token=token,
                            algorithm=algorithm, datatype=datatype)

    def alltoallv(self, x, counts, *, token=None, algorithm=None,
                  datatype=None):
        """Ragged all-to-all exchange (MPI_Alltoallv).

        Args:
            x: ``(n, max(counts), ...)`` stacked per-destination slots.
            counts: static n×n matrix ``counts[src][dst]``.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            datatype: optional derived datatype packing ``x``.
        Returns:
            ``(status, out)`` — slot ``s`` holds rank ``s``'s rows for
            this rank (``counts[s][rank]`` valid, zeros beyond).
        Raises:
            ValueError: bad counts matrix or a payload/counts mismatch.
        """
        from repro.core import vcollectives as v
        return v.alltoallv(x, counts, comm=self, token=token,
                           algorithm=algorithm, datatype=datatype)

    def barrier(self, *, token=None):
        """Synchronize the group (MPI_Barrier).

        Args:
            token: explicit ordering token; None uses the ambient chain.
        Returns:
            ``SUCCESS`` — or ``(SUCCESS, token)`` with an explicit token.
            No jmpi op sequenced after the barrier can be scheduled before
            every rank reaches it.
        """
        from repro.core import collectives as c
        return c.barrier(comm=self, token=token)

    # -- nonblocking collectives (MPI-3 i* -> Request) ---------------------
    # Same payload/token/algorithm conventions as the blocking forms; each
    # returns a unified Request (``tag`` recorded for wait-side matching)
    # completed via wait/waitall/waitany/test/testall/testany.

    def iallreduce(self, x, op: Operator = Operator.SUM, *, token=None,
                   algorithm=None, tag: int = 0, datatype=None):
        """Nonblocking :meth:`allreduce` (MPI_Iallreduce).

        Args:
            x: payload array/View.
            op: reduction :class:`Operator` (default SUM).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import collectives as c
        return c.iallreduce(x, op, comm=self, token=token,
                            algorithm=algorithm, tag=tag, datatype=datatype)

    def ibcast(self, x, root: int = 0, *, token=None, algorithm=None,
               tag: int = 0, datatype=None):
        """Nonblocking :meth:`bcast` (MPI_Ibcast).

        Args:
            x: payload array/View (contents ignored off-root).
            root: static broadcasting rank.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import collectives as c
        return c.ibcast(x, root, comm=self, token=token, algorithm=algorithm,
                        tag=tag, datatype=datatype)

    def iscatter(self, x, root: int = 0, *, token=None, algorithm=None,
                 tag: int = 0, datatype=None):
        """Nonblocking :meth:`scatter` (MPI_Iscatter).

        Args:
            x: payload whose axis 0 is divisible by the group size.
            root: static scattering rank.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry forced on the underlying bcast.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request` completing with this rank's chunk.
        """
        from repro.core import collectives as c
        return c.iscatter(x, root, comm=self, token=token,
                          algorithm=algorithm, tag=tag, datatype=datatype)

    def igather(self, x, root: int = 0, *, token=None, algorithm=None,
                tag: int = 0, datatype=None):
        """Nonblocking :meth:`gather` (MPI_Igather).

        Args:
            x: per-rank payload (identical static shape).
            root: rank at which the result is contractually valid.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request` completing with the concatenation.
        """
        from repro.core import collectives as c
        return c.igather(x, root, comm=self, token=token, algorithm=algorithm,
                         tag=tag, datatype=datatype)

    def iallgather(self, x, *, token=None, algorithm=None, tag: int = 0,
                   datatype=None):
        """Nonblocking :meth:`allgather` (MPI_Iallgather).

        Args:
            x: per-rank payload (identical static shape).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request` completing with the concatenation.
        """
        from repro.core import collectives as c
        return c.iallgather(x, comm=self, token=token, algorithm=algorithm,
                            tag=tag, datatype=datatype)

    def ialltoall(self, x, *, token=None, split_axis: int = 0,
                  concat_axis: int = 0, algorithm=None, tag: int = 0,
                  datatype=None):
        """Nonblocking :meth:`alltoall` (MPI_Ialltoall).

        Args:
            x: payload whose ``split_axis`` is divisible by the group size.
            token: explicit ordering token; None uses the ambient chain.
            split_axis: axis carved into per-destination chunks.
            concat_axis: axis along which received chunks concatenate.
            algorithm: registry entry to force; None → policy choice.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import collectives as c
        return c.ialltoall(x, comm=self, token=token, split_axis=split_axis,
                           concat_axis=concat_axis, algorithm=algorithm,
                           tag=tag, datatype=datatype)

    def ireduce_scatter(self, x, op: Operator = Operator.SUM, *, token=None,
                        algorithm=None, tag: int = 0, datatype=None):
        """Nonblocking :meth:`reduce_scatter` (MPI_Ireduce_scatter_block).

        Args:
            x: payload whose axis 0 is divisible by the group size.
            op: reduction :class:`Operator`.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force; None → policy choice.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request` completing with the reduced chunk.
        """
        from repro.core import collectives as c
        return c.ireduce_scatter(x, op, comm=self, token=token,
                                 algorithm=algorithm, tag=tag,
                                 datatype=datatype)

    def iscatterv(self, x, counts, root: int = 0, *, token=None,
                  algorithm=None, tag: int = 0, datatype=None):
        """Nonblocking :meth:`scatterv` (MPI_Iscatterv).

        Args: as :meth:`scatterv`, plus ``tag`` recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import vcollectives as v
        return v.iscatterv(x, counts, root, comm=self, token=token,
                           algorithm=algorithm, tag=tag, datatype=datatype)

    def igatherv(self, x, counts, root: int = 0, *, token=None,
                 algorithm=None, tag: int = 0, datatype=None):
        """Nonblocking :meth:`gatherv` (MPI_Igatherv).

        Args: as :meth:`gatherv`, plus ``tag`` recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import vcollectives as v
        return v.igatherv(x, counts, root, comm=self, token=token,
                          algorithm=algorithm, tag=tag, datatype=datatype)

    def iallgatherv(self, x, counts, *, token=None, algorithm=None,
                    tag: int = 0, datatype=None):
        """Nonblocking :meth:`allgatherv` (MPI_Iallgatherv).

        Args: as :meth:`allgatherv`, plus ``tag`` recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import vcollectives as v
        return v.iallgatherv(x, counts, comm=self, token=token,
                             algorithm=algorithm, tag=tag, datatype=datatype)

    def ialltoallv(self, x, counts, *, token=None, algorithm=None,
                   tag: int = 0, datatype=None):
        """Nonblocking :meth:`alltoallv` (MPI_Ialltoallv).

        Args: as :meth:`alltoallv`, plus ``tag`` recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import vcollectives as v
        return v.ialltoallv(x, counts, comm=self, token=token,
                            algorithm=algorithm, tag=tag, datatype=datatype)

    def ibarrier(self, *, token=None, tag: int = 0):
        """Nonblocking :meth:`barrier` (MPI_Ibarrier).

        Args:
            token: explicit ordering token; None uses the ambient chain.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request` whose completion point is the
            synchronization.
        """
        from repro.core import collectives as c
        return c.ibarrier(comm=self, token=token, tag=tag)

    # -- point-to-point ----------------------------------------------------
    # Static topology (DESIGN.md §2): dest/source are static Python ranks,
    # patterns are full (src, dst) pair lists; one fused ppermute per call.

    def send(self, x, dest: int, *, source: int, tag: int = 0, token=None,
             datatype=None):
        """MPI_Send along a static (source → dest) edge.

        Args:
            x: payload array/View (the matched recv is the same fused
                permute; the paired :meth:`recv` returns the payload).
            dest: static destination rank.
            source: static sending rank (SPMD traces both sides at once).
            tag: message tag (validated at the wait side).
            token: explicit ordering token; None uses the ambient chain.
            datatype: optional derived datatype packing ``x``.
        Returns:
            ``status`` (SUCCESS).
        """
        from repro.core import p2p
        return p2p.send(x, dest, source=source, tag=tag, comm=self,
                        token=token, datatype=datatype)

    def recv(self, x, source: int, *, dest: int, tag: int = 0, token=None,
             datatype=None, recv_into=None):
        """MPI_Recv along a static (source → dest) edge.

        Args:
            x: the send-side value (the fused SPMD permute needs it
                in-trace; ignored on non-source ranks).
            source: static sending rank.
            dest: static receiving rank.
            tag: message tag.
            token: explicit ordering token; None uses the ambient chain.
            datatype: optional derived datatype packing ``x``.
            recv_into: View / bound datatype the received message
                scatters into (ERR_TRUNCATE status when statically too
                small).
        Returns:
            ``(status, payload)`` — the received buffer on ``dest``.
        """
        from repro.core import p2p
        return p2p.recv(x, source, dest=dest, tag=tag, comm=self, token=token,
                        datatype=datatype, recv_into=recv_into)

    def sendrecv(self, x, pairs=None, *, perm=None, dest=None, source=None,
                 tag: int = 0, token=None, datatype=None, recv_into=None):
        """Blocking fused exchange along a static (src → dst) pattern.

        Args:
            x: payload array/View (every listed src sends it).
            pairs/perm: static (src, dst) pair list (aliases).
            dest/source: single-edge shorthand when no pair list is given.
            tag: message tag.
            token: explicit ordering token; None uses the ambient chain.
            datatype: optional derived datatype packing ``x``.
            recv_into: View / bound datatype to scatter the received
                message into (ERR_TRUNCATE status when statically too
                small).
        Returns:
            ``(status, received)`` — plus the token when one was passed.
        Raises:
            ValueError: no pattern given, out-of-range ranks, or a
                non-injective pattern.
        """
        from repro.core import p2p
        return p2p.sendrecv(x, pairs, perm=perm, dest=dest, source=source,
                            tag=tag, comm=self, token=token,
                            datatype=datatype, recv_into=recv_into)

    def isend(self, x, dest: int, *, source: int, tag: int = 0, token=None,
              datatype=None):
        """MPI_Isend: nonblocking :meth:`send`.

        Args: as :meth:`send`.
        Returns:
            ``(status, Request)`` — complete via ``wait*``/``test*``.
        """
        from repro.core import p2p
        return p2p.isend(x, dest, source=source, tag=tag, comm=self,
                         token=token, datatype=datatype)

    def irecv(self, x, source: int, *, dest: int, tag: int = 0, token=None,
              datatype=None, recv_into=None):
        """MPI_Irecv: nonblocking :meth:`recv`.

        Args: as :meth:`recv`.
        Returns:
            ``(status, Request)`` — ``wait(request)`` yields the payload.
        """
        from repro.core import p2p
        return p2p.irecv(x, source, dest=dest, tag=tag, comm=self,
                         token=token, datatype=datatype,
                         recv_into=recv_into)

    def isendrecv(self, x, pairs=None, *, perm=None, dest=None, source=None,
                  tag: int = 0, token=None, datatype=None, recv_into=None):
        """Nonblocking :meth:`sendrecv` (fused MPI_Isend + MPI_Irecv).

        Args: as :meth:`sendrecv`.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        from repro.core import p2p
        return p2p.isendrecv(x, pairs, perm=perm, dest=dest, source=source,
                             tag=tag, comm=self, token=token,
                             datatype=datatype, recv_into=recv_into)

    # -- persistent plans (MPI-4 *_init -> Plan) ---------------------------
    # ``shape_dtype`` is the payload signature (jax.ShapeDtypeStruct, a
    # concrete array, or a (shape, dtype) pair); the registry's algorithm
    # choice is resolved ONCE and frozen into a process-globally cached
    # Plan — ``plan.start(x) -> Request``.

    def allreduce_init(self, shape_dtype, op: Operator = Operator.SUM, *,
                       algorithm=None):
        """Persistent :meth:`allreduce` (MPI_Allreduce_init).

        Args:
            shape_dtype: payload signature the plan is frozen for.
            op: reduction :class:`Operator`.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        """
        from repro.core import plans
        return plans.allreduce_init(shape_dtype, op, comm=self,
                                    algorithm=algorithm)

    def bcast_init(self, shape_dtype, root: int = 0, *, algorithm=None):
        """Persistent :meth:`bcast` (MPI_Bcast_init).

        Args:
            shape_dtype: payload signature the plan is frozen for.
            root: static broadcasting rank.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        """
        from repro.core import plans
        return plans.bcast_init(shape_dtype, root, comm=self,
                                algorithm=algorithm)

    def scatter_init(self, shape_dtype, root: int = 0, *, algorithm=None):
        """Persistent :meth:`scatter` (MPI_Scatter_init).

        Args:
            shape_dtype: full-buffer signature (axis 0 divisible by the
                group size; the per-rank chunk slice is frozen in).
            root: static scattering rank.
            algorithm: registry entry frozen on the underlying bcast.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: axis 0 not divisible by the group size.
        """
        from repro.core import plans
        return plans.scatter_init(shape_dtype, root, comm=self,
                                  algorithm=algorithm)

    def gather_init(self, shape_dtype, root: int = 0, *, algorithm=None):
        """Persistent :meth:`gather` (MPI_Gather_init; allgather lowering,
        valid-at-root contract).

        Args:
            shape_dtype: per-rank payload signature.
            root: rank at which the result is contractually valid.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        """
        from repro.core import plans
        return plans.gather_init(shape_dtype, root, comm=self,
                                 algorithm=algorithm)

    def allgather_init(self, shape_dtype, *, algorithm=None):
        """Persistent :meth:`allgather` (MPI_Allgather_init).

        Args:
            shape_dtype: per-rank payload signature.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        """
        from repro.core import plans
        return plans.allgather_init(shape_dtype, comm=self,
                                    algorithm=algorithm)

    def alltoall_init(self, shape_dtype, *, split_axis: int = 0,
                      concat_axis: int = 0, algorithm=None):
        """Persistent :meth:`alltoall` (MPI_Alltoall_init).

        Args:
            shape_dtype: payload signature (``split_axis`` divisible by
                the group size).
            split_axis: axis carved into per-destination chunks.
            concat_axis: axis along which received chunks concatenate.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: multi-axis communicator or non-divisible payload.
        """
        from repro.core import plans
        return plans.alltoall_init(shape_dtype, comm=self,
                                   split_axis=split_axis,
                                   concat_axis=concat_axis,
                                   algorithm=algorithm)

    def reduce_scatter_init(self, shape_dtype, op: Operator = Operator.SUM,
                            *, algorithm=None):
        """Persistent :meth:`reduce_scatter` (MPI_Reduce_scatter_init).

        Args:
            shape_dtype: payload signature (axis 0 divisible by the group
                size).
            op: reduction :class:`Operator`.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: non-divisible payload.
        """
        from repro.core import plans
        return plans.reduce_scatter_init(shape_dtype, op, comm=self,
                                         algorithm=algorithm)

    def scatterv_init(self, shape_dtype, counts, root: int = 0, *,
                      algorithm=None):
        """Persistent :meth:`scatterv` (MPI_Scatterv_init).

        Args:
            shape_dtype: root's full ``(sum(counts), ...)`` signature.
            counts: static per-rank row counts (frozen into the plan).
            root: static scattering rank.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: bad counts or a signature/counts mismatch.
        """
        from repro.core import plans
        return plans.scatterv_init(shape_dtype, counts, root, comm=self,
                                   algorithm=algorithm)

    def gatherv_init(self, shape_dtype, counts, root: int = 0, *,
                     algorithm=None):
        """Persistent :meth:`gatherv` (MPI_Gatherv_init).

        Args:
            shape_dtype: the local padded ``(max(counts), ...)`` signature.
            counts: static per-rank row counts (frozen into the plan).
            root: rank at which the result is contractually valid.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: bad counts or a signature/counts mismatch.
        """
        from repro.core import plans
        return plans.gatherv_init(shape_dtype, counts, root, comm=self,
                                  algorithm=algorithm)

    def allgatherv_init(self, shape_dtype, counts, *, algorithm=None):
        """Persistent :meth:`allgatherv` (MPI_Allgatherv_init).

        Args:
            shape_dtype: the local padded ``(max(counts), ...)`` signature.
            counts: static per-rank row counts (frozen into the plan).
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: bad counts or a signature/counts mismatch.
        """
        from repro.core import plans
        return plans.allgatherv_init(shape_dtype, counts, comm=self,
                                     algorithm=algorithm)

    def alltoallv_init(self, shape_dtype, counts, *, algorithm=None):
        """Persistent :meth:`alltoallv` (MPI_Alltoallv_init).

        Args:
            shape_dtype: the ``(n, max(counts), ...)`` stacked-slot
                signature.
            counts: static n×n matrix ``counts[src][dst]`` (frozen).
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`Plan`.
        Raises:
            ValueError: bad counts matrix or a signature/counts mismatch.
        """
        from repro.core import plans
        return plans.alltoallv_init(shape_dtype, counts, comm=self,
                                    algorithm=algorithm)

    def barrier_init(self):
        """Persistent :meth:`barrier` (MPI_Barrier_init).

        Returns:
            A cached :class:`Plan` whose ``start()`` takes no payload.
        """
        from repro.core import plans
        return plans.barrier_init(comm=self)

    def sendrecv_init(self, shape_dtype, pairs=None, *, perm=None, dest=None,
                      source=None, recv_into=None):
        """Persistent :meth:`sendrecv` (MPI_Send_init family).

        Args:
            shape_dtype: strip signature the plan is frozen for.
            pairs/perm: static (src, dst) pattern (validated and frozen).
            dest/source: single-edge shorthand.
            recv_into: View / bound datatype the received message scatters
                into at completion (ERR_TRUNCATE status frozen at init
                when statically too small).
        Returns:
            A cached :class:`Plan`; ``start(strip)`` is one token-tied
            ppermute.
        Raises:
            ValueError: missing/invalid pattern.
        """
        from repro.core import plans
        return plans.sendrecv_init(shape_dtype, pairs, perm=perm, dest=dest,
                                   source=source, comm=self,
                                   recv_into=recv_into)


# --------------------------------------------------------------------------
# Ambient "world" — set by ``repro.core.spmd`` so call sites can write
# ``jmpi.rank()`` exactly as in the paper's listings.
# --------------------------------------------------------------------------
_WORLD: list[Communicator | None] = [None]


def set_world(comm: Communicator | None) -> None:
    """Install ``comm`` as the ambient WORLD (None clears it).

    Args:
        comm: the communicator module-level jmpi calls default to; managed
            by :func:`spmd` around each traced body.
    """
    _WORLD[0] = comm


def world() -> Communicator:
    """The ambient WORLD communicator (MPI_COMM_WORLD analogue).

    Returns:
        The communicator installed by the enclosing :func:`spmd` trace.
    Raises:
        RuntimeError: no ambient communicator is installed (call jmpi ops
            inside an spmd-wrapped function, or pass ``comm=`` explicitly).
    """
    if _WORLD[0] is None:
        raise RuntimeError(
            "No ambient communicator: call jmpi ops inside a repro.core.spmd-"
            "wrapped function, or pass comm= explicitly.")
    return _WORLD[0]


_BACKENDS = ("emulated", "multiproc")
_BACKEND = ["emulated"]


def set_backend(name: str) -> None:
    """Select the process-default transport backend (``jmpi.set_backend``).

    ``"emulated"`` (the default) runs every op inside one process over
    shard_map mesh axes; ``"multiproc"`` declares that ops run across real
    host processes — inside a worker spawned by
    :func:`repro.transport.launcher.launch` the bootstrap calls this and
    installs a ``MultiprocComm`` as the ambient WORLD, so the same
    ``comm.allreduce``/plan programs execute over the wire.  Selecting
    ``"multiproc"`` outside a worker only affects default-policy knobs
    (e.g. the bench env fingerprint); communication still needs a
    multiproc communicator.

    Args:
        name: ``"emulated"`` or ``"multiproc"``.
    Raises:
        ValueError: unknown backend name.
    """
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {_BACKENDS}")
    _BACKEND[0] = name


def get_backend() -> str:
    """The process-default transport backend name (see :func:`set_backend`).

    Returns:
        ``"emulated"`` or ``"multiproc"``.
    """
    return _BACKEND[0]


def resolve(comm: Communicator | None) -> Communicator:
    """``comm`` itself, or the ambient :func:`world` when None.

    Args:
        comm: an explicit communicator or None.
    Returns:
        A concrete :class:`Communicator`.
    Raises:
        RuntimeError: ``comm`` is None and no ambient WORLD is installed.
    """
    return comm if comm is not None else world()


def spmd(mesh, in_specs, out_specs, axis_names: tuple[str, ...] | None = None,
         check_vma: bool = False, jit: bool = True):
    """``mpiexec`` analogue: wrap a function in jit(shard_map) + install WORLD.

    Inside the wrapped function, ``jmpi.rank()/size()`` and every collective
    default to a communicator spanning all mesh axes (row-major), and a fresh
    ambient ordering token is installed — mirroring numba-mpi's import-time
    MPI_Init. The whole body is ONE XLA program: compute *and* communication
    JIT-resident, which is the paper's point (``jit=False`` opts into eager
    shard_map — the per-op-dispatch mode, for debugging only; it is the
    moral equivalent of running numba-mpi with NUMBA_DISABLE_JIT).
    """
    def deco(fn):
        names = axis_names if axis_names is not None else tuple(mesh.axis_names)

        def body(*args, **kwargs):
            prev = _WORLD[0]
            set_world(Communicator(names))
            token_lib.reset_ambient()
            try:
                return fn(*args, **kwargs)
            finally:
                set_world(prev)
                token_lib.reset_ambient()

        wrapped = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_vma=check_vma)
        return jax.jit(wrapped) if jit else wrapped

    return deco
