"""Token threading — MPI message-ordering semantics inside XLA programs.

numba-mpi inherits MPI's non-overtaking guarantee from the MPI library itself:
two sends issued by one rank to the same destination are matched in order.
Inside an XLA program nothing stops the compiler from reordering, CSE-ing or
even eliding "identical" collectives, so — following the mpi4jax discipline the
paper cites — every jmpi operation threads an explicit ``Token``.  The token is
a zero-cost (1-element) array data dependency: op N+1 consumes op N's token, so
XLA must schedule them in program order, while *compute* that does not touch
the token is still free to overlap (this is what makes isend/irecv genuinely
non-blocking on TPU: the latency-hiding scheduler hoists the DMA start as early
as its data allows and sinks the wait as late as its consumer allows).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# MPI-style status codes.  Topology errors are trace-time Python exceptions
# (stricter than MPI's runtime codes — see DESIGN.md §2); SUCCESS is what every
# well-formed op returns, keeping the paper's ``status == mpi.SUCCESS`` idiom.
SUCCESS = 0
ERR_TOPOLOGY = 1
ERR_TRUNCATE = 2


def new_token() -> jax.Array:
    """A fresh ordering token (1-element float32, contents irrelevant)."""
    return jnp.zeros((1,), jnp.float32)


def tie(token: jax.Array, *arrays: jax.Array) -> tuple[jax.Array, ...]:
    """Tie ``arrays`` and ``token`` together with an optimization barrier.

    Returns ``(token', *arrays')`` such that XLA can neither reorder the
    arrays' producers after the barrier nor the consumers before it.  This is
    the ``wait`` primitive underneath p2p completion semantics.
    """
    out = jax.lax.optimization_barrier((token, *arrays))
    return out


def advance(token: jax.Array, value: jax.Array) -> jax.Array:
    """Derive the next token from ``value`` so the op cannot be dead-code
    eliminated or reordered w.r.t. later jmpi ops.

    A data dependency is created by folding one scalar element of ``value``
    into the token through an optimization barrier (cost: one scalar add).
    """
    probe = jnp.real(value.ravel()[0]).astype(jnp.float32) * 0.0
    token, probe = jax.lax.optimization_barrier((token, probe))
    return token + probe


@dataclasses.dataclass
class TokenContext:
    """Implicit token threading for user convenience.

    numba-mpi has no visible token (MPI orders messages internally).  To keep
    call sites close to the paper's listings (``mpi.allreduce(part, pi)``),
    ops default to an ambient per-trace token managed here; power users pass
    and receive tokens explicitly for precise overlap control.
    """

    token: Any = None

    def get(self) -> jax.Array:
        if self.token is None:
            self.token = new_token()
        return self.token

    def set(self, token: jax.Array) -> None:
        self.token = token


# Ambient context: fine because a single trace is single-threaded; shard_map
# re-traces per call so contexts do not leak across programs.
_AMBIENT = TokenContext()


def ambient() -> TokenContext:
    """The process-global ambient token context jmpi ops default to.

    Returns:
        The live :class:`TokenContext` (per-trace; reset by ``spmd``).
    """
    return _AMBIENT


def reset_ambient() -> None:
    """Start a fresh ambient token (call at the top of each traced program)."""
    _AMBIENT.token = None
