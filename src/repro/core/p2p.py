"""Point-to-point messaging: send/recv/sendrecv, isend/irecv, wait/test.

SPMD adaptation (DESIGN.md §2, "static topology"): in MPI each rank runs its
own control flow and may compute ``dest``/``source`` at run time; under XLA
SPMD every device traces the *same* program and the communication pattern must
be static.  A jmpi point-to-point call therefore carries the full (src, dst)
pair list — one ``lax.ppermute`` — instead of per-rank branches.  The paper's
Listing 5 (rank 0 ⇄ rank 1 exchange with isend/irecv + waitall) maps to::

    reqs = jmpi.isendrecv(src_data, pairs=[(0, 1), (1, 0)], tag=11)
    status, dst_data = jmpi.wait(reqs)

Same wire traffic, same non-blocking semantics (XLA's latency-hiding scheduler
starts the DMA as soon as ``src_data`` is ready and only forces completion at
the ``wait`` consumption point), checked at trace time instead of run time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import datatypes as datatypes_lib
from repro.core import token as token_lib
from repro.core.comm import Communicator, resolve
from repro.core.token import ERR_TRUNCATE, SUCCESS

#: Wildcard for :func:`wait`'s ``tag=`` filter (MPI_ANY_TAG analogue).
ANY_TAG = -1


@dataclasses.dataclass
class Request:
    """Handle to an in-flight non-blocking operation (MPI_Request analogue).

    Holds the in-flight value and its ordering token.  ``wait`` is the
    dataflow point where the value becomes consumable; until then XLA is free
    to overlap independent compute with the transfer.  ``used_ambient``
    records whether the op drew its token from the ambient chain — explicit-
    token requests never touch ambient state (tokens created inside lax
    control-flow scopes must not leak to outer traces).  ``status`` is
    SUCCESS unless the receive buffer was statically too small for the
    message (ERR_TRUNCATE, detected at trace time from the static shapes).
    """

    value: Any
    token: jax.Array
    tag: int = 0
    recv: Any = None  # receive adapter (View / bound datatype) to scatter into
    used_ambient: bool = True
    status: int = SUCCESS
    #: Host-synchronous request (persistent-channel plan): the value is
    #: already materialized when the request is created, so completion
    #: skips the token tie — there is no in-flight XLA op to order.
    host: bool = False

    def _materialize(self):
        if self.host:
            token, value = self.token, self.value
        else:
            token, value = token_lib.tie(self.token, self.value)
        if self.recv is not None:
            value = self.recv.scatter_into(value)
        return token, value


_payload = datatypes_lib.pack_payload


def _resolve_perm(comm: Communicator, pairs=None, perm=None, dest=None,
                  source=None) -> list[tuple[int, int]]:
    if perm is not None:
        return comm.pairwise_perm(perm)
    if pairs is not None:
        return comm.pairwise_perm(pairs)
    if dest is None or source is None:
        raise ValueError("p2p needs pairs=/perm= or both source= and dest= "
                         "(static ranks; see DESIGN.md §2 static topology)")
    return comm.pairwise_perm([(int(source), int(dest))])


# ---------------------------------------------------------------------------
# Non-blocking primitives (the blocking forms are wait-wrapped versions).
# ---------------------------------------------------------------------------

def isendrecv(x, pairs=None, *, perm=None, dest=None, source=None, tag: int = 0,
              comm: Communicator | None = None, token=None,
              datatype=None, recv_into=None) -> Request:
    """Start a non-blocking exchange along a static (src→dst) pattern.

    Fuses MPI_Isend + MPI_Irecv: each listed src sends, each listed dst
    receives; ranks absent from the pattern receive zeros (discardable).

    Payloads are ``(x, datatype)`` uniform: ``datatype=`` packs ``x``
    through an explicit :class:`~repro.core.datatypes.Datatype`, or ``x``
    may be a View / ``dt.bind(buf)`` value packing itself.  ``recv_into``
    is the receive-side counterpart — a View, a bound datatype, or a
    fully-covering datatype — whose layout the received message scatters
    into at completion (ERR_TRUNCATE status when statically too small).
    """
    comm = resolve(comm)
    tok = token if token is not None else token_lib.ambient().get()
    payload = _payload(x, datatype)
    recv = datatypes_lib.recv_adapter(recv_into)
    p = _resolve_perm(comm, pairs, perm, dest, source)
    status = SUCCESS
    rcount = datatypes_lib.adapter_count(recv)
    if rcount is not None and rcount < payload.size:
        # Message statically larger than the receive layout: MPI_ERR_TRUNCATE.
        # The transfer still happens (shapes are static under SPMD); the
        # receive layout keeps the leading elements and the status reports it.
        status = ERR_TRUNCATE
    # Token-tie the payload so this ppermute cannot be hoisted over earlier
    # jmpi ops (MPI non-overtaking order), then transfer.
    tok, payload = token_lib.tie(tok, payload)
    out = comm._ppermute(payload, p)
    new_tok = token_lib.advance(tok, out)
    if token is None:
        token_lib.ambient().set(new_tok)
    return Request(value=out, token=new_tok, tag=tag, recv=recv,
                   used_ambient=token is None, status=status)


def isend(x, dest: int, *, source: int, tag: int = 0,
          comm: Communicator | None = None, token=None,
          datatype=None) -> tuple[int, Request]:
    """MPI_Isend analogue (static source & dest ranks). Returns (status, req)."""
    req = isendrecv(x, dest=dest, source=source, tag=tag, comm=comm,
                    token=token, datatype=datatype)
    return SUCCESS, req


def irecv(x, source: int, *, dest: int, tag: int = 0,
          comm: Communicator | None = None, token=None, datatype=None,
          recv_into=None) -> tuple[int, Request]:
    """MPI_Irecv analogue: (status, request); wait(request) -> payload.

    Under SPMD the matching isend *is* the transfer (one fused permute), so
    irecv issues that permute with ``x`` as the send-side value; on the
    ``dest`` rank the waited value is the received buffer.  ``recv_into``
    scatters the message through a View/bound-datatype layout.  Prefer
    :func:`isendrecv` for new code (documented in README).
    """
    req = isendrecv(x, dest=dest, source=source, tag=tag, comm=comm,
                    token=token, datatype=datatype, recv_into=recv_into)
    return SUCCESS, req


def _check_tag(req: Request, tag: int) -> None:
    if tag != ANY_TAG and tag != req.tag:
        # MPI would leave the recv unmatched (deadlock); our static-topology
        # discipline surfaces the mismatch at trace time instead.
        raise ValueError(f"tag mismatch: waiting for tag {tag} on a request "
                         f"posted with tag {req.tag} (use ANY_TAG to ignore)")


def wait(req: Request, tag: int = ANY_TAG):
    """Complete a request: (status, value). Forces the dataflow dependency.

    ``tag``: assert the request was posted with this tag (MPI tag matching;
    mismatch is a trace-time error, see DESIGN.md §2 static topology).
    Status is the request's — ERR_TRUNCATE when the receive view was
    statically smaller than the message.
    """
    _check_tag(req, tag)
    token, value = req._materialize()
    if req.used_ambient:
        token_lib.ambient().set(token)
    return req.status, value


def waitall(reqs: Sequence[Request], tag: int = ANY_TAG):
    """Complete all requests: (status, [values]).  Status is SUCCESS only if
    every request succeeded (first error code otherwise, MPI_Waitall-style).

    ``tag``: assert every request was posted with this tag (default ANY_TAG)
    — same trace-time validation as :func:`wait`/:func:`waitany`.  Requests
    may mix p2p and nonblocking-collective origins (one unified Request
    model); completion materializes each in issue order.
    """
    for r in reqs:
        _check_tag(r, tag)
    out = [r._materialize() for r in reqs]
    toks = [t for t, _ in out]
    vals = [v for _, v in out]
    if toks and all(r.used_ambient for r in reqs):
        if all(r.host for r in reqs):
            token_lib.ambient().set(toks[-1])  # host tokens pass through
        else:
            token_lib.ambient().set(sum(toks) / len(toks))
    status = next((r.status for r in reqs if r.status != SUCCESS), SUCCESS)
    return status, vals


def waitany(reqs: Sequence[Request], tag: int = ANY_TAG):
    """Complete one request: (status, index, value).

    Ordering guarantee: XLA dataflow has no runtime completion order, so
    'any' deterministically completes the FIRST (lowest-index, i.e. earliest
    issued) request — index 0 always.  Later requests stay pending and can
    be waited on afterwards; their tokens are untouched, so issue order is
    preserved (MPI non-overtaking).
    """
    status, value = wait(reqs[0], tag=tag)
    return status, 0, value


def test(req: Request, tag: int = ANY_TAG):
    """(status, flag, value). Under XLA dataflow a value is by construction
    available at its consumption point, so flag is statically True; the call
    still forces ordering exactly like wait (semantics note in DESIGN.md §2).
    """
    status, value = wait(req, tag=tag)
    return status, jnp.bool_(True), value


def testall(reqs: Sequence[Request], tag: int = ANY_TAG):
    """(status, flag, values) — :func:`waitall` with the statically-True flag
    of :func:`test`; ``tag`` filters like every other completion call
    (ANY_TAG default, trace-time mismatch error otherwise)."""
    status, values = waitall(reqs, tag=tag)
    return status, jnp.bool_(True), values


def testany(reqs: Sequence[Request], tag: int = ANY_TAG):
    """(status, flag, index, value) — same deterministic first-request
    ordering as :func:`waitany`, with the statically-True flag of
    :func:`test`."""
    status, idx, value = waitany(reqs, tag=tag)
    return status, jnp.bool_(True), idx, value


# ---------------------------------------------------------------------------
# Blocking forms
# ---------------------------------------------------------------------------

def sendrecv(x, pairs=None, *, perm=None, dest=None, source=None, tag: int = 0,
             comm: Communicator | None = None, token=None,
             datatype=None, recv_into=None):
    """Blocking exchange: (status, received) — or (status, received, token)
    when an explicit token is passed (control-flow-safe form).  Payloads
    and receive targets are datatype-uniform (see :func:`isendrecv`)."""
    req = isendrecv(x, pairs=pairs, perm=perm, dest=dest, source=source,
                    tag=tag, comm=comm, token=token, datatype=datatype,
                    recv_into=recv_into)
    status, value = wait(req)
    if token is not None:
        return status, value, req.token
    return status, value


def send(x, dest: int, *, source: int, tag: int = 0,
         comm: Communicator | None = None, token=None, datatype=None) -> int:
    """MPI_Send analogue (static ranks). The matched recv is the same fused
    permute — use the return of the paired :func:`recv` for the payload.
    ``datatype=`` packs ``x`` through an explicit derived datatype."""
    status, _ = sendrecv(x, dest=dest, source=source, tag=tag, comm=comm,
                         token=token, datatype=datatype)
    return status


def recv(x, source: int, *, dest: int, tag: int = 0,
         comm: Communicator | None = None, token=None, datatype=None,
         recv_into=None):
    """MPI_Recv analogue: (status, payload). ``x`` is the send-side value (the
    fused SPMD permute needs it in-trace; on non-source ranks its contents are
    ignored).  ``recv_into`` scatters the message through a View/bound
    datatype layout."""
    return sendrecv(x, dest=dest, source=source, tag=tag, comm=comm,
                    token=token, datatype=datatype, recv_into=recv_into)
