"""JAX version compatibility shims.

The repo targets the modern JAX surface (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types=...``); CI and the dev container pin jax 0.4.37 where those
live under ``jax.experimental.shard_map`` / have no ``axis_types`` kwarg and
``jax.sharding.AxisType`` does not exist yet.  Every call site goes through
this module so the rest of the codebase reads like current JAX and upgrades
are a one-file change.
"""

from __future__ import annotations

import inspect

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new JAX; ``jax.experimental.shard_map`` on 0.4.x.

    ``check_vma`` maps to the old API's ``check_rep`` (same meaning: verify
    per-axis replication claims; our jmpi collectives manage replication
    manually, so callers pass False).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the rename: modern JAX spells it
    ``pltpu.CompilerParams``, 0.4.x ``pltpu.TPUCompilerParams`` (same
    fields — dimension_semantics, has_side_effects, ...)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types when supported.

    Old JAX (< AxisType) has implicit-auto axes only, which is exactly what
    every caller here wants, so the kwarg is simply dropped there.
    """
    if hasattr(jax.sharding, "AxisType"):
        kwargs = {}
        if "axis_types" in inspect.signature(jax.make_mesh).parameters:
            kwargs["axis_types"] = (
                (jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
