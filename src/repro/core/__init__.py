"""repro.core — "jmpi": JIT-resident message passing for JAX/TPU.

TPU-native reproduction of numba-mpi v1.0 (DESIGN.md §1–2): the full v1.0 API
surface — size/rank, [i]send/[i]recv, wait[all|any], test[all|any], allreduce,
bcast, barrier, scatter/[all]gather, wtime — usable *inside* jit/shard_map
programs so compute and communication live in one XLA executable, plus the
beyond-paper features (non-default communicators, alltoall/reduce_scatter,
ring schedules, compressed allreduce) recorded in DESIGN.md §7.

Typical use (paper Listing 3 analogue)::

    import repro.core as jmpi

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P())
    def pi_step(intervals):
        part = get_pi_part(intervals, jmpi.rank(), jmpi.size())
        status, pi = jmpi.allreduce(part)
        return pi

Collective algorithm registry
-----------------------------
Each logical collective has multiple registered lowerings (``xla_native``,
``ring``, ``recursive_doubling``, ``tree``, ``pairwise``, ``bf16_wire``);
the active :class:`repro.core.registry.PolicyTable` picks one per call from
the payload bytes and group size, **at trace time**.  Control points::

    jmpi.allreduce(x, algorithm="ring")          # force per call
    jmpi.set_algorithm("allreduce", "ring")      # force per process
    with jmpi.algorithm_override(bcast="tree"):  # force per scope
        ...
    jmpi.load_policy("experiments/collective_policy.json")  # tuned table

Regenerate the tuned table with ``python -m repro.launch.hillclimb
--tune-collectives`` or inspect crossovers with
``python benchmarks/bench_collectives.py --sweep-algorithms``.
"""

import time as _time

import jax as _jax

from repro.core import registry
from repro.core import schedules as _schedules  # registers rd/tree/pairwise
from repro.core.collectives import (Operator, allgather, allreduce, alltoall,
                                    barrier, bcast, gather, reduce_scatter,
                                    scatter)
from repro.core.comm import Communicator, resolve, set_world, spmd, world
from repro.core.compression import (CompressionState, compressed_allreduce,
                                    init_state, wire_bytes_per_rank)
from repro.core.hostbridge import HostBridge
from repro.core.p2p import (ANY_TAG, Request, irecv, isend, isendrecv, recv,
                            send, sendrecv, test, testall, testany, wait,
                            waitall, waitany)
from repro.core.registry import (PolicyRule, PolicyTable, algorithm_override,
                                 algorithms, clear_algorithms, load_policy,
                                 save_policy, set_algorithm, set_policy)
from repro.core.ring import ring_allgather, ring_allreduce
from repro.core.token import (ERR_TOPOLOGY, ERR_TRUNCATE, SUCCESS, TokenContext,
                              ambient, new_token, reset_ambient, tie)
from repro.core.views import View


def initialized() -> bool:
    """numba-mpi ``initialized()`` analogue: the JAX backend is live."""
    try:
        return len(_jax.devices()) > 0
    except RuntimeError:
        return False


def rank(comm: Communicator | None = None):
    """Rank within ``comm`` (ambient WORLD by default). Traced int32."""
    return resolve(comm).rank()


def size(comm: Communicator | None = None) -> int:
    """Group size. Static Python int (usable for loop bounds, ring schedules)."""
    return resolve(comm).size()


def wtime() -> float:
    """Host wall-clock (MPI_Wtime analogue). Host-only: inside a traced
    program there is no clock — use step-level timing hooks instead."""
    return _time.perf_counter()


RequestType = Request  # paper spells it mpi.RequestType in Listing 5

__all__ = [
    "Operator", "Communicator", "Request", "RequestType", "View",
    "HostBridge", "CompressionState", "TokenContext",
    "SUCCESS", "ERR_TOPOLOGY", "ERR_TRUNCATE", "ANY_TAG",
    "allgather", "allreduce", "alltoall", "barrier", "bcast", "gather",
    "reduce_scatter", "scatter", "sendrecv", "send", "recv", "isend", "irecv",
    "isendrecv", "wait", "waitall", "waitany", "test", "testall", "testany",
    "ring_allreduce", "ring_allgather", "compressed_allreduce", "init_state",
    "wire_bytes_per_rank", "spmd", "world", "set_world", "resolve",
    "ambient", "new_token", "reset_ambient", "tie",
    "initialized", "rank", "size", "wtime",
    "registry", "PolicyRule", "PolicyTable", "algorithms", "set_algorithm",
    "clear_algorithms", "algorithm_override", "set_policy", "load_policy",
    "save_policy",
]
