"""repro.core — "jmpi": JIT-resident message passing for JAX/TPU.

TPU-native reproduction of numba-mpi v1.0 (DESIGN.md §1–2): the full v1.0 API
surface — size/rank, [i]send/[i]recv, wait[all|any], test[all|any], allreduce,
bcast, barrier, scatter/[all]gather, wtime — usable *inside* jit/shard_map
programs so compute and communication live in one XLA executable, plus the
beyond-paper features (non-default communicators, alltoall/reduce_scatter,
ring schedules, compressed allreduce) recorded in DESIGN.md §7.

jmpi 2.0 — communicator-centric API
-----------------------------------
The :class:`Communicator` is the center of the API: every routine is a
method (``comm.allreduce``, ``comm.isend``, ``comm.dup()``, ``comm.split()``),
and the module-level functions below are thin wrappers that resolve the
ambient WORLD and delegate — every v1.0 call site keeps working.

Migration table (module function → communicator method)::

    jmpi.rank() / jmpi.size()       comm.rank() / comm.size()
    jmpi.allreduce(x, op)           comm.allreduce(x, op)
    jmpi.bcast(x, root)             comm.bcast(x, root)
    jmpi.scatter / gather           comm.scatter / comm.gather
    jmpi.allgather / alltoall       comm.allgather / comm.alltoall
    jmpi.reduce_scatter             comm.reduce_scatter
    jmpi.barrier()                  comm.barrier()
    jmpi.[i]send / [i]recv          comm.[i]send / comm.[i]recv
    jmpi.[i]sendrecv                comm.[i]sendrecv
    (new, MPI-3)                    comm.iallreduce/ibcast/iscatter/igather/
                                    iallgather/ialltoall/ireduce_scatter/
                                    ibarrier  -> Request
    (new, MPI-4)                    comm.<collective>_init(...) -> Plan;
                                    comm.sendrecv_init(...)    -> Plan
    (new, topology)                 comm.cart_create(dims, periods) -> CartComm
                                    with cart_coords/cart_rank/cart_shift/
                                    cart_sub and the MPI-3 neighborhood
                                    collectives neighbor_allgather /
                                    neighbor_alltoall[v] (+ i*/_init forms)
    (new, v-variants)               comm.scatterv/gatherv/allgatherv/
                                    alltoallv with static counts
                                    (+ i*/_init forms)
    (new, datatypes)                jmpi.contiguous/vector/subarray/indexed/
                                    slots/pytree — MPI derived-datatype
                                    algebra; every op accepts
                                    (payload, datatype) or dt.bind(buf)

The complete reference table lives in docs/API.md; the layer diagram and
dispatch walkthrough in docs/ARCHITECTURE.md; the paper-feature coverage
map in docs/PAPER_MAP.md.

Nonblocking collectives return the SAME ``Request`` type as isend/irecv, so
mixed p2p + collective request lists complete through one unified
``wait``/``waitall``/``waitany``/``test``/``testall``/``testany``.

Persistent plans (paper Listing-3 analogue, 2.0 style)::

    import repro.core as jmpi

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P())
    def pi_step(intervals):
        comm = jmpi.world()
        part = get_pi_part(intervals, comm.rank(), comm.size())
        plan = comm.allreduce_init(                 # algorithm frozen ONCE,
            jax.ShapeDtypeStruct(part.shape, part.dtype))  # plan cached
        status, pi = jmpi.wait(plan.start(part))    # re-startable per step
        return pi

``plan.start(x)`` skips the per-call registry/policy dispatch (the choice is
frozen at init) and the process-global plan cache returns the same Plan on
re-trace — see ``benchmarks/bench_collectives.py --persistent`` and
:func:`plan_cache_stats`.

Collective algorithm registry
-----------------------------
Each logical collective has multiple registered lowerings (``xla_native``,
``ring``, ``recursive_doubling``, ``tree``, ``pairwise``, ``bf16_wire``);
the active :class:`repro.core.registry.PolicyTable` picks one per call from
the payload bytes and group size, **at trace time**.  Control points::

    jmpi.allreduce(x, algorithm="ring")          # force per call
    jmpi.set_algorithm("allreduce", "ring")      # force per process
    with jmpi.algorithm_override(bcast="tree"):  # force per scope
        ...
    jmpi.load_policy("experiments/collective_policy.json")  # tuned table

An (algorithm, Operator) pair the lowering cannot honor raises a uniform
trace-time ``ValueError`` naming both — never a silent fallback to a wrong
reduction.  Regenerate the tuned table with ``python -m
repro.launch.hillclimb --tune-collectives`` or inspect crossovers with
``python benchmarks/bench_collectives.py --sweep-algorithms``.
"""

import time as _time

import jax as _jax

from repro.core import registry
from repro.core import schedules as _schedules

_ = _schedules  # imported for its side effect: registers rd/tree/pairwise
from repro.core.collectives import (Operator, allgather, allreduce, alltoall,
                                    barrier, bcast, gather, iallgather,
                                    iallreduce, ialltoall, ibarrier, ibcast,
                                    igather, ireduce_scatter, iscatter,
                                    reduce_scatter, scatter)
from repro.core.comm import (Communicator, get_backend, resolve, set_backend,
                             set_world, spmd, world)
from repro.core.compression import (CompressionState, compressed_allreduce,
                                    compressed_reduce_scatter,
                                    icompressed_allreduce, init_state,
                                    wire_bytes_per_rank)
from repro.core import datatypes
from repro.core.datatypes import (Datatype, contiguous, face, indexed,
                                  pytree, slots, subarray, vector)
from repro.core.hostbridge import HostBridge
from repro.core.p2p import (ANY_TAG, Request, irecv, isend, isendrecv, recv,
                            send, sendrecv, test, testall, testany, wait,
                            waitall, waitany)
from repro.core.plans import (Plan, allgather_init, allgatherv_init,
                              allreduce_init, alltoall_init, alltoallv_init,
                              barrier_init, bcast_init, gather_init,
                              gatherv_init, neighbor_allgather_init,
                              neighbor_alltoall_init, neighbor_alltoallv_init,
                              plan_cache_clear, plan_cache_stats,
                              reduce_scatter_init, scatter_init,
                              scatterv_init, sendrecv_init)
from repro.core.vcollectives import (allgatherv, alltoallv, gatherv,
                                     iallgatherv, ialltoallv, igatherv,
                                     iscatterv, scatterv)
from repro.core.registry import (PolicyRule, PolicyTable, algorithm_override,
                                 algorithms, clear_algorithms, load_policy,
                                 save_policy, set_algorithm, set_policy)
# topology also registers the neighbor_* lowerings + hierarchical allreduce
from repro.core.topology import (PROC_NULL, CartComm, cart_create,
                                 ineighbor_allgather, ineighbor_alltoall,
                                 ineighbor_alltoallv, neighbor_allgather,
                                 neighbor_alltoall, neighbor_alltoallv)
from repro.core.ring import ring_allgather, ring_allreduce
from repro.core.token import (ERR_TOPOLOGY, ERR_TRUNCATE, SUCCESS, TokenContext,
                              ambient, new_token, reset_ambient, tie)
from repro.core.views import View


def initialized() -> bool:
    """numba-mpi ``initialized()`` analogue: the JAX backend is live."""
    try:
        return len(_jax.devices()) > 0
    except RuntimeError:
        return False


def rank(comm: Communicator | None = None):
    """Rank within ``comm`` (ambient WORLD by default). Traced int32."""
    return resolve(comm).rank()


def size(comm: Communicator | None = None) -> int:
    """Group size. Static Python int (usable for loop bounds, ring schedules)."""
    return resolve(comm).size()


def wtime() -> float:
    """Host wall-clock (MPI_Wtime analogue). Host-only: inside a traced
    program there is no clock — use step-level timing hooks instead."""
    return _time.perf_counter()


RequestType = Request  # paper spells it mpi.RequestType in Listing 5

__all__ = [
    "Operator", "Communicator", "CartComm", "Request", "RequestType", "View",
    "Plan", "HostBridge", "CompressionState", "TokenContext",
    "SUCCESS", "ERR_TOPOLOGY", "ERR_TRUNCATE", "ANY_TAG", "PROC_NULL",
    "allgather", "allreduce", "alltoall", "barrier", "bcast", "gather",
    "reduce_scatter", "scatter",
    "scatterv", "gatherv", "allgatherv", "alltoallv",
    "iallgather", "iallreduce", "ialltoall", "ibarrier", "ibcast", "igather",
    "ireduce_scatter", "iscatter",
    "iscatterv", "igatherv", "iallgatherv", "ialltoallv",
    "cart_create", "neighbor_allgather", "neighbor_alltoall",
    "neighbor_alltoallv", "ineighbor_allgather", "ineighbor_alltoall",
    "ineighbor_alltoallv",
    "allgather_init", "allreduce_init", "alltoall_init", "barrier_init",
    "bcast_init", "gather_init", "reduce_scatter_init", "scatter_init",
    "scatterv_init", "gatherv_init", "allgatherv_init", "alltoallv_init",
    "sendrecv_init", "neighbor_allgather_init", "neighbor_alltoall_init",
    "neighbor_alltoallv_init", "plan_cache_stats", "plan_cache_clear",
    "datatypes", "Datatype", "contiguous", "vector", "subarray", "indexed",
    "face", "slots", "pytree",
    "sendrecv", "send", "recv", "isend", "irecv",
    "isendrecv", "wait", "waitall", "waitany", "test", "testall", "testany",
    "ring_allreduce", "ring_allgather", "compressed_allreduce",
    "icompressed_allreduce", "compressed_reduce_scatter", "init_state",
    "wire_bytes_per_rank", "spmd", "world", "set_world", "resolve",
    "set_backend", "get_backend",
    "ambient", "new_token", "reset_ambient", "tie",
    "initialized", "rank", "size", "wtime",
    "registry", "PolicyRule", "PolicyTable", "algorithms", "set_algorithm",
    "clear_algorithms", "algorithm_override", "set_policy", "load_policy",
    "save_policy",
]
