"""Pure-numpy oracles for every jmpi collective.

The debugging analogue of numba-mpi's JIT-disabled ``py_func`` path: each
function takes the *global* list of per-rank payloads and returns the list of
per-rank results, simulating what the MPI library would do.  Property tests
drive the jmpi ops (under shard_map on emulated devices) and these oracles
with the same inputs and assert equality.
"""

from __future__ import annotations

import numpy as np


def allreduce(shards, op="sum"):
    stack = np.stack(shards)
    red = {
        "sum": lambda s: s.sum(0),
        "prod": lambda s: s.prod(0),
        "min": lambda s: s.min(0),
        "max": lambda s: s.max(0),
        "land": lambda s: (s != 0).all(0).astype(shards[0].dtype),
        "lor": lambda s: (s != 0).any(0).astype(shards[0].dtype),
    }[op](stack)
    return [red.copy() for _ in shards]


def bcast(shards, root=0):
    return [shards[root].copy() for _ in shards]


def scatter(shards, root=0):
    chunks = np.split(shards[root], len(shards), axis=0)
    return [c.copy() for c in chunks]


def gather(shards, root=0):
    full = np.concatenate(shards, axis=0)
    return [full.copy() for _ in shards]  # SPMD lowering: valid-at-root contract


def allgather(shards):
    full = np.concatenate(shards, axis=0)
    return [full.copy() for _ in shards]


def alltoall(shards):
    n = len(shards)
    out = []
    for j in range(n):
        pieces = [np.split(shards[i], n, axis=0)[j] for i in range(n)]
        out.append(np.concatenate(pieces, axis=0))
    return out


def reduce_scatter(shards):
    n = len(shards)
    total = np.stack(shards).sum(0)
    return [c.copy() for c in np.split(total, n, axis=0)]


def ppermute(shards, perm):
    n = len(shards)
    out = [np.zeros_like(shards[0]) for _ in range(n)]
    for src, dst in perm:
        out[dst] = shards[src].copy()
    return out


# ---------------------------------------------------------------------------
# Cartesian-topology oracles: independent coordinate math (no shared helpers
# with repro.core.topology), list-of-per-rank-payloads in, list out.
# ---------------------------------------------------------------------------

def _cart_neighbors(rank, dims, periods):
    """2·ndims neighbour ranks of ``rank`` in MPI-3 slot order (None where a
    non-periodic boundary has no neighbour)."""
    strides = []
    acc = 1
    for d in reversed(dims):
        strides.append(acc)
        acc *= d
    strides = list(reversed(strides))
    coords = [(rank // s) % d for s, d in zip(strides, dims)]
    out = []
    for d in range(len(dims)):
        for disp in (-1, +1):
            c = coords[d] + disp
            if periods[d]:
                c %= dims[d]
            elif not 0 <= c < dims[d]:
                out.append(None)
                continue
            out.append(rank + (c - coords[d]) * strides[d])
    return out


def neighbor_allgather(shards, dims, periods):
    """Per rank: stack of the 2·ndims neighbours' payloads (zeros at null
    neighbours), MPI-3 slot order."""
    out = []
    for r in range(len(shards)):
        slots = [np.zeros_like(shards[0]) if nb is None else shards[nb].copy()
                 for nb in _cart_neighbors(r, dims, periods)]
        out.append(np.stack(slots))
    return out


def neighbor_alltoall(shards, dims, periods):
    """Per rank: slot k holds what neighbour k sent *to this rank* — i.e.
    the neighbour's mirror slot (its +1 slot for our −1 neighbour and vice
    versa); zeros at null neighbours."""
    out = []
    for r in range(len(shards)):
        slots = []
        for k, nb in enumerate(_cart_neighbors(r, dims, periods)):
            mirror = k + 1 if k % 2 == 0 else k - 1
            slots.append(np.zeros_like(shards[0][0]) if nb is None
                         else shards[nb][mirror].copy())
        out.append(np.stack(slots))
    return out


# ---------------------------------------------------------------------------
# v-variant oracles (padded-buffer SPMD semantics, see repro.core.vcollectives)
# ---------------------------------------------------------------------------

def scatterv(shards, counts, root=0):
    """Per rank: (max(counts), ...) padded chunk — counts[r] valid leading
    rows of root's buffer at the rank's static offset, zeros beyond."""
    maxc = max(counts) if counts else 0
    offs = np.concatenate([[0], np.cumsum(counts)]).astype(int)
    buf = np.asarray(shards[root])
    out = []
    for r, c in enumerate(counts):
        chunk = np.zeros((maxc,) + buf.shape[1:], buf.dtype)
        chunk[:c] = buf[offs[r]:offs[r] + c]
        out.append(chunk)
    return out


def gatherv(shards, counts, root=0):
    """Per rank: the (sum(counts), ...) concatenation of every rank's valid
    prefix (SPMD lowering materializes it everywhere; valid-at-root
    contract)."""
    full = np.concatenate([np.asarray(shards[r])[:c]
                           for r, c in enumerate(counts)], axis=0)
    return [full.copy() for _ in shards]


def allgatherv(shards, counts):
    """Per rank: the (sum(counts), ...) concatenation, valid everywhere."""
    return gatherv(shards, counts)


def alltoallv(shards, counts):
    """Per rank r: (n, max, ...) stack — slot s holds counts[s][r] valid
    rows of rank s's slot-r send buffer, zeros beyond."""
    n = len(shards)
    maxc = max((c for row in counts for c in row), default=0)
    out = []
    for r in range(n):
        slots = np.zeros((n, maxc) + np.asarray(shards[0]).shape[2:],
                         np.asarray(shards[0]).dtype)
        for s in range(n):
            c = counts[s][r]
            slots[s, :c] = np.asarray(shards[s])[r, :c]
        out.append(slots)
    return out
