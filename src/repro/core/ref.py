"""Pure-numpy oracles for every jmpi collective.

The debugging analogue of numba-mpi's JIT-disabled ``py_func`` path: each
function takes the *global* list of per-rank payloads and returns the list of
per-rank results, simulating what the MPI library would do.  Property tests
drive the jmpi ops (under shard_map on emulated devices) and these oracles
with the same inputs and assert equality.
"""

from __future__ import annotations

import numpy as np


def allreduce(shards, op="sum"):
    stack = np.stack(shards)
    red = {
        "sum": lambda s: s.sum(0),
        "prod": lambda s: s.prod(0),
        "min": lambda s: s.min(0),
        "max": lambda s: s.max(0),
        "land": lambda s: (s != 0).all(0).astype(shards[0].dtype),
        "lor": lambda s: (s != 0).any(0).astype(shards[0].dtype),
    }[op](stack)
    return [red.copy() for _ in shards]


def bcast(shards, root=0):
    return [shards[root].copy() for _ in shards]


def scatter(shards, root=0):
    chunks = np.split(shards[root], len(shards), axis=0)
    return [c.copy() for c in chunks]


def gather(shards, root=0):
    full = np.concatenate(shards, axis=0)
    return [full.copy() for _ in shards]  # SPMD lowering: valid-at-root contract


def allgather(shards):
    full = np.concatenate(shards, axis=0)
    return [full.copy() for _ in shards]


def alltoall(shards):
    n = len(shards)
    out = []
    for j in range(n):
        pieces = [np.split(shards[i], n, axis=0)[j] for i in range(n)]
        out.append(np.concatenate(pieces, axis=0))
    return out


def reduce_scatter(shards):
    n = len(shards)
    total = np.stack(shards).sum(0)
    return [c.copy() for c in np.split(total, n, axis=0)]


def ppermute(shards, perm):
    n = len(shards)
    out = [np.zeros_like(shards[0]) for _ in range(n)]
    for src, dst in perm:
        out[dst] = shards[src].copy()
    return out
