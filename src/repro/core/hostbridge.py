"""HostBridge — the mpi4py-analogue *baseline* (paper Listing 2).

mpi4py cannot be called from inside Numba-JIT code, so each communication
forces a round-trip: leave the compiled block, run interpreted MPI, re-enter.
The XLA-world equivalent of that failure mode is the pattern this class
implements deliberately: one jit dispatch per compute fragment, then a
device→host transfer, a host-side (numpy) reduction standing in for the
interpreted MPI call, and a host→device transfer back.  Every iteration pays
dispatch latency + two PCIe/host-RAM hops + a host synchronization.

This is the "before" column for the paper's Fig. 1 reproduction
(``benchmarks/bench_pi.py``) and for the trainer's ``comm_backend=hostbridge``
mode.  Nothing here is a strawman: the per-call structure mirrors exactly what
``pi_mpi4py`` does in the paper (compute in fast code, communicate outside).
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np


class HostBridge:
    """Host-side 'MPI library' over the per-device shards of a mesh array."""

    def __init__(self, mesh):
        self.mesh = mesh
        self.n = int(np.prod(mesh.devices.shape))

    # --- host-side collectives (the "interpreted MPI" stand-ins) ----------
    def allreduce_host(self, shards: list[np.ndarray]) -> np.ndarray:
        return np.sum(np.stack(shards), axis=0)

    def bcast_host(self, shards: list[np.ndarray], root: int = 0) -> np.ndarray:
        return shards[root]

    # --- the round-trip loop ----------------------------------------------
    def fetch_shards(self, sharded_value) -> list[np.ndarray]:
        """Device → host: one transfer per device shard (addressable data)."""
        return [np.asarray(s.data) for s in sharded_value.addressable_shards]

    def roundtrip_allreduce(self, sharded_value):
        """device_get → numpy sum → device_put (replicated)."""
        shards = self.fetch_shards(sharded_value)
        reduced = self.allreduce_host(shards)
        return jax.device_put(reduced)

    def loop(self, step_fn: Callable, state, n_iters: int, reduce_extract=None,
             reduce_insert=None):
        """Run ``n_iters`` of: jit(step_fn) → host allreduce → feed back.

        ``reduce_extract(out)`` picks the array to reduce; ``reduce_insert
        (state, reduced)`` threads it back.  Identity defaults reduce the
        whole output.  Each iteration is a separate dispatch — by design.
        """
        step = jax.jit(step_fn)
        reduce_extract = reduce_extract or (lambda o: o)
        reduce_insert = reduce_insert or (lambda s, r: r)
        for _ in range(n_iters):
            out = step(state)
            part = reduce_extract(out)
            part.block_until_ready()  # the host sync mpi4py implies
            reduced = self.roundtrip_allreduce(part)
            state = reduce_insert(out, reduced)
        return state
