"""Collective operations: allreduce, bcast, scatter, gather, allgather,
alltoall, reduce_scatter, barrier — the numba-mpi v1.0 collective surface
(+ reduce_scatter/alltoall beyond v1.0), dispatched through the
collective-algorithm registry (``repro.core.registry``).

Every op: takes NumPy-like payloads (or Views), deduces dtype/shape from the
data (paper §2.3 "signatures do not require supplying data types or sizes"),
threads the ordering token, and returns ``(status, value)`` — or
``(status, value, token)`` when an explicit token is passed.

Algorithm selection (new in the registry refactor): each logical op has
≥2 interchangeable lowerings — the ``xla_native`` kernels defined here, the
chunked-ring schedules in ``repro.core.ring``, and the latency-optimal
schedules in ``repro.core.schedules``.  Which one lowers is decided at trace
time from the payload size and group size by the active policy table; force
a specific one per-call with ``algorithm="ring"`` or globally with
``jmpi.set_algorithm("allreduce", "ring")``.
"""

from __future__ import annotations

import enum
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core import token as token_lib
from repro.core import views as views_lib
from repro.core.comm import Communicator, resolve
from repro.core.token import SUCCESS


class Operator(enum.Enum):
    """Reduction operators (paper: 'Operator enumeration, default SUM')."""

    SUM = "sum"
    PROD = "prod"
    MIN = "min"
    MAX = "max"
    LAND = "land"
    LOR = "lor"


def _tok_in(token):
    explicit = token is not None
    return (token if explicit else token_lib.ambient().get()), explicit


def _tok_out(explicit, new_token, status, value):
    if explicit:
        return status, value, new_token
    token_lib.ambient().set(new_token)
    return status, value


def _pack(x):
    if isinstance(x, views_lib.View):
        return x.pack()
    return jnp.asarray(x)


# ===========================================================================
# xla_native kernels (registry entries): one XLA collective per op.
# ===========================================================================

@registry.register("allreduce", "xla_native")
def _allreduce_xla(val, tok, comm, *, op):
    """SUM/MIN/MAX lower to one psum/pmin/pmax; PROD uses an allgather+reduce
    (XLA has no native product collective); LAND/LOR lower to pmin/pmax over
    booleans."""
    if op is Operator.SUM:
        out = jax.lax.psum(val, comm.axes)
    elif op is Operator.MIN:
        out = jax.lax.pmin(val, comm.axes)
    elif op is Operator.MAX:
        out = jax.lax.pmax(val, comm.axes)
    elif op is Operator.PROD:
        g = jax.lax.all_gather(val, comm.axes, axis=0, tiled=False)
        out = jnp.prod(g, axis=0).astype(val.dtype)
    elif op is Operator.LAND:
        out = jax.lax.pmin((val != 0).astype(jnp.int32), comm.axes).astype(val.dtype)
    elif op is Operator.LOR:
        out = jax.lax.pmax((val != 0).astype(jnp.int32), comm.axes).astype(val.dtype)
    else:  # pragma: no cover - exhaustive enum
        raise ValueError(f"unsupported operator {op}")
    return out, tok


@registry.register("bcast", "xla_native")
def _bcast_xla(val, tok, comm, *, root):
    """Masked psum (non-root ranks contribute zeros) — one all-reduce, exact
    for every dtype (zeros are additive identity), and the pattern XLA
    rewrites into a broadcast when the mesh topology allows."""
    mask = (comm.rank() == root)
    contrib = jnp.where(mask, val, jnp.zeros_like(val))
    # Sum of {root's value, zeros} == root's value: exact for every dtype,
    # no overflow possible.  Bool goes through int32 (psum needs arithmetic).
    if val.dtype == jnp.bool_:
        out = jax.lax.psum(contrib.astype(jnp.int32), comm.axes).astype(jnp.bool_)
    else:
        out = jax.lax.psum(contrib, comm.axes)
    return out, tok


@registry.register("allgather", "xla_native")
def _allgather_xla(val, tok, comm):
    out = jax.lax.all_gather(val, comm.axes, axis=0, tiled=True)
    return out, tok


@registry.register("reduce_scatter", "xla_native")
def _reduce_scatter_xla(val, tok, comm, *, op):
    out = jax.lax.psum_scatter(val, comm.axes, scatter_dimension=0, tiled=True)
    return out, tok


@registry.register("alltoall", "xla_native",
                    supports=lambda val, comm, **kw: len(comm.axes) == 1)
def _alltoall_xla(val, tok, comm, *, split_axis=0, concat_axis=0):
    out = jax.lax.all_to_all(val, comm.axes[0], split_axis=split_axis,
                             concat_axis=concat_axis, tiled=True)
    return out, tok


# ===========================================================================
# Public ops — pack payload, select algorithm, thread the token.
# ===========================================================================

def allreduce(x, op: Operator = Operator.SUM, *,
              comm: Communicator | None = None, token=None,
              algorithm: str | None = None):
    """MPI_Allreduce.  ``algorithm``: force a registry entry by name
    (xla_native | ring | recursive_doubling | bf16_wire); default is the
    active policy's size-aware choice."""
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    val = _pack(x)
    algo = registry.select("allreduce", val, comm, algorithm=algorithm, op=op)
    tok, val = token_lib.tie(tok, val)
    out, tok = algo.fn(val, tok, comm, op=op)
    new_tok = token_lib.advance(tok, out)
    return _tok_out(explicit, new_tok, SUCCESS, out)


def bcast(x, root: int = 0, *, comm: Communicator | None = None, token=None,
          algorithm: str | None = None):
    """MPI_Bcast: root's value lands on every rank (xla_native | tree)."""
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    val = _pack(x)
    algo = registry.select("bcast", val, comm, algorithm=algorithm, root=root)
    tok, val = token_lib.tie(tok, val)
    out, tok = algo.fn(val, tok, comm, root=root)
    new_tok = token_lib.advance(tok, out)
    return _tok_out(explicit, new_tok, SUCCESS, out)


def scatter(x, root: int = 0, *, comm: Communicator | None = None, token=None,
            algorithm: str | None = None):
    """MPI_Scatter: rank i receives the i-th equal chunk (axis 0) of root's
    buffer. Lowered as bcast + static per-rank dynamic_slice; XLA's partitioner
    elides the unused chunks on real meshes.  The underlying bcast follows the
    same algorithm selection as :func:`bcast`."""
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    val = _pack(x)
    n = comm.size()
    if val.shape[0] % n:
        raise ValueError(f"scatter payload axis0={val.shape[0]} not divisible "
                         f"by comm size {n}")
    status, full, tok = bcast(val, root, comm=comm, token=tok,
                              algorithm=algorithm)
    chunk = val.shape[0] // n
    start = comm.rank() * chunk
    out = jax.lax.dynamic_slice_in_dim(full, start, chunk, axis=0)
    new_tok = token_lib.advance(tok, out)
    return _tok_out(explicit, new_tok, status, out)


def allgather(x, *, comm: Communicator | None = None, token=None,
              algorithm: str | None = None):
    """MPI_Allgather: concatenate every rank's buffer along axis 0
    (xla_native | ring)."""
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    val = _pack(x)
    algo = registry.select("allgather", val, comm, algorithm=algorithm)
    tok, val = token_lib.tie(tok, val)
    out, tok = algo.fn(val, tok, comm)
    new_tok = token_lib.advance(tok, out)
    return _tok_out(explicit, new_tok, SUCCESS, out)


def gather(x, root: int = 0, *, comm: Communicator | None = None, token=None,
           algorithm: str | None = None):
    """MPI_Gather: the concatenation is *valid at root*. SPMD lowering uses
    all_gather (every rank materializes the result; contents identical), the
    root-only contract is preserved at the API level."""
    del root  # root-only validity is a contract, not a dataflow difference
    return allgather(x, comm=comm, token=token, algorithm=algorithm)


def alltoall(x, *, comm: Communicator | None = None, token=None,
             split_axis: int = 0, concat_axis: int = 0,
             algorithm: str | None = None):
    """MPI_Alltoall: rank j receives chunk j from every rank, concatenated
    (xla_native | pairwise).  Payload axis ``split_axis`` must be divisible
    by comm size."""
    comm = resolve(comm)
    if len(comm.axes) != 1:
        raise ValueError("alltoall currently requires a single-axis "
                         "communicator (split the comm first)")
    tok, explicit = _tok_in(token)
    val = _pack(x)
    n = comm.size()
    if val.shape[split_axis] % n:
        raise ValueError(f"alltoall axis {split_axis} size {val.shape[split_axis]}"
                         f" not divisible by comm size {n}")
    algo = registry.select("alltoall", val, comm, algorithm=algorithm,
                           split_axis=split_axis, concat_axis=concat_axis)
    tok, val = token_lib.tie(tok, val)
    out, tok = algo.fn(val, tok, comm, split_axis=split_axis,
                       concat_axis=concat_axis)
    new_tok = token_lib.advance(tok, out)
    return _tok_out(explicit, new_tok, SUCCESS, out)


def reduce_scatter(x, op: Operator = Operator.SUM, *,
                   comm: Communicator | None = None, token=None,
                   algorithm: str | None = None):
    """MPI_Reduce_scatter_block (SUM only): psum_scatter along axis 0
    (xla_native | ring)."""
    comm = resolve(comm)
    if op is not Operator.SUM:
        raise ValueError("reduce_scatter supports SUM only")
    tok, explicit = _tok_in(token)
    val = _pack(x)
    n = comm.size()
    if val.shape[0] % n:
        raise ValueError(f"reduce_scatter axis0={val.shape[0]} not divisible "
                         f"by comm size {n}")
    algo = registry.select("reduce_scatter", val, comm, algorithm=algorithm,
                           op=op)
    tok, val = token_lib.tie(tok, val)
    out, tok = algo.fn(val, tok, comm, op=op)
    new_tok = token_lib.advance(tok, out)
    return _tok_out(explicit, new_tok, SUCCESS, out)


def barrier(*, comm: Communicator | None = None, token=None):
    """MPI_Barrier: a 1-element psum tied into the token chain. No jmpi op
    sequenced after the barrier can be scheduled before every rank reaches it."""
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    probe = jax.lax.psum(tok, comm.axes)
    new_tok = token_lib.advance(tok, probe)
    if explicit:
        return SUCCESS, new_tok
    token_lib.ambient().set(new_tok)
    return SUCCESS
