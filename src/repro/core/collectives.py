"""Collective operations: allreduce, bcast, scatter, gather, allgather,
alltoall, reduce_scatter, barrier — the numba-mpi v1.0 collective surface
(+ reduce_scatter/alltoall beyond v1.0), dispatched through the
collective-algorithm registry (``repro.core.registry``).

jmpi 2.0 surface: every op exists in three forms sharing ONE dispatch path —

* blocking   — ``allreduce(x) -> (status, value)`` (v1.0-compatible);
* nonblocking — ``iallreduce(x) -> Request`` (MPI-3 ``MPI_Iallreduce``):
  the same :class:`repro.core.p2p.Request` as isend/irecv, so a mixed list
  of p2p and collective requests flows through one unified
  ``wait/waitall/waitany/test/testall/testany``;
* persistent — ``allreduce_init(...) -> Plan`` (MPI-4 ``MPI_Allreduce_init``,
  in :mod:`repro.core.plans`): algorithm choice frozen once, re-dispatched
  from a cache on hot paths.

Every op: takes NumPy-like payloads (or Views), deduces dtype/shape from the
data (paper §2.3 "signatures do not require supplying data types or sizes"),
threads the ordering token, and returns ``(status, value)`` — or
``(status, value, token)`` when an explicit token is passed.

Algorithm selection: each logical op has ≥2 interchangeable lowerings — the
``xla_native`` kernels defined here, the chunked-ring schedules in
``repro.core.ring``, and the latency-optimal schedules in
``repro.core.schedules``.  Which one lowers is decided at trace time from
the payload size and group size by the active policy table; force a
specific one per-call with ``algorithm="ring"`` or globally with
``jmpi.set_algorithm("allreduce", "ring")``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import datatypes as datatypes_lib
from repro.core import registry
from repro.core import token as token_lib
from repro.core.comm import Communicator, resolve
from repro.core.operators import Operator
from repro.core.p2p import Request
from repro.core.token import SUCCESS

__all__ = [
    "Operator", "allreduce", "bcast", "scatter", "gather", "allgather",
    "alltoall", "reduce_scatter", "barrier", "iallreduce", "ibcast",
    "iscatter", "igather", "iallgather", "ialltoall", "ireduce_scatter",
    "ibarrier",
]


def _tok_in(token):
    explicit = token is not None
    return (token if explicit else token_lib.ambient().get()), explicit


def _tok_out(explicit, new_token, status, value):
    if explicit:
        return status, value, new_token
    token_lib.ambient().set(new_token)
    return status, value


_pack = datatypes_lib.pack_payload


# ===========================================================================
# xla_native kernels (registry entries): one XLA collective per op.
# ===========================================================================

@registry.register("allreduce", "xla_native")
def _allreduce_xla(val, tok, comm, *, op):
    """SUM/MIN/MAX lower to one psum/pmin/pmax; PROD uses an allgather+reduce
    (XLA has no native product collective); LAND/LOR lower to pmin/pmax over
    booleans."""
    if op is Operator.SUM:
        out = jax.lax.psum(val, comm.axes)
    elif op is Operator.MIN:
        out = jax.lax.pmin(val, comm.axes)
    elif op is Operator.MAX:
        out = jax.lax.pmax(val, comm.axes)
    elif op is Operator.PROD:
        g = jax.lax.all_gather(val, comm.axes, axis=0, tiled=False)
        out = jnp.prod(g, axis=0).astype(val.dtype)
    elif op is Operator.LAND:
        out = jax.lax.pmin((val != 0).astype(jnp.int32), comm.axes).astype(val.dtype)
    elif op is Operator.LOR:
        out = jax.lax.pmax((val != 0).astype(jnp.int32), comm.axes).astype(val.dtype)
    else:  # pragma: no cover - exhaustive enum
        raise ValueError(f"unsupported operator {op}")
    return out, tok


@registry.register("bcast", "xla_native")
def _bcast_xla(val, tok, comm, *, root):
    """Masked psum (non-root ranks contribute zeros) — one all-reduce, exact
    for every dtype (zeros are additive identity), and the pattern XLA
    rewrites into a broadcast when the mesh topology allows."""
    mask = (comm.rank() == root)
    contrib = jnp.where(mask, val, jnp.zeros_like(val))
    # Sum of {root's value, zeros} == root's value: exact for every dtype,
    # no overflow possible.  Bool goes through int32 (psum needs arithmetic).
    if val.dtype == jnp.bool_:
        out = jax.lax.psum(contrib.astype(jnp.int32), comm.axes).astype(jnp.bool_)
    else:
        out = jax.lax.psum(contrib, comm.axes)
    return out, tok


@registry.register("allgather", "xla_native")
def _allgather_xla(val, tok, comm):
    out = jax.lax.all_gather(val, comm.axes, axis=0, tiled=True)
    return out, tok


@registry.register("reduce_scatter", "xla_native", operators=(Operator.SUM,))
def _reduce_scatter_xla(val, tok, comm, *, op):
    out = jax.lax.psum_scatter(val, comm.axes, scatter_dimension=0, tiled=True)
    return out, tok


@registry.register("alltoall", "xla_native",
                    supports=lambda val, comm, **kw: len(comm.axes) == 1)
def _alltoall_xla(val, tok, comm, *, split_axis=0, concat_axis=0):
    out = jax.lax.all_to_all(val, comm.axes[0], split_axis=split_axis,
                             concat_axis=concat_axis, tiled=True)
    return out, tok


# ===========================================================================
# Shared dispatch: pack payload, select algorithm, thread the token, wrap
# the in-flight value in a Request.  Blocking ops complete it immediately;
# the i* forms hand the Request to the unified wait/test machinery.
# ===========================================================================

def _issue(op_name, x, *, comm, token, algorithm, tag=0, datatype=None,
           recv=None, **kw):
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    val = _pack(x, datatype)
    algo = registry.select(op_name, val, comm, algorithm=algorithm, **kw)
    tok, val = token_lib.tie(tok, val)
    out, tok = algo.fn(val, tok, comm, **kw)
    new_tok = token_lib.advance(tok, out)
    if not explicit:
        token_lib.ambient().set(new_tok)
    return Request(value=out, token=new_tok, tag=tag, recv=recv,
                   used_ambient=not explicit), explicit


def _finish(req, explicit):
    """Blocking completion: same (status, value[, token]) tuple as v1.0."""
    return _tok_out(explicit, req.token, req.status, req.value)


# ===========================================================================
# Nonblocking collectives (MPI-3 ``MPI_I<collective>`` analogues).
#
# Issue eagerly, complete at wait: the returned Request holds the collective
# result and its ordering token; XLA's latency-hiding scheduler overlaps
# independent compute until the ``wait``/``test`` consumption point — the
# exact Request model of isend/irecv, so mixed p2p+collective request lists
# flow through one waitall/waitany/testall/testany.
# ===========================================================================

def iallreduce(x, op: Operator = Operator.SUM, *,
               comm: Communicator | None = None, token=None,
               algorithm: str | None = None, tag: int = 0,
               datatype=None) -> Request:
    """MPI_Iallreduce: start a nonblocking allreduce, complete via wait*/test*."""
    req, _ = _issue("allreduce", x, comm=comm, token=token,
                    algorithm=algorithm, tag=tag, datatype=datatype, op=op)
    return req


def ibcast(x, root: int = 0, *, comm: Communicator | None = None, token=None,
           algorithm: str | None = None, tag: int = 0,
           datatype=None) -> Request:
    """MPI_Ibcast: root's value lands on every rank at completion."""
    req, _ = _issue("bcast", x, comm=comm, token=token, algorithm=algorithm,
                    tag=tag, datatype=datatype, root=root)
    return req


def iscatter(x, root: int = 0, *, comm: Communicator | None = None,
             token=None, algorithm: str | None = None, tag: int = 0,
             datatype=None) -> Request:
    """MPI_Iscatter: rank i's Request completes with the i-th equal chunk
    (axis 0) of root's buffer.  Lowered as bcast + static per-rank slice;
    XLA's partitioner elides the unused chunks on real meshes."""
    comm = resolve(comm)
    val = _pack(x, datatype)
    n = comm.size()
    if val.shape[0] % n:
        raise ValueError(f"scatter payload axis0={val.shape[0]} not divisible "
                         f"by comm size {n}")
    breq, explicit = _issue("bcast", val, comm=comm, token=token,
                            algorithm=algorithm, root=root)
    chunk = val.shape[0] // n
    out = jax.lax.dynamic_slice_in_dim(breq.value, comm.rank() * chunk, chunk,
                                       axis=0)
    new_tok = token_lib.advance(breq.token, out)
    if not explicit:
        token_lib.ambient().set(new_tok)
    return Request(value=out, token=new_tok, tag=tag,
                   used_ambient=not explicit, status=breq.status)


def iallgather(x, *, comm: Communicator | None = None, token=None,
               algorithm: str | None = None, tag: int = 0,
               datatype=None) -> Request:
    """MPI_Iallgather: completes with every rank's buffer concatenated
    along axis 0."""
    req, _ = _issue("allgather", x, comm=comm, token=token,
                    algorithm=algorithm, tag=tag, datatype=datatype)
    return req


def igather(x, root: int = 0, *, comm: Communicator | None = None, token=None,
            algorithm: str | None = None, tag: int = 0,
            datatype=None) -> Request:
    """MPI_Igather: the concatenation is *valid at root*. SPMD lowering uses
    all_gather (every rank materializes the result; contents identical), the
    root-only contract is preserved at the API level."""
    del root  # root-only validity is a contract, not a dataflow difference
    return iallgather(x, comm=comm, token=token, algorithm=algorithm, tag=tag,
                      datatype=datatype)


def ialltoall(x, *, comm: Communicator | None = None, token=None,
              split_axis: int = 0, concat_axis: int = 0,
              algorithm: str | None = None, tag: int = 0,
              datatype=None) -> Request:
    """MPI_Ialltoall: completes with chunk j from every rank, concatenated."""
    comm = resolve(comm)
    if len(comm.axes) != 1:
        raise ValueError("alltoall currently requires a single-axis "
                         "communicator (split the comm first)")
    val = _pack(x, datatype)
    n = comm.size()
    if val.shape[split_axis] % n:
        raise ValueError(f"alltoall axis {split_axis} size {val.shape[split_axis]}"
                         f" not divisible by comm size {n}")
    req, _ = _issue("alltoall", val, comm=comm, token=token,
                    algorithm=algorithm, tag=tag, split_axis=split_axis,
                    concat_axis=concat_axis)
    return req


def ireduce_scatter(x, op: Operator = Operator.SUM, *,
                    comm: Communicator | None = None, token=None,
                    algorithm: str | None = None, tag: int = 0,
                    datatype=None) -> Request:
    """MPI_Ireduce_scatter_block: completes with this rank's reduced chunk."""
    comm = resolve(comm)
    val = _pack(x, datatype)
    n = comm.size()
    if val.shape[0] % n:
        raise ValueError(f"reduce_scatter axis0={val.shape[0]} not divisible "
                         f"by comm size {n}")
    req, _ = _issue("reduce_scatter", val, comm=comm, token=token,
                    algorithm=algorithm, tag=tag, op=op)
    return req


def ibarrier(*, comm: Communicator | None = None, token=None,
             tag: int = 0) -> Request:
    """MPI_Ibarrier: the Request's completion point is the synchronization —
    no jmpi op sequenced after ``wait(req)`` can be scheduled before every
    rank reached the barrier."""
    comm = resolve(comm)
    tok, explicit = _tok_in(token)
    probe = comm._barrier_probe(tok)
    new_tok = token_lib.advance(tok, probe)
    if not explicit:
        token_lib.ambient().set(new_tok)
    return Request(value=probe, token=new_tok, tag=tag,
                   used_ambient=not explicit)


# ===========================================================================
# Blocking forms (v1.0 surface) — issue + immediate completion.
# ===========================================================================

def allreduce(x, op: Operator = Operator.SUM, *,
              comm: Communicator | None = None, token=None,
              algorithm: str | None = None, datatype=None):
    """MPI_Allreduce.  ``algorithm``: force a registry entry by name
    (xla_native | ring | recursive_doubling | bf16_wire); default is the
    active policy's size-aware choice.  ``datatype``: pack ``x`` through an
    explicit derived datatype (see ``repro.core.datatypes``)."""
    req, explicit = _issue("allreduce", x, comm=comm, token=token,
                           algorithm=algorithm, datatype=datatype, op=op)
    return _finish(req, explicit)


def bcast(x, root: int = 0, *, comm: Communicator | None = None, token=None,
          algorithm: str | None = None, datatype=None):
    """MPI_Bcast: root's value lands on every rank (xla_native | tree)."""
    req, explicit = _issue("bcast", x, comm=comm, token=token,
                           algorithm=algorithm, datatype=datatype, root=root)
    return _finish(req, explicit)


def scatter(x, root: int = 0, *, comm: Communicator | None = None, token=None,
            algorithm: str | None = None, datatype=None):
    """MPI_Scatter: rank i receives the i-th equal chunk (axis 0) of root's
    buffer.  The underlying bcast follows the same algorithm selection as
    :func:`bcast`."""
    explicit = token is not None
    req = iscatter(x, root, comm=comm, token=token, algorithm=algorithm,
                   datatype=datatype)
    return _finish(req, explicit)


def allgather(x, *, comm: Communicator | None = None, token=None,
              algorithm: str | None = None, datatype=None):
    """MPI_Allgather: concatenate every rank's buffer along axis 0
    (xla_native | ring)."""
    req, explicit = _issue("allgather", x, comm=comm, token=token,
                           algorithm=algorithm, datatype=datatype)
    return _finish(req, explicit)


def gather(x, root: int = 0, *, comm: Communicator | None = None, token=None,
           algorithm: str | None = None, datatype=None):
    """MPI_Gather: the concatenation is *valid at root* (see igather)."""
    del root  # root-only validity is a contract, not a dataflow difference
    return allgather(x, comm=comm, token=token, algorithm=algorithm,
                     datatype=datatype)


def alltoall(x, *, comm: Communicator | None = None, token=None,
             split_axis: int = 0, concat_axis: int = 0,
             algorithm: str | None = None, datatype=None):
    """MPI_Alltoall: rank j receives chunk j from every rank, concatenated
    (xla_native | pairwise).  Payload axis ``split_axis`` must be divisible
    by comm size."""
    explicit = token is not None
    req = ialltoall(x, comm=comm, token=token, split_axis=split_axis,
                    concat_axis=concat_axis, algorithm=algorithm,
                    datatype=datatype)
    return _finish(req, explicit)


def reduce_scatter(x, op: Operator = Operator.SUM, *,
                   comm: Communicator | None = None, token=None,
                   algorithm: str | None = None, datatype=None):
    """MPI_Reduce_scatter_block along axis 0 (xla_native | ring).  The
    xla_native lowering (psum_scatter) is SUM-only; other Operators require
    an algorithm that declares them (e.g. ``ring``) — an unsupported pair
    raises the registry's uniform trace-time error."""
    explicit = token is not None
    req = ireduce_scatter(x, op, comm=comm, token=token, algorithm=algorithm,
                          datatype=datatype)
    return _finish(req, explicit)


def barrier(*, comm: Communicator | None = None, token=None):
    """MPI_Barrier: a 1-element psum tied into the token chain. No jmpi op
    sequenced after the barrier can be scheduled before every rank reaches it."""
    req = ibarrier(comm=comm, token=token)
    if token is not None:
        return SUCCESS, req.token
    return SUCCESS
