"""Collective-algorithm registry + size-aware selection policy.

Every logical collective (allreduce, bcast, allgather, reduce_scatter,
alltoall) has ≥2 interchangeable lowerings — ``xla_native`` (the XLA
collective, latency/bandwidth profile chosen by the compiler), ``ring``
(chunked ppermute schedule, overlappable), ``recursive_doubling`` (log₂ n
full-payload exchange rounds — latency-optimal for small payloads),
``tree`` (binomial-tree bcast), ``pairwise`` (alltoall as n−1 shifted
permutes), ``bf16_wire`` (half-width wire for bandwidth-bound float sums).
OMB-Py (Alnaasan et al., 2021) shows the right choice is payload-size- and
rank-count-dependent; this module is the seam that makes the choice a table
lookup instead of a rewrite.

Selection order, resolved **at trace time** (payload shapes are static):

1. explicit ``algorithm=`` argument on the collective call (error if the
   named algorithm cannot handle the payload);
2. a process-global per-op override installed by :func:`set_algorithm` /
   the :func:`algorithm_override` context manager;
3. the active :class:`PolicyTable` — first matching (op, rank-count,
   byte-range) rule, else the table's per-op default;
4. ``xla_native`` as the final fallback (always registered, supports
   everything its public op supports).

If the chosen algorithm's ``supports`` predicate rejects the payload (e.g.
``recursive_doubling`` on a non-power-of-two group, ``ring`` allreduce for a
non-SUM operator) the selection silently falls back to ``xla_native`` —
except for case 1, where the caller asked by name and gets a trace-time
``ValueError`` instead.  When even ``xla_native`` rejects the payload (an
op whose native lowering is narrower than the op itself, e.g. alltoallv on
a multi-axis communicator) selection scans the remaining registered
lowerings for an eligible one and raises a trace-time error only when none
exists — an ineligible choice is never silently executed.

Policy tables serialize to JSON.  ``repro.launch.collective_tuner`` sweeps
algorithms × sizes on the live backend and emits a tuned table;
``benchmarks/bench_collectives.py --sweep-algorithms`` prints the same
table with the measured crossover points.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Any, Callable, Optional

OPS = ("allreduce", "bcast", "allgather", "reduce_scatter", "alltoall",
       "scatterv", "gatherv", "allgatherv", "alltoallv",
       "neighbor_allgather", "neighbor_alltoall", "neighbor_alltoallv")
DEFAULT_ALGORITHM = "xla_native"

#: Known transport backends.  Lowerings are registered per backend: the
#: emulated (single-process shard_map) entries above, and the eager
#: inter-process ``direct`` kernels contributed by
#: ``repro.transport.endpoint``.  Selection keys off ``comm.backend``.
BACKENDS = ("emulated", "multiproc")

#: Per-backend final-fallback algorithm name (the emulated registry keeps
#: the historical ``xla_native`` fallback; multiproc's wire kernels are all
#: registered as ``direct``).
BACKEND_DEFAULTS = {"emulated": DEFAULT_ALGORITHM, "multiproc": "direct"}


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A registered lowering for one logical collective op.

    ``fn(val, tok, comm, **kw) -> (out, tok)`` — the kernel; receives the
    packed payload and the ordering token (already tied), threads the token
    through its own communication steps, and returns the result plus the
    final token.  ``supports(val, comm, **kw) -> bool`` is a trace-time
    eligibility predicate (static shapes / static group size only).
    ``operators`` declares the reduction Operators the kernel honors
    (values of :class:`repro.core.operators.Operator`); ``None`` means
    all six / operator-free — an unsupported (algorithm, operator) pair is
    a uniform trace-time ValueError, never a silent wrong answer.
    """

    op: str
    name: str
    fn: Callable[..., Any]
    supports: Callable[..., bool]
    operators: Optional[frozenset] = None

    def supports_operator(self, red_op) -> bool:
        """True when this lowering honors reduction operator ``red_op``
        (None declarations mean all operators / operator-free ops).

        Args:
            red_op: an :class:`~repro.core.operators.Operator` member, its
                string value, or None.
        Returns:
            Whether the (algorithm, operator) pair is legal.
        """
        if self.operators is None or red_op is None:
            return True
        return getattr(red_op, "value", red_op) in self.operators

    def operator_error(self, red_op) -> str:
        """The uniform trace-time error message for an unsupported pair.

        Args:
            red_op: the rejected operator.
        Returns:
            A message naming the algorithm, the op, the operator and the
            supported set.
        """
        return (f"algorithm {self.name!r} for {self.op!r} does not support "
                f"Operator.{getattr(red_op, 'name', red_op)}; supported "
                f"operators: {sorted(self.operators)}")


_REGISTRY: dict[str, dict[str, dict[str, Algorithm]]] = {
    b: {op: {} for op in OPS} for b in BACKENDS}


def register(op: str, name: str, supports: Callable[..., bool] | None = None,
             operators=None, backend: str = "emulated"):
    """Decorator: register ``fn`` as algorithm ``name`` for logical ``op``
    on transport ``backend``.

    ``operators``: iterable of supported Operator members (or their string
    values); None = every operator (or the op takes no operator).
    """
    if backend not in _REGISTRY:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if op not in _REGISTRY[backend]:
        raise ValueError(f"unknown collective op {op!r}; expected one of {OPS}")
    op_set = (None if operators is None else
              frozenset(getattr(o, "value", o) for o in operators))

    def deco(fn):
        _REGISTRY[backend][op][name] = Algorithm(
            op=op, name=name, fn=fn,
            supports=supports if supports is not None
            else (lambda val, comm, **kw: True),
            operators=op_set)
        return fn

    return deco


def algorithms(op: str, backend: str = "emulated") -> list[str]:
    """Registered algorithm names for ``op`` on ``backend`` (sorted; the
    backend's default first)."""
    default = BACKEND_DEFAULTS.get(backend, DEFAULT_ALGORITHM)
    names = sorted(_REGISTRY[backend][op])
    if default in names:
        names.remove(default)
        names.insert(0, default)
    return names


def get(op: str, name: str, backend: str = "emulated") -> Algorithm:
    """Look up a registered lowering by name.

    Args:
        op: logical collective (one of :data:`OPS`).
        name: registered algorithm name.
        backend: transport backend the lowering was registered for.
    Returns:
        The :class:`Algorithm` entry.
    Raises:
        ValueError: unknown ``op``/``backend`` or unregistered ``name``.
    """
    if backend not in _REGISTRY:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if op not in _REGISTRY[backend]:
        raise ValueError(f"unknown collective op {op!r}; expected one of {OPS}")
    if name not in _REGISTRY[backend][op]:
        raise ValueError(
            f"no algorithm {name!r} registered for {op!r} on backend "
            f"{backend!r}; available: {algorithms(op, backend)}")
    return _REGISTRY[backend][op][name]


# ---------------------------------------------------------------------------
# Policy table — size/rank-count → algorithm, JSON round-trippable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """First matching rule wins: op equal, rank count equal (when pinned),
    payload bytes within [min_bytes, max_bytes]."""

    op: str
    algorithm: str
    min_bytes: int = 0
    max_bytes: Optional[int] = None   # None = unbounded
    ranks: Optional[int] = None       # None = any group size

    def matches(self, op: str, nbytes: int, n_ranks: int) -> bool:
        """Whether this rule applies to one (op, payload, group) query.

        Args:
            op: logical collective name.
            nbytes: static payload size in bytes.
            n_ranks: communicator group size.
        Returns:
            True when op matches, the rank pin (if any) matches, and
            ``nbytes`` falls within [min_bytes, max_bytes].
        """
        if self.op != op:
            return False
        if self.ranks is not None and self.ranks != n_ranks:
            return False
        if nbytes < self.min_bytes:
            return False
        if self.max_bytes is not None and nbytes > self.max_bytes:
            return False
        return True


@dataclasses.dataclass
class PolicyTable:
    rules: list[PolicyRule] = dataclasses.field(default_factory=list)
    default: dict[str, str] = dataclasses.field(default_factory=dict)

    def choose(self, op: str, nbytes: int, n_ranks: int) -> str:
        """First matching rule's algorithm, else the per-op default.

        Args:
            op: logical collective name.
            nbytes: static payload size in bytes.
            n_ranks: communicator group size.
        Returns:
            The chosen algorithm name (eligibility NOT yet checked —
            :func:`select` applies ``supports`` and falls back).
        """
        for rule in self.rules:
            if rule.matches(op, nbytes, n_ranks):
                return rule.algorithm
        return self.default.get(op, DEFAULT_ALGORITHM)

    # -- serialization ----------------------------------------------------
    def to_json(self) -> str:
        """Serialize the table (rules + defaults) to versioned JSON.

        Returns:
            The JSON text :meth:`from_json` round-trips.
        """
        return json.dumps({
            "version": 1,
            "rules": [dataclasses.asdict(r) for r in self.rules],
            "default": self.default,
        }, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "PolicyTable":
        """Parse a table from :meth:`to_json` output.

        Args:
            text: the JSON document.
        Returns:
            The reconstructed :class:`PolicyTable`.
        """
        doc = json.loads(text)
        return cls(rules=[PolicyRule(**r) for r in doc.get("rules", [])],
                   default=dict(doc.get("default", {})))

    def save(self, path: str) -> None:
        """Write the table as JSON to ``path``.

        Args:
            path: destination file.
        """
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "PolicyTable":
        """Read a JSON table from ``path`` (without installing it).

        Args:
            path: source file.
        Returns:
            The parsed :class:`PolicyTable`.
        """
        with open(path) as f:
            return cls.from_json(f.read())

    def describe(self) -> str:
        """Human-readable policy table (what the bench sweep prints).

        Returns:
            One line per rule plus the per-op default rows.
        """
        lines = [f"{'op':<20}{'bytes':<24}{'ranks':<8}algorithm",
                 "-" * 64]
        for r in self.rules:
            hi = "inf" if r.max_bytes is None else str(r.max_bytes)
            rk = "any" if r.ranks is None else str(r.ranks)
            lines.append(f"{r.op:<20}{f'[{r.min_bytes}, {hi}]':<24}"
                         f"{rk:<8}{r.algorithm}")
        for op in OPS:
            lines.append(f"{op:<20}{'(default)':<24}{'any':<8}"
                         f"{self.default.get(op, DEFAULT_ALGORITHM)}")
        return "\n".join(lines)


def default_policy() -> PolicyTable:
    """Built-in policy: XLA-native everywhere except latency-bound (tiny)
    payloads, where the log₂n-round schedules win on latency (rule of thumb
    from OMB-Py-style sweeps; regenerate with the tuner for real hardware)."""
    return PolicyTable(
        rules=[
            PolicyRule("allreduce", "recursive_doubling", max_bytes=1024),
            PolicyRule("bcast", "tree", max_bytes=1024),
        ],
        default={op: DEFAULT_ALGORITHM for op in OPS},
    )


_ACTIVE_POLICY: list[PolicyTable] = [default_policy()]
_OVERRIDES: dict[str, str] = {}
_SELECTION_EPOCH = [0]


def selection_epoch() -> int:
    """Monotonic counter bumped whenever the selection inputs change (policy
    table installed, per-op override set/cleared).  Callers that cache a
    resolved selection (``repro.core.plans``) key their fast path on it so a
    cache hit can legitimately skip :func:`select`."""
    return _SELECTION_EPOCH[0]


def _bump_epoch() -> None:
    _SELECTION_EPOCH[0] += 1


def active_policy() -> PolicyTable:
    """The process-global policy table currently consulted by selection.

    Returns:
        The installed :class:`PolicyTable` (built-in default if none).
    """
    return _ACTIVE_POLICY[0]


def set_policy(table: PolicyTable | None) -> None:
    """Install ``table`` as the process-global policy (None = built-in)."""
    _ACTIVE_POLICY[0] = table if table is not None else default_policy()
    _bump_epoch()


def load_policy(path: str) -> PolicyTable:
    """Load a tuner-emitted JSON policy table and make it active."""
    table = PolicyTable.load(path)
    set_policy(table)
    return table


def save_policy(path: str) -> None:
    """Write the active policy table to ``path`` as JSON.

    Args:
        path: destination file (loadable via :func:`load_policy`).
    """
    active_policy().save(path)


def set_algorithm(op: str, name: str | None) -> None:
    """Force ``op`` to use algorithm ``name`` for all subsequent traces
    (``jmpi.set_algorithm``); ``None`` clears the override.  Unsupported
    payloads still fall back to ``xla_native``."""
    if name is None:
        _OVERRIDES.pop(op, None)
        _bump_epoch()
        return
    get(op, name)  # validate eagerly
    _OVERRIDES[op] = name
    _bump_epoch()


def clear_algorithms() -> None:
    """Drop every per-op override installed by :func:`set_algorithm`
    (selection falls back to the active policy table)."""
    _OVERRIDES.clear()
    _bump_epoch()


@contextlib.contextmanager
def algorithm_override(**ops_to_names: str):
    """Scoped :func:`set_algorithm` for one or more ops:

        with jmpi.algorithm_override(allreduce="ring"):
            ... trace code ...
    """
    saved = dict(_OVERRIDES)
    try:
        for op, name in ops_to_names.items():
            set_algorithm(op, name)
        yield
    finally:
        _OVERRIDES.clear()
        _OVERRIDES.update(saved)
        _bump_epoch()


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------

def payload_bytes(val) -> int:
    """Static payload size (trace-time: shapes/dtypes are static)."""
    import numpy as np
    return int(np.prod(val.shape, dtype=int)) * val.dtype.itemsize


def choose_name(op: str, nbytes: int, n_ranks: int) -> str:
    """Policy-level choice (override → table), without eligibility checks.
    Host-side helper for planners (ParamSharder.collective_plan, overlap)."""
    if op in _OVERRIDES:
        return _OVERRIDES[op]
    return active_policy().choose(op, nbytes, n_ranks)


def select(op_name: str, val, comm, algorithm: str | None = None,
           **kw) -> Algorithm:
    """Resolve the algorithm for one collective call (trace time).

    (First parameter is ``op_name`` because ``op=`` is a kernel kwarg —
    the reduction Operator — forwarded through ``**kw``.)

    Operator eligibility is checked separately from payload eligibility so
    an unsupported (algorithm, Operator) pair raises the uniform trace-time
    error from :meth:`Algorithm.operator_error` — both when the caller named
    the algorithm and when the policy fallback itself cannot honor the
    operator (it must never silently compute the wrong reduction).

    Fallback eligibility IS checked: when even ``xla_native`` rejects the
    payload (e.g. alltoallv on a multi-axis communicator — its native
    lowering needs one axis, its pairwise schedule does not), selection
    scans the remaining registered lowerings for an eligible one and only
    errors when none exists — never a silently wrong transfer.
    """
    red_op = kw.get("op")
    backend = getattr(comm, "backend", "emulated")
    if algorithm is not None:
        algo = get(op_name, algorithm, backend)
        if not algo.supports_operator(red_op):
            raise ValueError(algo.operator_error(red_op))
        if not algo.supports(val, comm, **kw):
            raise ValueError(
                f"algorithm {algorithm!r} cannot handle this {op_name} call "
                f"(shape={tuple(val.shape)}, dtype={val.dtype}, "
                f"ranks={comm.size()}, {kw})")
        return algo
    name = choose_name(op_name, payload_bytes(val), comm.size())
    algo = _REGISTRY[backend][op_name].get(name)
    if algo is not None and algo.supports_operator(red_op) \
            and algo.supports(val, comm, **kw):
        return algo
    fallback = get(op_name, BACKEND_DEFAULTS.get(backend, DEFAULT_ALGORITHM),
                   backend)
    if not fallback.supports_operator(red_op):
        raise ValueError(fallback.operator_error(red_op))
    if fallback.supports(val, comm, **kw):
        return fallback
    for other in algorithms(op_name, backend):
        cand = _REGISTRY[backend][op_name][other]
        if cand.supports_operator(red_op) and cand.supports(val, comm, **kw):
            return cand
    raise ValueError(
        f"no registered algorithm for {op_name!r} supports this call "
        f"(shape={tuple(val.shape)}, dtype={val.dtype}, "
        f"ranks={comm.size()}, backend={backend!r}, {kw}); "
        f"registered: {algorithms(op_name, backend)}")
