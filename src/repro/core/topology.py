"""Cartesian process topologies + MPI-3 neighborhood collectives.

numba-mpi v1.0 stops at ``COMM_WORLD``; its headline applications (py-pde,
PyMPDATA-MPI — paper §3) nevertheless *are* Cartesian domain decompositions,
hand-computing neighbour ranks and issuing raw isend/irecv pairs.  This
module supplies the MPI layer that exists to eliminate exactly that
boilerplate:

* :func:`cart_create` / :class:`CartComm` — ``MPI_Cart_create`` and the
  query surface (``cart_coords`` / ``cart_rank`` / ``cart_shift`` /
  ``cart_sub``), mapped onto jmpi's mesh-axis communicators.  A Cartesian
  dimension is a consecutive run of mesh axes (row-major), so every derived
  group is again a plain axis-subset communicator and all of jmpi 2.0
  (collectives, plans, Requests) works on it unchanged.
* MPI-3 **neighborhood collectives** — ``neighbor_allgather`` and
  ``neighbor_alltoall[v]`` — registered as first-class collectives in the
  algorithm registry with two lowerings each: ``xla_native`` (one
  ``ppermute`` shift per (dimension, direction)) and ``ring`` (p2p-fused
  unidirectional rings — both directions of a dimension travel the same
  forward ring, the torus-network-friendly schedule).  Blocking,
  nonblocking ``ineighbor_*`` (unified :class:`~repro.core.p2p.Request`)
  and persistent ``neighbor_*_init`` plans all share the registry dispatch.
* a node-aware two-level ``hierarchical`` allreduce lowering
  (reduce-scatter intra-group, allreduce inter-group, allgather intra-group
  — the classic SMP-aware schedule), selectable by the policy table.

Null-rank semantics: at a non-periodic boundary MPI delivers from/to
``MPI_PROC_NULL`` — the send vanishes and the receive buffer is left
untouched.  Functional arrays have no "untouched", so jmpi defines the
boundary slots as **zeros** (the ppermute convention for ranks absent from
a permutation); :meth:`CartComm.cart_shift` reports :data:`PROC_NULL` for
the missing neighbour exactly like MPI.

Static-topology discipline (DESIGN.md §2): ``dims``/``periods`` are Python
values, shift patterns are full (src, dst) lists built at trace time, and
``reorder`` is accepted-but-ignored (rank order is fixed by the mesh under
SPMD — there is no runtime rank renumbering to exploit).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datatypes as datatypes_lib
from repro.core import registry
from repro.core import token as token_lib
from repro.core import views as views_lib
from repro.core.comm import Communicator, resolve
from repro.core.operators import Operator
from repro.core.p2p import Request

__all__ = [
    "PROC_NULL", "CartComm", "cart_create",
    "neighbor_allgather", "neighbor_alltoall", "neighbor_alltoallv",
    "ineighbor_allgather", "ineighbor_alltoall", "ineighbor_alltoallv",
]

#: MPI_PROC_NULL analogue: the "rank" reported by :meth:`CartComm.cart_shift`
#: for the missing neighbour at a non-periodic boundary.
PROC_NULL = -1


# ---------------------------------------------------------------------------
# dims ↔ mesh-axes factorization
# ---------------------------------------------------------------------------

def _strides(dims: tuple[int, ...]) -> tuple[int, ...]:
    """Row-major strides of a dims grid (last dimension fastest)."""
    out, acc = [], 1
    for d in reversed(dims):
        out.append(acc)
        acc *= d
    return tuple(reversed(out))


def _unflatten(rank: int, dims: tuple[int, ...]) -> tuple[int, ...]:
    """Static rank → row-major Cartesian coordinates."""
    coords = []
    for s in _strides(dims):
        coords.append(rank // s)
        rank %= s
    return tuple(coords)


def _flatten(coords: Sequence[int], dims: tuple[int, ...]) -> int:
    """Row-major Cartesian coordinates → static rank."""
    return sum(c * s for c, s in zip(coords, _strides(dims)))


def _factor_axes(axes: tuple[str, ...], sizes: tuple[int, ...],
                 dims: tuple[int, ...]) -> tuple[tuple[str, ...], ...]:
    """Partition ``axes`` (in order) into one consecutive group per dim.

    Row-major rank order over the communicator's axes must equal row-major
    order over ``dims``, so each Cartesian dimension has to be a consecutive
    run of mesh axes whose sizes multiply to the dim extent.  Among the
    valid partitions the one with the fewest empty groups wins (degenerate
    size-1 dims keep a size-1 mesh axis when one is available, so
    :meth:`CartComm.cart_sub` can retain them).

    Args:
        axes: the communicator's mesh-axis names, in rank-major order.
        sizes: the per-axis extents (same length as ``axes``).
        dims: requested Cartesian grid extents.
    Returns:
        ``axis_map`` — for each dim, the tuple of mesh axes composing it.
    Raises:
        ValueError: no consecutive-run factorization exists (build the mesh
            so its axis sizes factor the requested grid).
    """
    n_axes, n_dims = len(axes), len(dims)
    best = None
    for cuts in itertools.combinations_with_replacement(
            range(n_axes + 1), n_dims - 1):
        bounds = (0,) + cuts + (n_axes,)
        groups = [tuple(range(bounds[i], bounds[i + 1]))
                  for i in range(n_dims)]
        if any(math.prod(sizes[j] for j in g) != dims[i]
               for i, g in enumerate(groups)):
            continue
        score = sum(1 for g in groups if not g)
        if best is None or score < best[0]:
            best = (score, groups)
    if best is None:
        raise ValueError(
            f"cart_create: dims {tuple(dims)} do not factor the "
            f"communicator's axis sizes {tuple(sizes)} as consecutive runs "
            f"(axes {tuple(axes)}); build the mesh so its axis sizes match "
            f"the Cartesian grid (static topology, DESIGN.md §2)")
    return tuple(tuple(axes[j] for j in g) for g in best[1])


# ---------------------------------------------------------------------------
# CartComm
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CartComm(Communicator):
    """A communicator with an attached Cartesian topology (MPI_Cart_create).

    Ranks are the parent communicator's ranks; coordinates are the row-major
    unflattening of the rank over ``dims`` (dimension 0 slowest), which by
    construction (see :func:`_factor_axes`) coincides with the mesh-axis
    linearization.  ``axis_map[d]`` records which mesh axes compose
    dimension ``d`` (empty for degenerate size-1 dims).

    All :class:`Communicator` methods (collectives, p2p, plans, ``dup``,
    ``split``) work unchanged; ``dup()`` keeps the topology (a fresh
    communication context, MPI_Comm_dup), ``split()`` drops it (returns a
    plain :class:`Communicator`, matching MPI_Comm_split).
    """

    dims: tuple[int, ...] = ()
    periods: tuple[bool, ...] = ()
    axis_map: tuple[tuple[str, ...], ...] = ()

    # -- topology queries (static) ----------------------------------------
    @property
    def ndims(self) -> int:
        """Number of Cartesian dimensions (MPI_Cartdim_get)."""
        return len(self.dims)

    @property
    def neighbor_count(self) -> int:
        """Slot count of the neighborhood collectives: 2·ndims, ordered
        (dim-0 −1, dim-0 +1, dim-1 −1, dim-1 +1, …) — the MPI-3 Cartesian
        neighbour order."""
        return 2 * len(self.dims)

    def cart_coords(self, rank: int | None = None):
        """Cartesian coordinates (MPI_Cart_coords).

        Args:
            rank: a static Python rank → static ``tuple[int, ...]``; None →
                the calling device's coordinates as traced int32 scalars
                (valid only inside an spmd trace).
        Returns:
            Tuple of per-dimension coordinates (static ints or traced
            arrays; degenerate dims are the static int 0).
        Raises:
            ValueError: static ``rank`` outside ``[0, size)``.
        """
        if rank is not None:
            if not 0 <= rank < self.size():
                raise ValueError(f"rank {rank} out of range for cart comm "
                                 f"of size {self.size()}")
            return _unflatten(int(rank), self.dims)
        return tuple(jax.lax.axis_index(am) if am else 0
                     for am in self.axis_map)

    def cart_rank(self, coords: Sequence[int]) -> int:
        """Static coordinates → static rank (MPI_Cart_rank).

        Args:
            coords: one integer per dimension.  Out-of-range entries wrap
                on periodic dims (MPI semantics) and raise otherwise.
        Returns:
            The row-major rank as a Python int.
        Raises:
            ValueError: wrong arity, or an out-of-range coordinate on a
                non-periodic dimension.
        """
        if len(coords) != self.ndims:
            raise ValueError(f"expected {self.ndims} coords, got {coords!r}")
        fixed = []
        for c, n, p in zip(coords, self.dims, self.periods):
            c = int(c)
            if p:
                c %= n
            elif not 0 <= c < n:
                raise ValueError(
                    f"coordinate {c} out of range [0, {n}) on a "
                    f"non-periodic dimension")
            fixed.append(c)
        return _flatten(fixed, self.dims)

    def cart_shift(self, dim: int, disp: int = 1):
        """Traced (source, dest) ranks for a shift (MPI_Cart_shift).

        Args:
            dim: dimension index to shift along.
            disp: displacement (positive = towards higher coordinates).
        Returns:
            ``(source, dest)`` int32 scalars per device: the rank this
            device would receive from / send to; :data:`PROC_NULL` where
            the non-periodic boundary leaves no neighbour.
        Raises:
            IndexError: ``dim`` out of range.
        """
        n = self.dims[dim]
        coords = self.cart_coords()
        stride = _strides(self.dims)
        base = sum(c * s for d, (c, s) in enumerate(zip(coords, stride))
                   if d != dim)

        def side(delta):
            c = coords[dim] + delta
            if self.periods[dim]:
                return jnp.asarray(base + (c % n) * stride[dim], jnp.int32)
            valid = (c >= 0) & (c < n)
            cc = jnp.clip(c, 0, n - 1)
            return jnp.where(valid, base + cc * stride[dim],
                             PROC_NULL).astype(jnp.int32)

        return side(-disp), side(+disp)

    def cart_shift_perm(self, dim: int, disp: int = 1) -> list[tuple[int, int]]:
        """Static (src, dst) pairs of a shift — the SPMD pattern form.

        The whole-group counterpart of :meth:`cart_shift` (DESIGN.md §2:
        communication patterns are static): every rank's send is one pair;
        pairs whose destination falls off a non-periodic boundary are
        dropped (their receivers get ppermute zeros — null semantics).

        Args:
            dim: dimension index to shift along.
            disp: displacement (may be negative or exceed the extent).
        Returns:
            Injective pair list consumable by ``sendrecv``/``ppermute``.
        """
        pairs = []
        for r in range(self.size()):
            coords = list(_unflatten(r, self.dims))
            c = coords[dim] + disp
            if self.periods[dim]:
                c %= self.dims[dim]
            elif not 0 <= c < self.dims[dim]:
                continue
            coords[dim] = c
            pairs.append((r, _flatten(coords, self.dims)))
        return pairs

    def neighbor_ranks(self, rank: int) -> list[int]:
        """Static neighbour list of ``rank`` in MPI-3 slot order.

        Args:
            rank: static Python rank.
        Returns:
            ``2·ndims`` ranks — (dim-0 −1, dim-0 +1, dim-1 −1, …), with
            :data:`PROC_NULL` where a non-periodic boundary has none.
        """
        out = []
        coords = _unflatten(rank, self.dims)
        for d in range(self.ndims):
            for disp in (-1, +1):
                c = coords[d] + disp
                if self.periods[d]:
                    c %= self.dims[d]
                elif not 0 <= c < self.dims[d]:
                    out.append(PROC_NULL)
                    continue
                cs = list(coords)
                cs[d] = c
                out.append(_flatten(cs, self.dims))
        return out

    def cart_sub(self, remain_dims: Sequence[bool]) -> "CartComm":
        """Sub-grid communicator (MPI_Cart_sub).

        Ranks sharing coordinates on every *dropped* dimension form one
        group — obtained for free by keeping only the retained dims' mesh
        axes (jmpi's ``Comm_split`` semantics).

        Args:
            remain_dims: one bool per dimension; True = keep.
        Returns:
            A :class:`CartComm` over the retained dims (topology, periods
            and axis map sliced accordingly), inheriting this
            communicator's context.
        Raises:
            ValueError: wrong arity, or every retained dim is degenerate
                with no backing mesh axis (a group over zero axes cannot be
                expressed — keep at least one non-degenerate dim).
        """
        remain = tuple(bool(b) for b in remain_dims)
        if len(remain) != self.ndims:
            raise ValueError(
                f"expected {self.ndims} remain flags, got {remain!r}")
        keep = [d for d in range(self.ndims) if remain[d]]
        axes = tuple(a for d in keep for a in self.axis_map[d])
        if not axes:
            raise ValueError(
                "cart_sub would retain only degenerate dims backed by no "
                "mesh axis; keep at least one dimension that spans an axis")
        return CartComm(
            axes=axes, context=self.context,
            dims=tuple(self.dims[d] for d in keep),
            periods=tuple(self.periods[d] for d in keep),
            axis_map=tuple(self.axis_map[d] for d in keep))

    # -- neighborhood collectives (jmpi 2.0 method surface) ----------------
    def neighbor_allgather(self, x, *, token=None, algorithm=None):
        """Gather ``x`` from the 2·ndims Cartesian neighbours
        (MPI_Neighbor_allgather).

        Args:
            x: payload array/View (identical static shape on every rank).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force (``xla_native`` | ``ring``).
        Returns:
            ``(status, out)`` with ``out`` of shape ``(2·ndims, *x.shape)``
            in MPI-3 slot order (zeros at null neighbours); plus the token
            when one was passed explicitly.
        """
        return neighbor_allgather(x, comm=self, token=token,
                                  algorithm=algorithm)

    def neighbor_alltoall(self, x, *, token=None, algorithm=None):
        """Per-neighbour exchange (MPI_Neighbor_alltoall).

        Args:
            x: ``(2·ndims, ...)`` stacked send slots — slot ``2d`` to the
                −1 neighbour of dim ``d``, slot ``2d+1`` to the +1.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force (``xla_native`` | ``ring``).
        Returns:
            ``(status, out)`` — slot ``k`` of ``out`` holds what neighbour
            ``k`` sent to *us* (zeros at null neighbours); plus the token
            when one was passed explicitly.
        """
        return neighbor_alltoall(x, comm=self, token=token,
                                 algorithm=algorithm)

    def neighbor_alltoallv(self, xs, *, token=None, algorithm=None):
        """Vector variant: per-neighbour payloads of distinct static shapes
        (MPI_Neighbor_alltoallv).

        Args:
            xs: sequence of 2·ndims arrays/Views (one per slot, shared
                dtype); the shape of slot ``k``'s *receive* is the static
                shape of the mirror slot it was sent from.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force (``xla_native`` | ``ring``).
        Returns:
            ``(status, [recv_0, …])`` — list in slot order; plus the token
            when one was passed explicitly.
        """
        return neighbor_alltoallv(xs, comm=self, token=token,
                                  algorithm=algorithm)

    def ineighbor_allgather(self, x, *, token=None, algorithm=None,
                            tag: int = 0) -> Request:
        """Nonblocking :meth:`neighbor_allgather`
        (MPI_Ineighbor_allgather).

        Args:
            x: payload array/View.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force.
            tag: tag recorded on the Request (for ``wait(..., tag=)``).
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        return ineighbor_allgather(x, comm=self, token=token,
                                   algorithm=algorithm, tag=tag)

    def ineighbor_alltoall(self, x, *, token=None, algorithm=None,
                           tag: int = 0) -> Request:
        """Nonblocking :meth:`neighbor_alltoall` (MPI_Ineighbor_alltoall).

        Args:
            x: ``(2·ndims, ...)`` stacked send slots.
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request`; complete via ``wait*``/``test*``.
        """
        return ineighbor_alltoall(x, comm=self, token=token,
                                  algorithm=algorithm, tag=tag)

    def ineighbor_alltoallv(self, xs, *, token=None, algorithm=None,
                            tag: int = 0) -> Request:
        """Nonblocking :meth:`neighbor_alltoallv`
        (MPI_Ineighbor_alltoallv).

        Args:
            xs: sequence of 2·ndims arrays/Views (shared dtype).
            token: explicit ordering token; None uses the ambient chain.
            algorithm: registry entry to force.
            tag: tag recorded on the Request.
        Returns:
            A unified :class:`Request` whose completion value is the slot
            list.
        """
        return ineighbor_alltoallv(xs, comm=self, token=token,
                                   algorithm=algorithm, tag=tag)

    def neighbor_allgather_init(self, shape_dtype, *, algorithm=None):
        """Persistent :meth:`neighbor_allgather`
        (MPI_Neighbor_allgather_init).

        Args:
            shape_dtype: payload signature (ShapeDtypeStruct / array /
                ``(shape, dtype)``).
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`~repro.core.plans.Plan`;
            ``plan.start(x) -> Request``.
        """
        from repro.core import plans
        return plans.neighbor_allgather_init(shape_dtype, comm=self,
                                             algorithm=algorithm)

    def neighbor_alltoall_init(self, shape_dtype, *, algorithm=None):
        """Persistent :meth:`neighbor_alltoall`
        (MPI_Neighbor_alltoall_init).

        Args:
            shape_dtype: the stacked ``(2·ndims, ...)`` payload signature.
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`~repro.core.plans.Plan`;
            ``plan.start(x) -> Request``.
        """
        from repro.core import plans
        return plans.neighbor_alltoall_init(shape_dtype, comm=self,
                                            algorithm=algorithm)

    def neighbor_alltoallv_init(self, shape_dtypes, *, algorithm=None):
        """Persistent :meth:`neighbor_alltoallv`
        (MPI_Neighbor_alltoallv_init).

        Args:
            shape_dtypes: sequence of 2·ndims per-slot signatures (shared
                dtype).
            algorithm: registry entry to freeze; None → policy choice.
        Returns:
            A cached :class:`~repro.core.plans.Plan` whose ``start(xs)``
            takes the slot list and whose Request completes with the
            received slot list.
        """
        from repro.core import plans
        return plans.neighbor_alltoallv_init(shape_dtypes, comm=self,
                                             algorithm=algorithm)


def cart_create(dims: Sequence[int],
                periods: Sequence[bool] | None = None,
                reorder: bool = False, *,
                comm: Communicator | None = None) -> CartComm:
    """Attach a Cartesian topology to ``comm`` (MPI_Cart_create).

    Args:
        dims: grid extents, one per dimension; their product must equal
            ``comm.size()`` and each dim must factor as a consecutive run
            of the comm's mesh axes (row-major rank order is shared).
        periods: per-dim periodicity (default: all False, as in MPI).
        reorder: accepted and ignored — under SPMD the rank order is fixed
            by the mesh; there is no runtime renumbering to exploit.
        comm: parent communicator (None = ambient WORLD).
    Returns:
        A :class:`CartComm` over the same group.
    Raises:
        ValueError: empty/ill-sized ``dims`` or ``periods``, or ``dims``
            that do not factor the communicator's axis sizes.
    """
    del reorder
    comm = resolve(comm)
    dims = tuple(int(d) for d in dims)
    if not dims or any(d < 1 for d in dims):
        raise ValueError(f"dims must be positive and non-empty, got {dims}")
    if math.prod(dims) != comm.size():
        raise ValueError(f"prod(dims)={math.prod(dims)} != comm size "
                         f"{comm.size()}")
    periods = (tuple(bool(p) for p in periods) if periods is not None
               else (False,) * len(dims))
    if len(periods) != len(dims):
        raise ValueError(f"periods arity {len(periods)} != dims arity "
                         f"{len(dims)}")
    axis_map = _factor_axes(comm.axes, comm.axis_sizes(), dims)
    return CartComm(axes=comm.axes, context=comm.context, dims=dims,
                    periods=periods, axis_map=axis_map)


def _require_cart(comm) -> CartComm:
    if not isinstance(comm, CartComm):
        raise TypeError(
            f"neighborhood collectives need a CartComm (got {type(comm).__name__}); "
            f"attach a topology first: comm.cart_create(dims, periods)")
    return comm


# ---------------------------------------------------------------------------
# Registered lowerings.  Kernel contract (repro.core.registry): payload is
# packed and token-tied by the public op; thread the token through every hop.
# ---------------------------------------------------------------------------

def _is_cart(val, comm, **kw):
    return isinstance(comm, CartComm)


def _ring_fwd(cart: CartComm, dim: int) -> list[tuple[int, int]]:
    """Full +1 ring pairs along ``dim`` including the wrap link — the ring
    lowering's *transport* pattern.  Non-periodic semantics are restored by
    masking boundary receives to zeros (the emulated/XLA transport is fully
    connected, so using the wrap link costs nothing semantically)."""
    pairs = []
    for r in range(cart.size()):
        coords = list(_unflatten(r, cart.dims))
        coords[dim] = (coords[dim] + 1) % cart.dims[dim]
        pairs.append((r, _flatten(coords, cart.dims)))
    return pairs


def _mask_boundary(cart: CartComm, dim: int, edge_coord, x):
    """Zero ``x`` on devices whose coord along ``dim`` equals ``edge_coord``
    (null-rank semantics for the ring lowering's masked wrap hop)."""
    coord = cart.cart_coords()[dim]
    return jnp.where(jnp.asarray(coord) == edge_coord, jnp.zeros_like(x), x)


def _hop(cart: CartComm, perm, x, tok):
    """One token-tied ppermute along a static pattern."""
    tok, x = token_lib.tie(tok, x)
    out = jax.lax.ppermute(x, cart.axes, perm)
    tok = token_lib.advance(tok, out)
    return out, tok


def _dim_exchange_shifts(cart, d, send_minus, send_plus, tok):
    """Both directions of dim ``d`` as two shift permutes (xla_native).

    Returns (from_minus, from_plus, tok): what arrived from the −1 / +1
    neighbour (zeros at non-periodic boundaries — the dropped perm pairs).
    """
    from_minus, tok = _hop(cart, cart.cart_shift_perm(d, +1), send_plus, tok)
    from_plus, tok = _hop(cart, cart.cart_shift_perm(d, -1), send_minus, tok)
    return from_minus, from_plus, tok


def _dim_exchange_ring(cart, d, send_minus, send_plus, tok):
    """Both directions of dim ``d`` over ONE forward ring (p2p-fused).

    ``send_plus`` reaches the +1 neighbour in one forward hop; ``send_minus``
    reaches the −1 neighbour by travelling the remaining n−1 forward hops —
    every message moves the same way around the torus (unidirectional-link
    schedule).  Non-periodic dims reuse the wrap link as transport and mask
    the boundary receives to zeros.
    """
    n = cart.dims[d]
    periodic = cart.periods[d]
    if n == 1:
        if periodic:  # self-neighbour: the exchange is a local swap
            return send_plus, send_minus, tok
        zeros = jnp.zeros_like(send_plus), jnp.zeros_like(send_minus)
        return zeros[0], zeros[1], tok
    fwd = _ring_fwd(cart, d)
    from_minus, tok = _hop(cart, fwd, send_plus, tok)
    if not periodic:
        from_minus = _mask_boundary(cart, d, 0, from_minus)
    cur = send_minus
    for _ in range(n - 1):
        cur, tok = _hop(cart, fwd, cur, tok)
    from_plus = cur
    if not periodic:
        from_plus = _mask_boundary(cart, d, n - 1, from_plus)
    return from_minus, from_plus, tok


# -- neighbor_allgather -----------------------------------------------------

@registry.register("neighbor_allgather", "xla_native", supports=_is_cart)
def _neighbor_allgather_shifts(val, tok, comm):
    """One ppermute shift per (dim, direction): 2·ndims hops of |x| each."""
    slots = []
    for d in range(comm.ndims):
        fm, fp, tok = _dim_exchange_shifts(comm, d, val, val, tok)
        slots += [fm, fp]
    return jnp.stack(slots), tok


@registry.register("neighbor_allgather", "ring", supports=_is_cart)
def _neighbor_allgather_ring(val, tok, comm):
    """Forward-ring lowering: circulate ``val`` n−1 hops per dim, plucking
    the −1 neighbour's copy at hop 1 and the +1 neighbour's at hop n−1."""
    slots = [None] * (2 * comm.ndims)
    for d in range(comm.ndims):
        n = comm.dims[d]
        periodic = comm.periods[d]
        if n == 1:
            z = val if periodic else jnp.zeros_like(val)
            slots[2 * d], slots[2 * d + 1] = z, z
            continue
        fwd = _ring_fwd(comm, d)
        cur = val
        for i in range(1, n):
            cur, tok = _hop(comm, fwd, cur, tok)
            if i == 1:
                fm = cur if periodic else _mask_boundary(comm, d, 0, cur)
                slots[2 * d] = fm
            if i == n - 1:
                fp = cur if periodic else _mask_boundary(comm, d, n - 1, cur)
                slots[2 * d + 1] = fp
    return jnp.stack(slots), tok


# -- neighbor_alltoall ------------------------------------------------------

def _natoa_supports(val, comm, **kw):
    return (isinstance(comm, CartComm)
            and val.ndim >= 1 and val.shape[0] == 2 * comm.ndims)


@registry.register("neighbor_alltoall", "xla_native", supports=_natoa_supports)
def _neighbor_alltoall_shifts(val, tok, comm):
    """Per dim: slot 2d+1 rides the +1 shift (landing as the receiver's
    from-minus slot), slot 2d rides the −1 shift."""
    slots = []
    for d in range(comm.ndims):
        fm, fp, tok = _dim_exchange_shifts(comm, d, val[2 * d],
                                           val[2 * d + 1], tok)
        slots += [fm, fp]
    return jnp.stack(slots), tok


@registry.register("neighbor_alltoall", "ring", supports=_natoa_supports)
def _neighbor_alltoall_ring(val, tok, comm):
    """Forward-ring lowering (see :func:`_dim_exchange_ring`)."""
    slots = []
    for d in range(comm.ndims):
        fm, fp, tok = _dim_exchange_ring(comm, d, val[2 * d],
                                         val[2 * d + 1], tok)
        slots += [fm, fp]
    return jnp.stack(slots), tok


# -- neighbor_alltoallv (flat-packed slots; shapes are static kwargs) -------

def _slot_sizes(slot_shapes):
    return [int(np.prod(s, dtype=int)) for s in slot_shapes]


def _split_slots(flat, slot_shapes):
    out, off = [], 0
    for shp, n in zip(slot_shapes, _slot_sizes(slot_shapes)):
        out.append(flat[off:off + n].reshape(shp))
        off += n
    return out


def _mirror(k: int) -> int:
    """Mirror slot: my −1 neighbour's +1 slot is addressed to me, and vice
    versa — recv slot k has the static shape of send slot mirror(k)."""
    return k + 1 if k % 2 == 0 else k - 1


def _natoav_supports(val, comm, *, slot_shapes=(), **kw):
    return (isinstance(comm, CartComm)
            and len(slot_shapes) == 2 * comm.ndims
            and val.size == sum(_slot_sizes(slot_shapes)))


def _natoav_kernel(exchange):
    def kernel(val, tok, comm, *, slot_shapes):
        slots = _split_slots(val, slot_shapes)
        recv = []
        for d in range(comm.ndims):
            fm, fp, tok = exchange(comm, d, slots[2 * d], slots[2 * d + 1],
                                   tok)
            recv += [fm, fp]
        return jnp.concatenate([r.reshape(-1) for r in recv]), tok
    return kernel


registry.register("neighbor_alltoallv", "xla_native",
                  supports=_natoav_supports)(
    _natoav_kernel(_dim_exchange_shifts))
registry.register("neighbor_alltoallv", "ring",
                  supports=_natoav_supports)(
    _natoav_kernel(_dim_exchange_ring))


# ---------------------------------------------------------------------------
# Node-aware two-level hierarchical allreduce (registry entry).
# ---------------------------------------------------------------------------

def _hier_supports(val, comm, *, op=None, **kw):
    if len(comm.axes) < 2 or val.ndim < 1:
        return False
    intra = int(jax.lax.psum(1, comm.axes[-1]))
    return intra > 0 and val.shape[0] % intra == 0


@registry.register("allreduce", "hierarchical", supports=_hier_supports,
                   operators=(Operator.SUM,))
def _hierarchical_allreduce(val, tok, comm, *, op=None):
    """Two-level node-aware allreduce: reduce-scatter inside the fast group
    (last mesh axis — intra-node), allreduce the owned shard across groups
    (remaining axes — inter-node), allgather the shards back inside the
    group.  Only 1/intra of the payload crosses the slow inter-group links
    — the classic SMP/SHARP-style schedule.  Groups come from
    ``comm.split``; needs ≥2 mesh axes and axis-0 divisibility by the
    intra-group size."""
    intra = comm.split(comm.axes[-1:])
    inter = comm.split(comm.axes[:-1])
    shard = jax.lax.psum_scatter(val, intra.axes, scatter_dimension=0,
                                 tiled=True)
    shard = jax.lax.psum(shard, inter.axes)
    out = jax.lax.all_gather(shard, intra.axes, axis=0, tiled=True)
    return out, tok


# ---------------------------------------------------------------------------
# Public ops — blocking / nonblocking, sharing the collective dispatch path
# (pack → registry.select → token tie → kernel → Request).
# ---------------------------------------------------------------------------

def ineighbor_allgather(x, *, comm: Communicator | None = None, token=None,
                        algorithm: str | None = None, tag: int = 0) -> Request:
    """MPI_Ineighbor_allgather: start gathering the 2·ndims neighbours'
    payloads; complete via the unified ``wait*``/``test*``.

    Args:
        x: payload array/View.
        comm: a :class:`CartComm` (None resolves the ambient WORLD, which
            must carry a topology).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force (``xla_native`` | ``ring``).
        tag: tag recorded on the Request.
    Returns:
        :class:`Request` completing with ``(2·ndims, *x.shape)``.
    Raises:
        TypeError: the communicator has no Cartesian topology.
    """
    from repro.core import collectives as _coll
    cart = _require_cart(resolve(comm))
    req, _ = _coll._issue("neighbor_allgather", x, comm=cart, token=token,
                          algorithm=algorithm, tag=tag)
    return req


def neighbor_allgather(x, *, comm: Communicator | None = None, token=None,
                       algorithm: str | None = None):
    """MPI_Neighbor_allgather: blocking form of
    :func:`ineighbor_allgather`.

    Args:
        x: payload array/View.
        comm: a :class:`CartComm` (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force.
    Returns:
        ``(status, out)`` — or ``(status, out, token)`` with an explicit
        token; ``out`` is ``(2·ndims, *x.shape)`` in MPI-3 slot order.
    Raises:
        TypeError: the communicator has no Cartesian topology.
    """
    from repro.core import collectives as _coll
    explicit = token is not None
    req = ineighbor_allgather(x, comm=comm, token=token, algorithm=algorithm)
    return _coll._finish(req, explicit)


def ineighbor_alltoall(x, *, comm: Communicator | None = None, token=None,
                       algorithm: str | None = None, tag: int = 0) -> Request:
    """MPI_Ineighbor_alltoall: start the per-neighbour exchange of the
    stacked slots; complete via the unified ``wait*``/``test*``.

    Args:
        x: ``(2·ndims, ...)`` stacked send slots (slot 2d → −1 neighbour of
            dim d, slot 2d+1 → +1 neighbour).
        comm: a :class:`CartComm` (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force (``xla_native`` | ``ring``).
        tag: tag recorded on the Request.
    Returns:
        :class:`Request` completing with the same-shape received stack.
    Raises:
        TypeError: no Cartesian topology; ValueError: axis 0 != 2·ndims.
    """
    from repro.core import collectives as _coll
    cart = _require_cart(resolve(comm))
    val = views_lib.pack(x)
    if val.ndim < 1 or val.shape[0] != cart.neighbor_count:
        raise ValueError(
            f"neighbor_alltoall payload axis 0 must be 2*ndims = "
            f"{cart.neighbor_count}, got shape {tuple(val.shape)}")
    req, _ = _coll._issue("neighbor_alltoall", val, comm=cart, token=token,
                          algorithm=algorithm, tag=tag)
    return req


def neighbor_alltoall(x, *, comm: Communicator | None = None, token=None,
                      algorithm: str | None = None):
    """MPI_Neighbor_alltoall: blocking form of :func:`ineighbor_alltoall`.

    Args:
        x: ``(2·ndims, ...)`` stacked send slots.
        comm: a :class:`CartComm` (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force.
    Returns:
        ``(status, out)`` — or ``(status, out, token)`` with an explicit
        token; slot ``k`` of ``out`` is what neighbour ``k`` sent here.
    Raises:
        TypeError / ValueError: as :func:`ineighbor_alltoall`.
    """
    from repro.core import collectives as _coll
    explicit = token is not None
    req = ineighbor_alltoall(x, comm=comm, token=token, algorithm=algorithm)
    return _coll._finish(req, explicit)


def recv_slot_shapes(slot_shapes) -> tuple:
    """Receive-side slot shapes of a neighbor_alltoallv: slot ``k`` arrives
    from neighbour ``k``, which sent its mirror slot — so the static shape
    is ``slot_shapes[mirror(k)]``.

    Args:
        slot_shapes: send-side per-slot shapes, in slot order.
    Returns:
        The mirrored shape tuple (receive-side, same order).
    """
    return tuple(tuple(slot_shapes[_mirror(k)])
                 for k in range(len(slot_shapes)))


def check_slots(cart: CartComm, slots):
    """Validate a neighbor_alltoallv slot list (shared by the direct path
    and the persistent-plan path so the rules cannot drift).

    Args:
        cart: the Cartesian communicator the slots address.
        slots: 2·ndims payloads — anything with ``.shape``/``.dtype``
            (concrete arrays or ShapeDtypeStructs).
    Returns:
        The shared jnp dtype.
    Raises:
        ValueError: wrong slot count or mixed dtypes.
    """
    if len(slots) != cart.neighbor_count:
        raise ValueError(f"neighbor_alltoallv needs 2*ndims = "
                         f"{cart.neighbor_count} slots, got {len(slots)}")
    dtypes = {jnp.dtype(s.dtype) for s in slots}
    if len(dtypes) != 1:
        raise ValueError(f"neighbor_alltoallv slots must share one dtype, "
                         f"got {sorted(map(str, dtypes))}")
    return next(iter(dtypes))


def _pack_slots(cart: CartComm, xs):
    """Slot list → (flat wire vector, per-slot shapes) via the Slots
    datatype (one packing pipeline with the persistent-plan path)."""
    slots = [views_lib.pack(x) for x in xs]
    dtype = check_slots(cart, slots)
    shapes = tuple(tuple(s.shape) for s in slots)
    return datatypes_lib.slots(shapes, dtype).pack(slots), shapes


def ineighbor_alltoallv(xs, *, comm: Communicator | None = None, token=None,
                        algorithm: str | None = None, tag: int = 0) -> Request:
    """MPI_Ineighbor_alltoallv: start the vector per-neighbour exchange;
    complete via the unified ``wait*``/``test*``.

    Args:
        xs: sequence of 2·ndims arrays/Views (one per slot, shared dtype;
            shapes may differ per slot but are identical across ranks —
            static counts, the SPMD reading of the v-variant).
        comm: a :class:`CartComm` (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force (``xla_native`` | ``ring``).
        tag: tag recorded on the Request.
    Returns:
        :class:`Request` whose completion value is the received slot list
        (slot ``k`` shaped like the mirror slot, see
        :func:`recv_slot_shapes`).
    Raises:
        TypeError: no Cartesian topology; ValueError: wrong slot count or
            mixed dtypes.
    """
    from repro.core import collectives as _coll
    cart = _require_cart(resolve(comm))
    flat, shapes = _pack_slots(cart, xs)
    recv_dt = datatypes_lib.slots(recv_slot_shapes(shapes),
                                  jnp.dtype(flat.dtype))
    req, _ = _coll._issue("neighbor_alltoallv", flat, comm=cart, token=token,
                          algorithm=algorithm, tag=tag, slot_shapes=shapes,
                          recv=recv_dt.bind(None))
    return req


def neighbor_alltoallv(xs, *, comm: Communicator | None = None, token=None,
                       algorithm: str | None = None):
    """MPI_Neighbor_alltoallv: blocking form of
    :func:`ineighbor_alltoallv`.

    Args:
        xs: sequence of 2·ndims arrays/Views (shared dtype).
        comm: a :class:`CartComm` (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force.
    Returns:
        ``(status, [recv_0, …])`` — or ``(status, values, token)`` with an
        explicit token.
    Raises:
        TypeError / ValueError: as :func:`ineighbor_alltoallv`.
    """
    from repro.core.p2p import wait
    explicit = token is not None
    req = ineighbor_alltoallv(xs, comm=comm, token=token, algorithm=algorithm)
    status, values = wait(req)
    if explicit:
        return status, values, req.token
    return status, values
