"""MPI derived-datatype algebra for jmpi payloads (paper §2.3, Listing 6).

The paper's usability claim — numba-mpi is "built around Numpy arrays
including handling of non-contiguous views over array slices" — is an MPI
*datatype* story: `MPI_Type_vector`, `MPI_Type_create_subarray` and friends
let a call site describe a non-contiguous region once and have the library
pack/unpack it at the transfer boundary.  This module is that layer for
jmpi: a :class:`Datatype` describes a typed memory layout and provides
trace-time ``pack``/``unpack`` lowerings (gathers/scatters XLA fuses into
the transfer's prologue/epilogue — the functional-array equivalent of MPI's
zero-copy datatype engine).

Constructors (mirroring the MPI type constructors):

* :func:`contiguous` — ``MPI_Type_contiguous``: a dense run of elements;
* :func:`vector` — ``MPI_Type_vector``: equally-spaced, equally-sized
  blocks of a flat buffer (strided columns, interleaved channels);
* :func:`subarray` — ``MPI_Type_create_subarray``: a rectangular block of
  an n-d array (halo faces, tile interiors); :func:`face` is the halo-slab
  special case;
* :func:`indexed` — ``MPI_Type_indexed``: ragged blocks of a flat buffer
  at arbitrary displacements (the v-variant payload layout);
* :class:`Slots` — the indexed layout over a *list* of per-slot arrays
  (what ``neighbor_alltoallv`` and the classic v-collectives carry);
* :func:`pytree` — beyond MPI: one datatype for a whole pytree of arrays
  (gradient trees), packing every leaf into one wire vector.

Uniform payload pipeline
------------------------
Every jmpi op accepts ``(payload, datatype)``: either pass ``datatype=`` to
the op, or hand the op a **bound** payload — ``dt.bind(x)`` — which works
anywhere an array is accepted (communicator methods, ``plan.start``,
``recv_into=``).  The single entry points are :func:`pack_payload` (send
side) and :func:`recv_adapter` (receive side); the blocking, nonblocking
and persistent paths all flow through them, so pack/unpack rules cannot
drift between paths.  ``views.View`` is sugar over :func:`subarray`
(see ``repro.core.views``).

Receive semantics are MPI's: completing a transfer into a bound datatype
writes the first ``min(message, extent)`` elements (row-major over the
datatype's layout); a statically larger message truncates —
``ERR_TRUNCATE`` on the request status — and a smaller one leaves the
remaining slots' prior contents in place.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _prod(shape) -> int:
    return int(np.prod(shape, dtype=int))


# ---------------------------------------------------------------------------
# Base + bound adapter
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Datatype:
    """Base class: a typed memory layout with pack/unpack lowerings.

    Subclasses define ``packed_shape`` (shape of the contiguous message),
    ``pack(buf)`` and ``unpack(message, into=...)``; everything else
    (``count``, ``bind``, truncation-aware ``scatter_into``, signature
    helpers) is shared.  ``dtype`` is the element dtype when statically
    known (None = inherit the buffer's).
    """

    dtype: Any = None

    # -- layout interface (subclass responsibility) ------------------------
    @property
    def packed_shape(self) -> tuple:
        """Static shape of the packed contiguous message."""
        raise NotImplementedError

    def pack(self, buf):
        """Materialize the described region of ``buf`` as one contiguous
        message (the send-side lowering; XLA fuses it into the transfer).

        Args:
            buf: the enclosing payload this datatype describes.
        Returns:
            A jnp array of :attr:`packed_shape`.
        """
        raise NotImplementedError

    def unpack(self, message, into=None):
        """Scatter a packed ``message`` back through the layout.

        Args:
            message: buffer shaped like (or reshapable to)
                :attr:`packed_shape`.
            into: the enclosing payload to write into.  Datatypes that
                fully cover their extent (contiguous, Slots, pytree) accept
                ``into=None`` and rebuild the payload from the message
                alone; sparse layouts (vector, subarray, indexed) require
                it.
        Returns:
            The payload with the message's elements in the described slots
            (equal to ``into`` elsewhere).
        """
        raise NotImplementedError

    # -- shared surface ----------------------------------------------------
    @property
    def covers_extent(self) -> bool:
        """True when the layout fully covers its extent, so a received
        message alone rebuilds the payload (no target buffer needed) —
        Slots and Pytree; sparse layouts (vector/subarray/indexed and the
        shape-erasing contiguous) must be bound to a buffer first."""
        return False

    @property
    def count(self) -> int:
        """Packed element count (the datatype's transfer size)."""
        return _prod(self.packed_shape)

    def struct(self, dtype=None) -> jax.ShapeDtypeStruct:
        """Signature of the packed message (for ``*_init`` plans).

        Args:
            dtype: element dtype override (required when the datatype has
                no static dtype of its own).
        Returns:
            ``jax.ShapeDtypeStruct(packed_shape, dtype)``.
        Raises:
            ValueError: no dtype available from either source.
        """
        dt = dtype if dtype is not None else self.dtype
        if dt is None:
            raise ValueError(f"{type(self).__name__} has no static dtype; "
                             f"pass dtype= to struct()")
        return jax.ShapeDtypeStruct(tuple(self.packed_shape), jnp.dtype(dt))

    def bind(self, buf) -> "Bound":
        """Attach this layout to a concrete payload.

        The returned :class:`Bound` value is accepted anywhere jmpi takes a
        payload (``pack`` protocol) or a receive target (``scatter_into``
        protocol) — the universal ``(payload, datatype)`` form.

        Args:
            buf: the enclosing array (or slot list / pytree).
        Returns:
            The :class:`Bound` adapter.
        """
        return Bound(datatype=self, buf=buf)

    def scatter_into(self, buf, message):
        """MPI-recv write of ``message`` into ``buf`` through this layout.

        The first ``min(message.size, count)`` elements land (row-major
        over the layout); a longer message's tail is dropped (the
        ERR_TRUNCATE condition — reported by the request's status, not
        here) and a shorter one leaves the remaining slots untouched.
        One uniform signature across the whole hierarchy: fully-covering
        layouts (Slots, Pytree) override this accepting ``buf=None``.

        Args:
            buf: the enclosing payload (None allowed only when
                :attr:`covers_extent`).
            message: the received contiguous buffer.
        Returns:
            The updated payload.
        """
        cur = self.pack(buf)
        m = jnp.ravel(jnp.asarray(message))[:cur.size]
        if m.size < cur.size:
            flat = jnp.concatenate([m.astype(cur.dtype),
                                    cur.reshape(-1)[m.size:]])
        else:
            flat = m.astype(cur.dtype)
        return self.unpack(flat.reshape(cur.shape), into=buf)


@dataclasses.dataclass(frozen=True)
class Bound:
    """A (datatype, payload) pair — the uniform jmpi payload value.

    Send side: ``pack()`` materializes the contiguous message (the duck
    type :func:`pack_payload` recognizes).  Receive side: pass it as
    ``recv_into=`` — ``scatter_into(message)`` applies the datatype's
    MPI-recv truncation semantics to the bound buffer.
    """

    datatype: Datatype
    buf: Any

    def pack(self):
        """The bound payload's contiguous message (send-side lowering)."""
        return self.datatype.pack(self.buf)

    def scatter_into(self, message):
        """Write a received ``message`` into the bound buffer (MPI-recv
        semantics: leading elements land, extra slots keep prior contents).

        Args:
            message: the received contiguous buffer.
        Returns:
            The updated enclosing payload.
        """
        return self.datatype.scatter_into(self.buf, message)

    @property
    def count(self) -> int:
        """Packed element count of the bound datatype."""
        return self.datatype.count

    @property
    def shape(self) -> tuple:
        """Shape of the packed message."""
        return tuple(self.datatype.packed_shape)


# ---------------------------------------------------------------------------
# Contiguous
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Contiguous(Datatype):
    """``MPI_Type_contiguous``: a dense run of ``n`` elements."""

    n: int = 0

    @property
    def packed_shape(self) -> tuple:
        """``(n,)``."""
        return (self.n,)

    def pack(self, buf):
        """Flatten ``buf`` (must hold exactly ``n`` elements).

        Args:
            buf: payload with ``buf.size == n``.
        Returns:
            The ``(n,)`` message.
        Raises:
            ValueError: element-count mismatch.
        """
        x = jnp.asarray(buf)
        if _prod(x.shape) != self.n:
            raise ValueError(f"contiguous({self.n}) cannot pack a payload "
                             f"of shape {tuple(x.shape)} "
                             f"({_prod(x.shape)} elements)")
        return x.reshape(self.n)

    def unpack(self, message, into=None):
        """Reshape the message back to the payload's shape.

        Args:
            message: the ``(n,)`` (or reshapable) message.
            into: optional payload supplying shape/dtype (None → the flat
                ``(n,)`` vector itself).
        Returns:
            The reconstructed payload.
        """
        m = jnp.asarray(message).reshape(self.n)
        if into is None:
            return m if self.dtype is None else m.astype(self.dtype)
        x = jnp.asarray(into)
        return m.reshape(x.shape).astype(x.dtype)


def contiguous(n: int, dtype=None) -> Contiguous:
    """``MPI_Type_contiguous(n)``: a dense run of ``n`` elements.

    Args:
        n: element count.
        dtype: optional static element dtype.
    Returns:
        The :class:`Contiguous` datatype.
    """
    return Contiguous(dtype=None if dtype is None else jnp.dtype(dtype),
                      n=int(n))


# ---------------------------------------------------------------------------
# Vector (equally-spaced blocks of a flat buffer)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Vector(Datatype):
    """``MPI_Type_vector``: ``n_blocks`` blocks of ``blocklen`` elements,
    the starts ``stride`` elements apart, over a flat (raveled) buffer."""

    n_blocks: int = 0
    blocklen: int = 1
    stride: int = 1

    def __post_init__(self):
        if self.blocklen > self.stride:
            raise ValueError(f"vector blocklen {self.blocklen} exceeds "
                             f"stride {self.stride} (blocks would overlap)")

    @property
    def extent(self) -> int:
        """Minimum flat-buffer length the layout spans."""
        if self.n_blocks == 0:
            return 0
        return (self.n_blocks - 1) * self.stride + self.blocklen

    @property
    def packed_shape(self) -> tuple:
        """``(n_blocks * blocklen,)``."""
        return (self.n_blocks * self.blocklen,)

    def _indices(self) -> np.ndarray:
        starts = np.arange(self.n_blocks) * self.stride
        return (starts[:, None] + np.arange(self.blocklen)).reshape(-1)

    def pack(self, buf):
        """Gather the strided blocks from the raveled buffer.

        Args:
            buf: payload with at least :attr:`extent` elements.
        Returns:
            The ``(n_blocks·blocklen,)`` message.
        Raises:
            ValueError: the buffer is too short for the layout.
        """
        flat = jnp.asarray(buf).reshape(-1)
        if flat.shape[0] < self.extent:
            raise ValueError(f"vector extent {self.extent} exceeds buffer "
                             f"size {flat.shape[0]}")
        return flat[self._indices()]

    def unpack(self, message, into=None):
        """Scatter the message back into the strided blocks of ``into``.

        Args:
            message: the packed message.
            into: the enclosing buffer (required — the layout is sparse).
        Returns:
            ``into`` with the blocks replaced.
        Raises:
            ValueError: ``into`` is None.
        """
        if into is None:
            raise ValueError("vector.unpack needs into= (sparse layout)")
        x = jnp.asarray(into)
        flat = x.reshape(-1)
        m = jnp.asarray(message).reshape(self.packed_shape).astype(x.dtype)
        return flat.at[self._indices()].set(m).reshape(x.shape)


def vector(n_blocks: int, blocklen: int, stride: int, dtype=None) -> Vector:
    """``MPI_Type_vector(count, blocklen, stride)`` over a flat buffer.

    Args:
        n_blocks: number of blocks.
        blocklen: elements per block.
        stride: elements between block starts (``>= blocklen``).
        dtype: optional static element dtype.
    Returns:
        The :class:`Vector` datatype.
    Raises:
        ValueError: overlapping blocks (``blocklen > stride``).
    """
    return Vector(dtype=None if dtype is None else jnp.dtype(dtype),
                  n_blocks=int(n_blocks), blocklen=int(blocklen),
                  stride=int(stride))


# ---------------------------------------------------------------------------
# Subarray (rectangular block of an n-d array; general slices)
# ---------------------------------------------------------------------------

def _norm_slice(s: slice, dim: int) -> tuple[int, int, int]:
    start, stop, step = s.indices(dim)
    return (start, stop, step)


def _slice_len(start: int, stop: int, step: int) -> int:
    return len(range(start, stop, step))


@dataclasses.dataclass(frozen=True)
class Subarray(Datatype):
    """``MPI_Type_create_subarray`` generalized to arbitrary static slices
    (including negative steps) with optional squeezed (integer-indexed)
    axes — the layout behind ``views.View``.

    ``index`` holds one resolved ``(start, stop, step)`` triple per array
    dimension; ``squeeze`` lists dimensions that integer indices removed
    from the packed message.
    """

    full_shape: tuple = ()
    index: tuple = ()
    squeeze: tuple = ()

    @property
    def sub_shape(self) -> tuple:
        """Per-dimension selected lengths (before squeezing)."""
        return tuple(_slice_len(*tr) for tr in self.index)

    @property
    def packed_shape(self) -> tuple:
        """The selected block's shape with squeezed axes removed."""
        return tuple(n for d, n in enumerate(self.sub_shape)
                     if d not in self.squeeze)

    def _slices(self) -> tuple:
        return tuple(slice(start, (None if (step < 0 and stop < 0) else stop),
                           step)
                     for (start, stop, step) in self.index)

    def pack(self, buf):
        """Slice the described block out of ``buf``.

        Args:
            buf: array of :attr:`full_shape`.
        Returns:
            The dense block, squeezed axes removed.
        Raises:
            ValueError: the buffer's shape is not :attr:`full_shape`.
        """
        x = jnp.asarray(buf)
        if tuple(x.shape) != tuple(self.full_shape):
            raise ValueError(f"subarray of {tuple(self.full_shape)} cannot "
                             f"pack a payload of shape {tuple(x.shape)}")
        out = x[self._slices()]
        if self.squeeze:
            out = out.reshape(self.packed_shape)
        return out

    def unpack(self, message, into=None):
        """Write the block back into ``into`` at its described position.

        Args:
            message: the dense block (packed shape).
            into: the enclosing array (required — the layout is sparse).
        Returns:
            ``into`` with the block replaced.
        Raises:
            ValueError: ``into`` is None.
        """
        if into is None:
            raise ValueError("subarray.unpack needs into= (sparse layout)")
        x = jnp.asarray(into)
        m = jnp.asarray(message).reshape(self.sub_shape).astype(x.dtype)
        return x.at[self._slices()].set(m)


def subarray(full_shape, sub_shape, starts, dtype=None) -> Subarray:
    """``MPI_Type_create_subarray``: a unit-stride rectangular block.

    Args:
        full_shape: shape of the enclosing array.
        sub_shape: shape of the block (same arity).
        starts: per-dimension block offsets (same arity).
    Returns:
        The :class:`Subarray` datatype.
    Raises:
        ValueError: arity mismatch or a block that exceeds the array.
    """
    full = tuple(int(d) for d in full_shape)
    sub = tuple(int(d) for d in sub_shape)
    off = tuple(int(d) for d in starts)
    if not (len(full) == len(sub) == len(off)):
        raise ValueError(f"subarray arity mismatch: full={full} sub={sub} "
                         f"starts={off}")
    for d, (n, m, s) in enumerate(zip(full, sub, off)):
        if s < 0 or m < 0 or s + m > n:
            raise ValueError(f"subarray dim {d}: block [{s}, {s + m}) "
                             f"outside array extent {n}")
    return Subarray(dtype=None if dtype is None else jnp.dtype(dtype),
                    full_shape=full,
                    index=tuple((s, s + m, 1) for s, m in zip(off, sub)))


def subarray_of(full_shape, index) -> Subarray:
    """Build a :class:`Subarray` from a NumPy-style index expression.

    Accepts what ``views.View`` accepts — a tuple of slices (any step,
    including negative) and integers (negative allowed; the dimension is
    squeezed out of the packed message).  Trailing unindexed dimensions
    are kept whole.

    Args:
        full_shape: shape of the enclosing array.
        index: tuple of slices/ints (or a single slice/int).
    Returns:
        The resolved :class:`Subarray`.
    Raises:
        TypeError: an index element is not a slice or int (``Ellipsis``,
            ``None``/newaxis and array indices are named explicitly).
        IndexError: too many indices or an integer out of range.
    """
    full = tuple(int(d) for d in full_shape)
    if not isinstance(index, tuple):
        index = (index,)
    if len(index) > len(full):
        raise IndexError(f"too many indices ({len(index)}) for shape {full}")
    triples, squeeze = [], []
    for d, dim in enumerate(full):
        if d >= len(index):
            triples.append((0, dim, 1))
            continue
        e = index[d]
        if e is Ellipsis:
            raise TypeError(
                "View/subarray indices do not support Ellipsis (...); "
                "spell out the per-dimension slices")
        if e is None:
            raise TypeError(
                "View/subarray indices do not support None/newaxis; the "
                "payload layout must keep the array's dimensionality")
        if isinstance(e, (np.ndarray, jnp.ndarray, list)):
            raise TypeError(
                "View/subarray indices do not support array/fancy indices; "
                "use repro.core.datatypes.indexed for ragged selections")
        if isinstance(e, slice):
            triples.append(_norm_slice(e, dim))
        elif isinstance(e, (int, np.integer)):
            i = int(e)
            if i < 0:
                i += dim
            if not 0 <= i < dim:
                raise IndexError(f"index {int(e)} out of range for dim {d} "
                                 f"of extent {dim}")
            triples.append((i, i + 1, 1))
            squeeze.append(d)
        else:
            raise TypeError(f"View index elements must be slice/int, "
                            f"got {e!r}")
    return Subarray(full_shape=full, index=tuple(triples),
                    squeeze=tuple(squeeze))


def face(full_shape, axis: int, side: str, width: int = 1,
         dtype=None) -> Subarray:
    """The halo-slab subarray: a boundary face of an n-d block.

    Args:
        full_shape: shape of the local block.
        axis: decomposed axis the face is perpendicular to.
        side: ``"lo"`` (leading ``width`` slabs) or ``"hi"`` (trailing).
        width: slab thickness (halo width).
    Returns:
        The :class:`Subarray` selecting the face.
    Raises:
        ValueError: bad side, or the face is thicker than the block.
    """
    full = tuple(int(d) for d in full_shape)
    if side not in ("lo", "hi"):
        raise ValueError(f"face side must be 'lo' or 'hi', got {side!r}")
    if not 0 <= axis < len(full):
        raise ValueError(f"face axis {axis} out of range for {full}")
    if width > full[axis]:
        raise ValueError(f"face width {width} exceeds extent {full[axis]} "
                         f"of axis {axis}")
    sub = tuple(width if d == axis else n for d, n in enumerate(full))
    starts = tuple((full[axis] - width if side == "hi" else 0)
                   if d == axis else 0 for d in range(len(full)))
    return subarray(full, sub, starts, dtype=dtype)


# ---------------------------------------------------------------------------
# Indexed (ragged blocks of a flat buffer)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Indexed(Datatype):
    """``MPI_Type_indexed``: ragged blocks at arbitrary displacements over
    a flat (raveled) buffer — the layout v-variant payloads live in."""

    blocklengths: tuple = ()
    displacements: tuple = ()

    def __post_init__(self):
        if len(self.blocklengths) != len(self.displacements):
            raise ValueError(
                f"indexed needs matching blocklengths/displacements, got "
                f"{len(self.blocklengths)} vs {len(self.displacements)}")

    @property
    def extent(self) -> int:
        """Minimum flat-buffer length the layout spans."""
        ends = [d + l for d, l in zip(self.displacements, self.blocklengths)]
        return max(ends, default=0)

    @property
    def packed_shape(self) -> tuple:
        """``(sum(blocklengths),)``."""
        return (sum(self.blocklengths),)

    def _indices(self) -> np.ndarray:
        if not self.blocklengths:
            return np.zeros((0,), dtype=int)
        return np.concatenate([np.arange(l) + d for l, d in
                               zip(self.blocklengths, self.displacements)])

    def pack(self, buf):
        """Gather the ragged blocks from the raveled buffer.

        Args:
            buf: payload with at least :attr:`extent` elements.
        Returns:
            The concatenated ``(sum(blocklengths),)`` message.
        Raises:
            ValueError: the buffer is too short for the layout.
        """
        flat = jnp.asarray(buf).reshape(-1)
        if flat.shape[0] < self.extent:
            raise ValueError(f"indexed extent {self.extent} exceeds buffer "
                             f"size {flat.shape[0]}")
        return flat[self._indices()]

    def unpack(self, message, into=None):
        """Scatter the message back into the ragged blocks of ``into``.

        Args:
            message: the packed message.
            into: the enclosing buffer (required — the layout is sparse).
        Returns:
            ``into`` with the blocks replaced.
        Raises:
            ValueError: ``into`` is None.
        """
        if into is None:
            raise ValueError("indexed.unpack needs into= (sparse layout)")
        x = jnp.asarray(into)
        m = jnp.asarray(message).reshape(self.packed_shape).astype(x.dtype)
        return x.reshape(-1).at[self._indices()].set(m).reshape(x.shape)


def indexed(blocklengths, displacements, dtype=None) -> Indexed:
    """``MPI_Type_indexed``: ragged blocks at static displacements.

    Args:
        blocklengths: per-block element counts.
        displacements: per-block flat-buffer offsets.
        dtype: optional static element dtype.
    Returns:
        The :class:`Indexed` datatype.
    Raises:
        ValueError: mismatched arities or overlapping blocks.
    """
    ls = tuple(int(l) for l in blocklengths)
    ds = tuple(int(d) for d in displacements)
    spans = sorted(zip(ds, ls))
    for (d0, l0), (d1, _) in zip(spans, spans[1:]):
        if d0 + l0 > d1:
            raise ValueError(f"indexed blocks overlap: [{d0}, {d0 + l0}) "
                             f"and [{d1}, ...)")
    return Indexed(dtype=None if dtype is None else jnp.dtype(dtype),
                   blocklengths=ls, displacements=ds)


def block_table(block_ids, block_size, n_tokens, row_elems=1,
                dtype=None) -> Indexed:
    """Per-sequence :func:`indexed` view of a paged KV pool.

    A paged cache stores token rows in fixed-size blocks of one flat pool
    (``(n_blocks * block_size, *row)``); a sequence's *block table* lists
    the pool blocks holding its tokens in position order.  The returned
    datatype selects the sequence's first ``n_tokens`` token rows from the
    *raveled* pool, so ``dt.pack(pool_layer)`` materializes the dense
    per-sequence K (or V) as one contiguous message — the view the
    paged-vs-dense equivalence oracle in ``serve/paged_cache.py`` compares,
    and the layout the engine's gather indices are derived from.

    Args:
        block_ids: pool block indices in sequence-position order.
        block_size: token rows per block.
        n_tokens: leading token count the view covers
            (at most ``len(block_ids) * block_size``).
        row_elems: flat elements per token row (``n_kv_heads * head_dim``
            for a KV pool; 1 for a scalar-per-token pool).
        dtype: optional static element dtype.
    Returns:
        The :class:`Indexed` datatype over the raveled pool.
    Raises:
        ValueError: ``n_tokens`` exceeds the table's capacity, a count is
            negative, or two table entries name the same block (overlap).
    """
    ids = [int(b) for b in block_ids]
    bs, n, re_ = int(block_size), int(n_tokens), int(row_elems)
    if bs <= 0 or re_ <= 0 or n < 0:
        raise ValueError(
            f"block_table needs block_size/row_elems > 0 and n_tokens >= 0, "
            f"got {bs}/{re_}/{n}")
    if n > len(ids) * bs:
        raise ValueError(f"block_table covers {len(ids) * bs} tokens "
                         f"({len(ids)} blocks x {bs}), asked for {n}")
    lengths, displs = [], []
    for p, bid in enumerate(ids):
        rows = min(bs, n - p * bs)
        if rows <= 0:
            break
        lengths.append(rows * re_)
        displs.append(bid * bs * re_)
    return indexed(lengths, displs, dtype=dtype)


# ---------------------------------------------------------------------------
# Slots (the indexed layout over a list of per-slot arrays)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Slots(Datatype):
    """The :func:`indexed` layout applied to a *list* of per-slot arrays —
    what ``neighbor_alltoallv`` (and any ragged multi-destination payload)
    carries.  ``pack`` concatenates the raveled slots into one wire vector;
    ``unpack`` splits it back into the slot list.  Fully covering, so it
    doubles as a receive adapter (``scatter_into(message)`` with no bound
    buffer)."""

    shapes: tuple = ()

    @property
    def packed_shape(self) -> tuple:
        """``(sum of slot sizes,)``."""
        return (sum(_prod(s) for s in self.shapes),)

    def pack(self, xs):
        """Concatenate the raveled slots (shape-checked) into one vector.

        Args:
            xs: sequence of slot arrays matching :attr:`shapes`.
        Returns:
            The flat wire vector.
        Raises:
            ValueError: slot count or a slot shape differs from the
                declared layout.
        """
        from repro.core.views import pack as _pack_one
        slots = [_pack_one(x) for x in xs]
        got = tuple(tuple(s.shape) for s in slots)
        if got != tuple(tuple(s) for s in self.shapes):
            raise ValueError(f"slot datatype is frozen for shapes "
                             f"{tuple(self.shapes)}; got {got}")
        if not slots:
            return jnp.zeros((0,), self.dtype or jnp.float32)
        return jnp.concatenate([s.reshape(-1) for s in slots])

    def unpack(self, message, into=None):
        """Split the wire vector back into the slot list.

        Args:
            message: the flat wire vector.
            into: ignored (the layout fully covers its extent).
        Returns:
            List of slot arrays in declared order.
        """
        del into
        flat = jnp.asarray(message).reshape(-1)
        out, off = [], 0
        for shp in self.shapes:
            n = _prod(shp)
            out.append(flat[off:off + n].reshape(shp))
            off += n
        return out

    @property
    def covers_extent(self) -> bool:
        """True: the slot list rebuilds from the wire vector alone."""
        return True

    def scatter_into(self, buf, message):
        """Rebuild the slot list from the completed wire vector (fully
        covering — ``buf`` may be None and is ignored).

        Args:
            buf: ignored (no target buffer needed).
            message: the received flat vector.
        Returns:
            The slot list.
        """
        del buf
        return self.unpack(message)


def slots(shapes, dtype=None) -> Slots:
    """The :class:`Slots` datatype for a list of per-slot arrays.

    Args:
        shapes: per-slot static shapes, in slot order.
        dtype: optional shared element dtype.
    Returns:
        The :class:`Slots` datatype.
    """
    return Slots(dtype=None if dtype is None else jnp.dtype(dtype),
                 shapes=tuple(tuple(int(d) for d in s) for s in shapes))


# ---------------------------------------------------------------------------
# Pytree (one datatype for a whole tree of arrays)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Pytree(Datatype):
    """One wire vector for a whole pytree of arrays (beyond MPI: the
    gradient-sync datatype).  Leaves pack in flatten order, each through
    its own leaf datatype, cast to ``wire_dtype`` on the wire and back to
    the leaf dtype on unpack.  Fully covering → usable as a receive
    adapter directly."""

    treedef: Any = None
    leaf_shapes: tuple = ()
    leaf_dtypes: tuple = ()

    @property
    def wire_dtype(self):
        """Dtype every leaf is cast to on the wire (``dtype`` field)."""
        return self.dtype

    @property
    def packed_shape(self) -> tuple:
        """``(total leaf elements,)``."""
        return (sum(_prod(s) for s in self.leaf_shapes),)

    def pack(self, tree):
        """Flatten the tree into one ``wire_dtype`` vector.

        Args:
            tree: pytree matching the frozen treedef/leaf signatures.
        Returns:
            The flat wire vector.
        Raises:
            ValueError: leaf count/shape mismatch with the frozen layout.
        """
        leaves, tdef = jax.tree_util.tree_flatten(tree)
        if tdef != self.treedef:
            raise ValueError(f"pytree datatype is frozen for {self.treedef}; "
                             f"got {tdef}")
        got = tuple(tuple(l.shape) for l in leaves)
        if got != self.leaf_shapes:
            raise ValueError(f"pytree datatype is frozen for leaf shapes "
                             f"{self.leaf_shapes}; got {got}")
        if not leaves:
            return jnp.zeros((0,), self.wire_dtype)
        return jnp.concatenate(
            [jnp.asarray(l).reshape(-1).astype(self.wire_dtype)
             for l in leaves])

    def unpack(self, message, into=None):
        """Rebuild the pytree from the wire vector (leaf dtypes restored).

        Args:
            message: the flat wire vector.
            into: ignored (fully-covering layout).
        Returns:
            The reconstructed pytree.
        """
        del into
        flat = jnp.asarray(message).reshape(-1)
        leaves, off = [], 0
        for shp, dt in zip(self.leaf_shapes, self.leaf_dtypes):
            n = _prod(shp)
            leaves.append(flat[off:off + n].reshape(shp).astype(dt))
            off += n
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    @property
    def covers_extent(self) -> bool:
        """True: the tree rebuilds from the wire vector alone."""
        return True

    def scatter_into(self, buf, message):
        """Rebuild the tree from the completed wire vector (fully
        covering — ``buf`` may be None and is ignored).

        Args:
            buf: ignored (no target buffer needed).
            message: the received flat vector.
        Returns:
            The reconstructed pytree.
        """
        del buf
        return self.unpack(message)


def pytree(tree, wire_dtype=None) -> Pytree:
    """One datatype for a whole pytree of arrays (gradient buckets).

    Args:
        tree: a pytree of arrays or ShapeDtypeStructs supplying the static
            leaf signatures.
        wire_dtype: dtype leaves are cast to on the wire (default: the
            jnp promotion of all leaf dtypes).
    Returns:
        The :class:`Pytree` datatype; ``pack(tree)`` → one flat vector,
        ``unpack(vec)`` → the tree with original leaf dtypes.
    """
    leaves, tdef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    if wire_dtype is None:
        wire_dtype = (jnp.result_type(*dtypes) if dtypes else jnp.float32)
    return Pytree(dtype=jnp.dtype(wire_dtype), treedef=tdef,
                  leaf_shapes=shapes, leaf_dtypes=dtypes)


# ---------------------------------------------------------------------------
# The shared payload pipeline (blocking / nonblocking / persistent paths)
# ---------------------------------------------------------------------------

def pack_payload(x, datatype: Optional[Datatype] = None):
    """THE send-side entry point: materialize any jmpi payload.

    Resolution order: an explicit ``datatype`` packs ``x`` through it; a
    payload carrying its own ``pack()`` (a :class:`Bound` value or a
    ``views.View``) packs itself; anything NumPy-like becomes a jnp array.
    Every dispatch path (blocking, i*, ``plan.start``) calls this one
    function, so pack rules cannot drift between paths.

    Args:
        x: the payload (array, View, Bound, slot list/pytree with a
            datatype).
        datatype: optional explicit layout.
    Returns:
        The contiguous jnp message.
    """
    if datatype is not None:
        return datatype.pack(x)
    if hasattr(x, "pack") and callable(x.pack):
        return x.pack()
    return jnp.asarray(x)


def recv_adapter(obj):
    """THE receive-side entry point: normalize a ``recv_into`` target.

    Accepts a ``views.View``, a :class:`Bound` value (``dt.bind(buf)``),
    or a fully-covering :class:`Datatype` (``covers_extent`` —
    Slots/Pytree, which need no target buffer) — returns an adapter with
    the single-argument ``scatter_into(message)`` protocol (and a
    ``count`` for the static ERR_TRUNCATE check), or None.

    Args:
        obj: the receive target (or None).
    Returns:
        The adapter, or None when ``obj`` is None.
    Raises:
        TypeError: ``obj`` has no usable receive protocol, or is a sparse
            (non-covering) datatype passed without a buffer.
    """
    if obj is None:
        return None
    if isinstance(obj, Datatype):
        if not obj.covers_extent:
            raise TypeError(
                f"{type(obj).__name__} is a sparse layout; bind it to a "
                f"buffer first: dt.bind(buf)")
        return obj.bind(None)
    if hasattr(obj, "scatter_into"):
        return obj
    raise TypeError(f"recv target {type(obj).__name__} has no "
                    f"scatter_into protocol; pass a View or dt.bind(buf)")


def adapter_count(adapter) -> Optional[int]:
    """Static packed element count of a receive adapter (for the
    trace-time ERR_TRUNCATE check), or None when it is not statically
    known without packing.

    Args:
        adapter: a normalized receive adapter.
    Returns:
        The element count, or None.
    """
    if adapter is None:
        return None
    count = getattr(adapter, "count", None)
    if count is not None:
        return int(count)
    if hasattr(adapter, "pack"):
        return _prod(adapter.pack().shape)
    return None
