"""Non-contiguous payloads (paper §2.3: 'handling of non-contiguous views
over array slices', Listing 6: Fortran-order arrays).

JAX arrays are functional values without a user-visible memory layout, so
"non-contiguous" cannot mean strided pointers here.  What survives the
translation is the *usability* contract: users hand jmpi a slice of a bigger
array and receive into a slice of a bigger array, without manual staging
copies.  ``View`` captures (array, index-expression) and is sugar over the
derived-datatype layer (``repro.core.datatypes``): the index expression
resolves to a :class:`~repro.core.datatypes.Subarray` datatype, whose
``pack`` materializes the contiguous message (XLA fuses it into the
transfer's prologue — the same zero-copy effect the paper gets from MPI
datatypes) and whose ``scatter_into`` writes a received message back with
MPI-recv truncation semantics.

Index support: per-dimension slices (any static step, including negative)
and integers (the dimension is squeezed from the packed message).
``Ellipsis``, ``None``/newaxis and array indices raise a clear
``TypeError`` at construction time — previously they crashed deep inside
jnp or silently mis-packed.

Fortran order: logical jnp arrays are always C-indexed; layout is an XLA
decision.  Transposed views (``View(x.T, ...)``) are the behavioural
equivalent and are covered by tests (DESIGN.md §2 changed-assumptions).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core import datatypes as datatypes_lib


@dataclasses.dataclass
class View:
    """A (possibly strided) rectangular slice of an array, as an MPI payload.

    Sugar over :func:`repro.core.datatypes.subarray_of`: the index
    expression is resolved once (clear trace-time errors for unsupported
    index kinds) and all pack/unpack work delegates to the datatype.
    """

    array: Any
    index: tuple = ()

    def __post_init__(self):
        shape = tuple(jnp.shape(self.array))
        self._dt = datatypes_lib.subarray_of(shape, self.index)
        self.index = tuple(self._dt._slices())

    @property
    def datatype(self) -> "datatypes_lib.Subarray":
        """The resolved :class:`~repro.core.datatypes.Subarray` layout."""
        return self._dt

    def pack(self):
        """Contiguous message buffer (gather/slice; fused by XLA).

        Returns:
            The selected slice as a dense jnp array.
        """
        return self._dt.pack(self.array)

    def unpack(self, message):
        """Enclosing array with ``message`` scattered into the view's slots.

        Args:
            message: buffer shaped like the view's slice (cast to the
                enclosing dtype).
        Returns:
            A new array equal to ``array`` outside the slice and
            ``message`` inside it.
        """
        return self._dt.unpack(message, into=self.array)

    def scatter_into(self, message):
        """MPI-recv style write of ``message`` into the view's slots.

        The first ``min(message.size, view.size)`` elements land (row-major);
        when the message is *longer* than the view the tail is dropped — the
        MPI_ERR_TRUNCATE condition, reported by the request's status — and
        when it is shorter the remaining view slots keep their prior
        contents (MPI writes only ``count`` received elements)."""
        return self._dt.scatter_into(self.array, message)

    @property
    def count(self) -> int:
        """Packed element count (static; used by the truncation check)."""
        return self._dt.count

    @property
    def shape(self):
        """Shape of the packed message."""
        return self._dt.packed_shape

    @property
    def dtype(self):
        """Element dtype of the enclosing array."""
        return jnp.asarray(self.array).dtype


def pack(x):
    """Materialize any jmpi payload: a View/Bound packs to its contiguous
    message, anything NumPy-like becomes a jnp array.  Thin alias of
    :func:`repro.core.datatypes.pack_payload` — the single helper shared by
    the blocking, nonblocking and persistent dispatch paths."""
    return datatypes_lib.pack_payload(x)
