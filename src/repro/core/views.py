"""Non-contiguous payloads (paper §2.3: 'handling of non-contiguous views
over array slices', Listing 6: Fortran-order arrays).

JAX arrays are functional values without a user-visible memory layout, so
"non-contiguous" cannot mean strided pointers here.  What survives the
translation is the *usability* contract: users hand jmpi a slice of a bigger
array and receive into a slice of a bigger array, without manual staging
copies.  ``View`` captures (array, index-expression); ``pack`` materializes
the contiguous message (XLA fuses it into the transfer's prologue — the same
zero-copy effect the paper gets from MPI datatypes), ``unpack`` scatters a
received message back into the enclosing array.

Fortran order: logical jnp arrays are always C-indexed; layout is an XLA
decision.  Transposed views (``View(x.T, ...)``) are the behavioural
equivalent and are covered by tests (DESIGN.md §2 changed-assumptions).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


def _normalize_index(idx) -> tuple:
    if not isinstance(idx, tuple):
        idx = (idx,)
    norm = []
    for e in idx:
        if isinstance(e, slice) or isinstance(e, int):
            norm.append(e)
        else:
            raise TypeError(f"View index elements must be slice/int, got {e!r}")
    return tuple(norm)


@dataclasses.dataclass
class View:
    """A (possibly strided) rectangular slice of an array, as an MPI payload."""

    array: Any
    index: tuple = ()

    def __post_init__(self):
        self.index = _normalize_index(self.index)

    def pack(self):
        """Contiguous message buffer (gather/slice; fused by XLA).

        Returns:
            The selected slice as a dense jnp array.
        """
        x = jnp.asarray(self.array)
        return x[self.index] if self.index else x

    def unpack(self, message):
        """Enclosing array with ``message`` scattered into the view's slots.

        Args:
            message: buffer shaped like the view's slice (cast to the
                enclosing dtype).
        Returns:
            A new array equal to ``array`` outside the slice and
            ``message`` inside it.
        """
        x = jnp.asarray(self.array)
        if not self.index:
            return jnp.asarray(message).reshape(x.shape).astype(x.dtype)
        return x.at[self.index].set(message.astype(x.dtype))

    def scatter_into(self, message):
        """MPI-recv style write of ``message`` into the view's slots.

        The first ``min(message.size, view.size)`` elements land (row-major);
        when the message is *longer* than the view the tail is dropped — the
        MPI_ERR_TRUNCATE condition, reported by the request's status — and
        when it is shorter the remaining view slots keep their prior
        contents (MPI writes only ``count`` received elements)."""
        cur = self.pack()
        m = jnp.ravel(jnp.asarray(message))[:cur.size]
        if m.size < cur.size:
            flat = jnp.concatenate(
                [m.astype(cur.dtype), cur.ravel()[m.size:]])
        else:
            flat = m.astype(cur.dtype)
        return self.unpack(flat.reshape(cur.shape))

    @property
    def shape(self):
        return self.pack().shape

    @property
    def dtype(self):
        return jnp.asarray(self.array).dtype


def pack(x):
    """Materialize any jmpi payload: a View packs to its contiguous message,
    anything NumPy-like becomes a jnp array (single helper shared by the
    blocking, nonblocking and persistent dispatch paths)."""
    if isinstance(x, View):
        return x.pack()
    return jnp.asarray(x)
