"""Classic MPI v-variant collectives: scatterv / gatherv / allgatherv /
alltoallv — first-class registry ops with ≥2 lowerings each, in the full
jmpi 2.0 surface (blocking, ``i*`` → unified Request, ``*_init`` → Plan).

SPMD reading of raggedness (DESIGN.md §2, static topology): MPI's
per-rank ``counts`` arrays are **static Python ints**, identical on every
rank (every device traces the same program), and per-rank buffers are
padded to the maximum count so all ranks share one static shape:

* ``scatterv(x, counts, root)`` — ``x`` is root's ``(sum(counts), ...)``
  buffer; every rank completes with ``(max(counts), ...)`` holding its
  ``counts[rank]`` valid leading rows, zeros beyond (the padded-buffer
  translation of MPI's ``recvcount`` contract).
* ``gatherv(x, counts, root)`` / ``allgatherv(x, counts)`` — ``x`` is the
  local ``(max(counts), ...)`` padded buffer with ``counts[rank]`` valid
  rows; completes with the ``(sum(counts), ...)`` concatenation of every
  rank's valid prefix (gatherv: contractually valid at root only).
* ``alltoallv(x, counts)`` — ``counts`` is the full static n×n matrix
  (``counts[src][dst]`` rows from src to dst); ``x`` is the
  ``(n, maxc, ...)`` stacked per-destination slot buffer.  Slot ``s`` of
  the result holds the ``counts[s][rank]`` rows rank ``s`` sent here,
  zeros beyond.  Invalid send rows are masked to zeros before transfer,
  so garbage in the padding never crosses the wire.

Lowerings (registered in ``registry.OPS``, policy-selectable like every
other collective):

* ``xla_native`` — one XLA collective plus static index math: masked-psum
  bcast + per-rank dynamic slice (scatterv), ``all_gather`` + static
  valid-row gather (gatherv/allgatherv), ``all_to_all`` on the padded
  slot stack (alltoallv, single-axis comms).
* p2p schedules — ``linear`` scatterv (root sends each rank its chunk,
  n−1 token-tied ppermutes, the classic linear-scatter tree), ``ring``
  gatherv/allgatherv (circulate the padded buffer n−1 forward hops,
  depositing each origin's block), ``pairwise`` alltoallv (n−1 shifted
  exchanges, the OMB pairwise schedule).

Payloads are datatype-uniform: ``datatype=`` (or a ``dt.bind(buf)`` /
``View`` payload) packs through :mod:`repro.core.datatypes` exactly like
every other registry op — an ``indexed`` datatype describing ragged
blocks of a flat buffer is the natural send-side companion of these ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.core import token as token_lib
from repro.core.comm import Communicator, resolve
from repro.core.p2p import Request

__all__ = [
    "scatterv", "gatherv", "allgatherv", "alltoallv",
    "iscatterv", "igatherv", "iallgatherv", "ialltoallv",
]


# ---------------------------------------------------------------------------
# counts helpers (shared by the public ops, the plans layer and the kernels)
# ---------------------------------------------------------------------------

def check_counts(counts, n: int) -> tuple[int, ...]:
    """Validate per-rank counts for scatterv/gatherv/allgatherv.

    Args:
        counts: one non-negative static int per rank.
        n: communicator size.
    Returns:
        The counts as a tuple of Python ints.
    Raises:
        ValueError: wrong arity or a negative count.
    """
    cs = tuple(int(c) for c in counts)
    if len(cs) != n:
        raise ValueError(f"counts arity {len(cs)} != comm size {n}")
    if any(c < 0 for c in cs):
        raise ValueError(f"counts must be non-negative, got {cs}")
    return cs


def check_count_matrix(counts, n: int) -> tuple[tuple[int, ...], ...]:
    """Validate the n×n alltoallv counts matrix (``counts[src][dst]``).

    Args:
        counts: n rows of n non-negative static ints.
        n: communicator size.
    Returns:
        The matrix as a tuple of tuples of Python ints.
    Raises:
        ValueError: wrong arity or a negative count.
    """
    rows = tuple(tuple(int(c) for c in row) for row in counts)
    if len(rows) != n or any(len(r) != n for r in rows):
        raise ValueError(f"alltoallv needs an {n}x{n} counts matrix, got "
                         f"shape {(len(rows),) + tuple(set(map(len, rows)))}")
    if any(c < 0 for r in rows for c in r):
        raise ValueError(f"counts must be non-negative, got {rows}")
    return rows


def _offsets(counts) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)


def _row_mask(maxc: int, count, like):
    """(maxc, 1, 1, ...) bool mask of the valid leading rows (traced
    ``count``), broadcastable over the trailing dims of ``like``."""
    mask = jnp.arange(maxc) < count
    return mask.reshape((maxc,) + (1,) * (like.ndim - 1))


def _hop(comm, perm, x, tok):
    """One token-tied ppermute along a static pattern."""
    tok, x = token_lib.tie(tok, x)
    out = jax.lax.ppermute(x, comm.axes, perm)
    tok = token_lib.advance(tok, out)
    return out, tok


# ---------------------------------------------------------------------------
# scatterv kernels
# ---------------------------------------------------------------------------

def _scatterv_supports(val, comm, *, counts=(), root=0, **kw):
    return (len(counts) == comm.size() and val.ndim >= 1
            and val.shape[0] == sum(counts))


@registry.register("scatterv", "xla_native", supports=_scatterv_supports)
def _scatterv_xla(val, tok, comm, *, counts, root):
    """Masked-psum bcast of the full buffer + per-rank dynamic slice at the
    static offset, invalid tail rows masked to zeros."""
    maxc = max(counts) if counts else 0
    rank = comm.rank()
    contrib = jnp.where(rank == root, val, jnp.zeros_like(val))
    full = jax.lax.psum(contrib, comm.axes)
    padded = jnp.concatenate(
        [full, jnp.zeros((maxc,) + full.shape[1:], full.dtype)])
    start = jnp.take(jnp.asarray(_offsets(counts)), rank)
    out = jax.lax.dynamic_slice_in_dim(padded, start, maxc, axis=0)
    cnt = jnp.take(jnp.asarray(counts, jnp.int32), rank)
    return jnp.where(_row_mask(maxc, cnt, out), out, 0), tok


@registry.register("scatterv", "linear", supports=_scatterv_supports)
def _scatterv_linear(val, tok, comm, *, counts, root):
    """Linear scatter tree: root sends each non-root rank its chunk as one
    token-tied ppermute (n−1 hops of max-count size)."""
    n = comm.size()
    maxc = max(counts) if counts else 0
    offs = _offsets(counts)
    rank = comm.rank()
    pad = jnp.zeros((maxc,) + val.shape[1:], val.dtype)
    padded = jnp.concatenate([val, pad])

    def chunk_for(r):
        c = jax.lax.slice_in_dim(padded, int(offs[r]), int(offs[r]) + maxc,
                                 axis=0)
        return jnp.where(_row_mask(maxc, counts[r], c), c, 0)

    out = jnp.where(rank == root, chunk_for(root),
                    jnp.zeros((maxc,) + val.shape[1:], val.dtype))
    for r in range(n):
        if r == root:
            continue
        got, tok = _hop(comm, [(root, r)], chunk_for(r), tok)
        out = jnp.where(rank == r, got, out)
    return out, tok


# ---------------------------------------------------------------------------
# gatherv / allgatherv kernels (shared implementations)
# ---------------------------------------------------------------------------

def _gatherv_supports(val, comm, *, counts=(), **kw):
    maxc = max(counts) if counts else 0
    return (len(counts) == comm.size() and val.ndim >= 1
            and val.shape[0] == maxc)


def _valid_rows(counts) -> np.ndarray:
    """Static row indices of every rank's valid prefix inside the padded
    (n·maxc, ...) gather, in rank order."""
    maxc = max(counts) if counts else 0
    if not counts or sum(counts) == 0:
        return np.zeros((0,), np.int32)
    return np.concatenate(
        [r * maxc + np.arange(c) for r, c in enumerate(counts)
         if c > 0]).astype(np.int32)


def _gatherv_xla(val, tok, comm, *, counts, root=0):
    """all_gather of the padded buffer + static gather of the valid rows."""
    g = jax.lax.all_gather(val, comm.axes, axis=0, tiled=False)
    flat = g.reshape((-1,) + tuple(val.shape[1:]))
    return jnp.take(flat, jnp.asarray(_valid_rows(counts)), axis=0), tok


def _gatherv_ring(val, tok, comm, *, counts, root=0):
    """Ring allgatherv: circulate the padded buffer n−1 forward hops,
    depositing each origin's block into its padded slot, then the same
    static valid-row gather as the native lowering."""
    n = comm.size()
    maxc = max(counts) if counts else 0
    rank = comm.rank()
    buf = jnp.zeros((n * maxc,) + tuple(val.shape[1:]), val.dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, val, rank * maxc, axis=0)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    cur = val
    for hop in range(1, n):
        cur, tok = _hop(comm, fwd, cur, tok)
        src = (rank - hop) % n
        buf = jax.lax.dynamic_update_slice_in_dim(buf, cur, src * maxc,
                                                  axis=0)
    return jnp.take(buf, jnp.asarray(_valid_rows(counts)), axis=0), tok


registry.register("gatherv", "xla_native",
                  supports=_gatherv_supports)(_gatherv_xla)
registry.register("gatherv", "ring", supports=_gatherv_supports)(_gatherv_ring)
registry.register("allgatherv", "xla_native",
                  supports=_gatherv_supports)(_gatherv_xla)
registry.register("allgatherv", "ring",
                  supports=_gatherv_supports)(_gatherv_ring)


# ---------------------------------------------------------------------------
# alltoallv kernels
# ---------------------------------------------------------------------------

def _alltoallv_supports(val, comm, *, counts=(), **kw):
    n = comm.size()
    if len(counts) != n or any(len(r) != n for r in counts):
        return False
    maxc = max((c for r in counts for c in r), default=0)
    return val.ndim >= 2 and val.shape[0] == n and val.shape[1] == maxc


def _alltoallv_natively_supported(val, comm, **kw):
    return _alltoallv_supports(val, comm, **kw) and len(comm.axes) == 1


def _mask_send_slots(val, counts, comm):
    """Zero the invalid padded rows of every send slot (rows beyond
    ``counts[rank][dst]``) so padding garbage never crosses the wire."""
    maxc = val.shape[1]
    row = jnp.take(jnp.asarray(counts, jnp.int32), comm.rank(), axis=0)
    mask = jnp.arange(maxc)[None, :] < row[:, None]
    return jnp.where(mask.reshape(mask.shape + (1,) * (val.ndim - 2)), val, 0)


@registry.register("alltoallv", "xla_native",
                   supports=_alltoallv_natively_supported)
def _alltoallv_xla(val, tok, comm, *, counts):
    """One tiled all_to_all over the masked padded slot stack."""
    masked = _mask_send_slots(val, counts, comm)
    out = jax.lax.all_to_all(masked, comm.axes[0], split_axis=0,
                             concat_axis=0, tiled=True)
    return out, tok


@registry.register("alltoallv", "pairwise", supports=_alltoallv_supports)
def _alltoallv_pairwise(val, tok, comm, *, counts):
    """Pairwise-exchange schedule: at step s every rank sends slot
    ``(rank+s) mod n`` to rank ``rank+s`` and deposits the block arriving
    from rank ``rank−s`` — n−1 shifted token-tied ppermutes."""
    n = comm.size()
    rank = comm.rank()
    masked = _mask_send_slots(val, counts, comm)
    out = jnp.zeros_like(masked)
    own = jnp.take(masked, rank, axis=0)
    out = jax.lax.dynamic_update_slice_in_dim(out, own[None], rank, axis=0)
    for s in range(1, n):
        perm = [(i, (i + s) % n) for i in range(n)]
        payload = jnp.take(masked, (rank + s) % n, axis=0)
        got, tok = _hop(comm, perm, payload, tok)
        src = (rank - s) % n
        out = jax.lax.dynamic_update_slice_in_dim(out, got[None], src, axis=0)
    return out, tok


# ---------------------------------------------------------------------------
# Public ops — blocking + i*, sharing the collective dispatch path.
# (The *_init persistent forms live in repro.core.plans.)
# ---------------------------------------------------------------------------

def _validate_scatterv(comm, val, counts):
    counts = check_counts(counts, comm.size())
    if val.ndim < 1 or val.shape[0] != sum(counts):
        raise ValueError(f"scatterv payload axis0={tuple(val.shape)[:1]} must "
                         f"be (sum(counts),)=({sum(counts)},); got shape "
                         f"{tuple(val.shape)}")
    return counts


def _validate_gatherv(comm, val, counts):
    counts = check_counts(counts, comm.size())
    maxc = max(counts) if counts else 0
    if val.ndim < 1 or val.shape[0] != maxc:
        raise ValueError(f"gatherv/allgatherv payload axis 0 must be "
                         f"max(counts)={maxc}, got shape {tuple(val.shape)}")
    return counts


def _validate_alltoallv(comm, val, counts):
    counts = check_count_matrix(counts, comm.size())
    n = comm.size()
    maxc = max((c for r in counts for c in r), default=0)
    if val.ndim < 2 or val.shape[0] != n or val.shape[1] != maxc:
        raise ValueError(f"alltoallv payload must be (n, max(counts), ...) = "
                         f"({n}, {maxc}, ...), got shape {tuple(val.shape)}")
    return counts


def iscatterv(x, counts, root: int = 0, *, comm: Communicator | None = None,
              token=None, algorithm: str | None = None, tag: int = 0,
              datatype=None) -> Request:
    """MPI_Iscatterv: start dealing ragged axis-0 chunks of root's buffer.

    Args:
        x: root's ``(sum(counts), ...)`` buffer (contents ignored off-root).
        counts: static per-rank row counts.
        root: static scattering rank.
        comm: communicator (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force (``xla_native`` | ``linear``).
        tag: tag recorded on the Request.
        datatype: optional derived datatype packing ``x``.
    Returns:
        :class:`Request` completing with ``(max(counts), ...)`` — this
        rank's ``counts[rank]`` valid rows, zeros beyond.
    Raises:
        ValueError: bad counts or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    comm = resolve(comm)
    val = _coll._pack(x, datatype)
    counts = _validate_scatterv(comm, val, counts)
    req, _ = _coll._issue("scatterv", val, comm=comm, token=token,
                          algorithm=algorithm, tag=tag, counts=counts,
                          root=root)
    return req


def scatterv(x, counts, root: int = 0, *, comm: Communicator | None = None,
             token=None, algorithm: str | None = None, datatype=None):
    """MPI_Scatterv: blocking form of :func:`iscatterv`.

    Args: as :func:`iscatterv`.
    Returns:
        ``(status, chunk)`` — plus the token when one was passed
        explicitly; ``chunk`` is ``(max(counts), ...)`` with this rank's
        ``counts[rank]`` valid rows.
    Raises:
        ValueError: bad counts or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    explicit = token is not None
    req = iscatterv(x, counts, root, comm=comm, token=token,
                    algorithm=algorithm, datatype=datatype)
    return _coll._finish(req, explicit)


def igatherv(x, counts, root: int = 0, *, comm: Communicator | None = None,
             token=None, algorithm: str | None = None, tag: int = 0,
             datatype=None) -> Request:
    """MPI_Igatherv: start gathering ragged per-rank prefixes (valid at
    ``root``; the SPMD lowering materializes the result everywhere).

    Args:
        x: local ``(max(counts), ...)`` padded buffer, ``counts[rank]``
            valid leading rows.
        counts: static per-rank row counts.
        root: rank at which the result is contractually valid.
        comm: communicator (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force (``xla_native`` | ``ring``).
        tag: tag recorded on the Request.
        datatype: optional derived datatype packing ``x``.
    Returns:
        :class:`Request` completing with the ``(sum(counts), ...)``
        concatenation of every rank's valid prefix.
    Raises:
        ValueError: bad counts or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    comm = resolve(comm)
    val = _coll._pack(x, datatype)
    counts = _validate_gatherv(comm, val, counts)
    req, _ = _coll._issue("gatherv", val, comm=comm, token=token,
                          algorithm=algorithm, tag=tag, counts=counts,
                          root=root)
    return req


def gatherv(x, counts, root: int = 0, *, comm: Communicator | None = None,
            token=None, algorithm: str | None = None, datatype=None):
    """MPI_Gatherv: blocking form of :func:`igatherv`.

    Args: as :func:`igatherv`.
    Returns:
        ``(status, stacked)`` — plus the token when one was passed
        explicitly; ``stacked`` is the ``(sum(counts), ...)``
        concatenation, contractually valid at ``root``.
    Raises:
        ValueError: bad counts or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    explicit = token is not None
    req = igatherv(x, counts, root, comm=comm, token=token,
                   algorithm=algorithm, datatype=datatype)
    return _coll._finish(req, explicit)


def iallgatherv(x, counts, *, comm: Communicator | None = None, token=None,
                algorithm: str | None = None, tag: int = 0,
                datatype=None) -> Request:
    """MPI_Iallgatherv: :func:`igatherv` valid on every rank.

    Args: as :func:`igatherv` (no root).
    Returns:
        :class:`Request` completing with the ``(sum(counts), ...)``
        concatenation on every rank.
    Raises:
        ValueError: bad counts or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    comm = resolve(comm)
    val = _coll._pack(x, datatype)
    counts = _validate_gatherv(comm, val, counts)
    req, _ = _coll._issue("allgatherv", val, comm=comm, token=token,
                          algorithm=algorithm, tag=tag, counts=counts)
    return req


def allgatherv(x, counts, *, comm: Communicator | None = None, token=None,
               algorithm: str | None = None, datatype=None):
    """MPI_Allgatherv: blocking form of :func:`iallgatherv`.

    Args: as :func:`iallgatherv`.
    Returns:
        ``(status, stacked)`` — plus the token when one was passed
        explicitly.
    Raises:
        ValueError: bad counts or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    explicit = token is not None
    req = iallgatherv(x, counts, comm=comm, token=token, algorithm=algorithm,
                      datatype=datatype)
    return _coll._finish(req, explicit)


def ialltoallv(x, counts, *, comm: Communicator | None = None, token=None,
               algorithm: str | None = None, tag: int = 0,
               datatype=None) -> Request:
    """MPI_Ialltoallv: start the ragged all-to-all exchange.

    Args:
        x: ``(n, max(counts), ...)`` stacked per-destination slots; slot
            ``d`` carries ``counts[rank][d]`` valid leading rows.
        counts: static n×n matrix, ``counts[src][dst]``.
        comm: communicator (None = ambient WORLD).
        token: explicit ordering token; None uses the ambient chain.
        algorithm: registry entry to force (``xla_native`` | ``pairwise``).
        tag: tag recorded on the Request.
        datatype: optional derived datatype packing ``x``.
    Returns:
        :class:`Request` completing with the same-shape stack — slot ``s``
        holds the ``counts[s][rank]`` rows rank ``s`` sent here, zeros
        beyond.
    Raises:
        ValueError: bad counts matrix or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    comm = resolve(comm)
    val = _coll._pack(x, datatype)
    counts = _validate_alltoallv(comm, val, counts)
    req, _ = _coll._issue("alltoallv", val, comm=comm, token=token,
                          algorithm=algorithm, tag=tag, counts=counts)
    return req


def alltoallv(x, counts, *, comm: Communicator | None = None, token=None,
              algorithm: str | None = None, datatype=None):
    """MPI_Alltoallv: blocking form of :func:`ialltoallv`.

    Args: as :func:`ialltoallv`.
    Returns:
        ``(status, out)`` — plus the token when one was passed explicitly;
        slot ``s`` of ``out`` is what rank ``s`` sent here (valid rows
        ``counts[s][rank]``, zeros beyond).
    Raises:
        ValueError: bad counts matrix or a payload/counts mismatch.
    """
    from repro.core import collectives as _coll
    explicit = token is not None
    req = ialltoallv(x, counts, comm=comm, token=token, algorithm=algorithm,
                     datatype=datatype)
    return _coll._finish(req, explicit)
