"""Reduction operators and their elementwise algebra — shared by every
registered collective lowering.

``Operator`` is the paper's reduction enumeration (default SUM).  The helpers
here are what lets *every* hand-scheduled collective (ring, recursive
doubling) honor the full six-operator surface instead of special-casing SUM:

* :func:`combiner` — (combine, pre, post) for an operator.  LAND/LOR work in
  an int32 {0, 1} domain (``pre`` normalizes, ``post`` casts back), which is
  also what the xla_native kernel does, so all lowerings agree bit-for-bit
  on logical reductions.
* :func:`identity_scalar` — the combiner's identity element in the working
  dtype, for schedules that thread an accumulator (ring reduce-scatter
  phase): 0 for SUM/LOR, 1 for PROD/LAND, ±dtype-extreme for MIN/MAX.
"""

from __future__ import annotations

import enum

import jax.numpy as jnp
import numpy as np


class Operator(enum.Enum):
    """Reduction operators (paper: 'Operator enumeration, default SUM')."""

    SUM = "sum"
    PROD = "prod"
    MIN = "min"
    MAX = "max"
    LAND = "land"
    LOR = "lor"


def combiner(op: Operator):
    """(combine, pre, post) for ``op``.

    ``combine(a, b)`` is the elementwise reduction; ``pre(v)`` maps the
    payload into the working domain and ``post(v, dtype)`` maps back (both
    None when the payload dtype is the working domain already).
    """
    if op is Operator.SUM:
        return (lambda a, b: a + b), None, None
    if op is Operator.PROD:
        return (lambda a, b: a * b), None, None
    if op is Operator.MIN:
        return jnp.minimum, None, None
    if op is Operator.MAX:
        return jnp.maximum, None, None
    if op is Operator.LAND:
        return (jnp.minimum,
                lambda v: (v != 0).astype(jnp.int32),
                lambda v, dtype: v.astype(dtype))
    if op is Operator.LOR:
        return (jnp.maximum,
                lambda v: (v != 0).astype(jnp.int32),
                lambda v, dtype: v.astype(dtype))
    raise ValueError(f"unsupported operator {op}")


def identity_scalar(op: Operator, dtype):
    """The identity element of ``op``'s combiner, as a python/numpy scalar in
    ``dtype`` (the *working* dtype: int32 for LAND/LOR after ``pre``)."""
    dt = jnp.dtype(dtype)
    if op in (Operator.SUM, Operator.LOR):
        return np.asarray(0, dt)
    if op in (Operator.PROD, Operator.LAND):
        return np.asarray(1, dt)
    if op is Operator.MIN:
        if jnp.issubdtype(dt, jnp.integer):
            return np.asarray(np.iinfo(dt).max, dt)
        return np.asarray(np.inf, dt)
    if op is Operator.MAX:
        if jnp.issubdtype(dt, jnp.integer):
            return np.asarray(np.iinfo(dt).min, dt)
        return np.asarray(-np.inf, dt)
    raise ValueError(f"unsupported operator {op}")
