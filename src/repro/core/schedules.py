"""Latency-oriented hand-scheduled collectives (registry entries).

Complements ``repro.core.ring`` (bandwidth-optimal chunked rings) with the
classic *latency*-optimal schedules from the MPI literature:

* ``recursive_doubling`` allreduce — log₂ n rounds, each a full-payload
  exchange with the rank whose id differs in bit k (MPICH's small-message
  allreduce).  α·log n latency versus the ring's α·2(n−1): the right choice
  for tiny, latency-bound payloads (loss scalars, norms, barriers-with-data).
* ``tree`` bcast — binomial tree rooted at ``root``: the set of informed
  ranks doubles each round, ⌈log₂ n⌉ ppermute hops move the payload
  verbatim (bit-exact for every dtype, any group size).
* ``pairwise`` alltoall — n−1 shifted permute rounds; each round r sends the
  chunk destined to rank (me+r) directly (MPI_Alltoall's pairwise-exchange
  algorithm; trades the XLA fused all-to-all for overlappable steps).

All kernels follow the registry contract ``fn(val, tok, comm, **kw) ->
(out, tok)``: payload already packed and token-tied by the public op,
token threaded through every hop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import operators as op_lib
from repro.core import registry
from repro.core import token as token_lib


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def recursive_doubling_allreduce(val, tok, comm, *, op):
    """MPI_Allreduce, recursive doubling: partner = rank XOR 2^k per round."""
    n = comm.size()
    # n == 1 still applies pre/post (LAND/LOR normalize to {0,1} like the
    # xla_native kernel); the exchange loop simply has zero rounds.
    combine, pre, post = op_lib.combiner(op)
    dtype = val.dtype
    cur = pre(val) if pre is not None else val
    k = 0
    while (1 << k) < n:
        d = 1 << k
        perm = [(i, i ^ d) for i in range(n)]  # involution: injective
        tok, cur = token_lib.tie(tok, cur)
        recv = jax.lax.ppermute(cur, comm.axes, perm)
        tok = token_lib.advance(tok, recv)
        cur = combine(cur, recv)
        k += 1
    if post is not None:
        cur = post(cur, dtype)
    return cur, tok


def _rd_supports(val, comm, *, op=None, **kw):
    return _is_pow2(comm.size())


registry.register("allreduce", "recursive_doubling",
                  supports=_rd_supports)(recursive_doubling_allreduce)


def tree_bcast(val, tok, comm, *, root):
    """MPI_Bcast, binomial tree: informed set doubles every round.

    Payload moves verbatim (no arithmetic) — bit-exact for every dtype.
    Ranks are numbered relative to the root; works for any group size.
    """
    n = comm.size()
    if n == 1:
        return val, tok
    rank = comm.rank()
    rrank = (rank - root) % n         # traced; root ≡ 0 in tree coordinates
    dtype = val.dtype
    as_bool = dtype == jnp.bool_
    cur = val.astype(jnp.int8) if as_bool else val
    d = 1
    while d < n:
        # ranks [0, d) send to [d, 2d) (tree coordinates), skipping dst ≥ n
        perm = [((root + i) % n, (root + i + d) % n)
                for i in range(min(d, n - d))]
        tok, cur = token_lib.tie(tok, cur)
        recv = jax.lax.ppermute(cur, comm.axes, perm)
        tok = token_lib.advance(tok, recv)
        is_receiver = (rrank >= d) & (rrank < min(2 * d, n))
        cur = jnp.where(is_receiver, recv, cur)
        d *= 2
    if as_bool:
        cur = cur.astype(jnp.bool_)
    return cur, tok


registry.register("bcast", "tree")(tree_bcast)


def pairwise_alltoall(val, tok, comm, *, split_axis=0, concat_axis=0):
    """MPI_Alltoall, pairwise exchange: round r ships chunk (me+r) mod n."""
    n = comm.size()
    if n == 1:
        return val, tok
    rank = comm.rank()
    chunk = val.shape[0] // n
    chunks = val.reshape(n, chunk, *val.shape[1:])
    out = jnp.zeros_like(chunks)
    own = jax.lax.dynamic_index_in_dim(chunks, rank, axis=0, keepdims=False)
    out = jax.lax.dynamic_update_index_in_dim(out, own, rank, axis=0)
    for shift in range(1, n):
        perm = [(i, (i + shift) % n) for i in range(n)]
        dst = (rank + shift) % n      # the rank whose chunk we ship this round
        send = jax.lax.dynamic_index_in_dim(chunks, dst, axis=0, keepdims=False)
        tok, send = token_lib.tie(tok, send)
        recv = jax.lax.ppermute(send, comm.axes, perm)
        tok = token_lib.advance(tok, recv)
        src = (rank - shift) % n      # who that chunk came from
        out = jax.lax.dynamic_update_index_in_dim(out, recv, src, axis=0)
    return out.reshape(val.shape), tok


def _pairwise_supports(val, comm, *, split_axis=0, concat_axis=0, **kw):
    return (len(comm.axes) == 1 and split_axis == 0 and concat_axis == 0
            and val.shape[0] % comm.size() == 0)


registry.register("alltoall", "pairwise",
                  supports=_pairwise_supports)(pairwise_alltoall)
