"""Gradient compression for data-parallel allreduce (beyond-paper feature).

Three wire-honest formats, the stateful two registered as first-class
collective lowerings (``int8_ef``, ``topk_ef``) next to the stateless
``bf16_wire`` entry:

* ``bf16_wire`` — bf16 payload through native ``psum`` (XLA keeps the wire
  in bf16): 2× fewer collective bytes than fp32.  Stateless registry entry.
* ``int8_ef``  — int8 wire format via the two-phase schedule
  ``all_to_all(int8) → local int32 accumulate → requantize → all_gather(int8)``.
  Per-rank wire bytes ≈ 2·|g|·1B versus ≈ 2·(n−1)/n·|g|·4B for an fp32 ring
  allreduce: a 4× reduction.  (A plain ``psum(int8→int32)`` would *not* be
  compressed — XLA moves int32 on the wire — which is why the schedule is
  explicit here.)
* ``topk_ef``  — top-k sparsification: each rank keeps the k = round(frac·|g|)
  largest-magnitude entries and allgathers (int32 index, fp32 value) pairs;
  everything it dropped feeds the residual.  Wire bytes scale with k, not
  |g| — the win grows as ``frac`` shrinks.

Error feedback (Seide et al. 2014; Karimireddy et al. 2019) is applied to the
send-side compression: the residual e_t is added to g_{t+1} before the next
compression, keeping the accumulated transmitted gradient unbiased up to a
vanishing tail.  The second-stage (post-sum) quantization error of the int8
schedule is not fed back (it is shared across ranks and one quantization
level of an n-fold sum); this matches common practice and is covered by the
oracle suite in ``tests/cases_compression.py`` and the convergence test in
``tests/test_compression.py``.

State threading through the registry
------------------------------------
A registry kernel's contract is ``fn(val, tok, comm, **kw) -> (out, tok)``
with ``out`` a plain array (the dispatch's ``advance(tok, out)`` folds one
scalar of it into the ordering token).  The EF lowerings extend the contract
*conditionally*: called with ``state=None`` (the stateless route — explicit
``algorithm="int8_ef"`` on a plain ``jmpi.allreduce``, or a policy-table
rule) they return the reduced array like any other kernel; called with a
:class:`CompressionState` they return ``(reduced, new_state)``, which the
plain ``_issue`` dispatch cannot thread — so the stateful front-ends below
(:func:`icompressed_allreduce`, :func:`compressed_allreduce`,
:func:`compressed_reduce_scatter`) run the select/tie/fn/advance sequence
themselves and hand back ``(Request, new_state)``.  Persistent plans freeze
kwargs in their cache signature, so traced state can never ride a plan —
stateful compression is Request-based by construction.

The emulated kernels' inner collectives (alltoall, allgather) go through the
registry like every other jmpi op, so a tuned policy table applies to the
compressed path too; the multiproc backend registers native ``direct``
twins in ``repro.transport.endpoint`` that put the small payloads on the
actual wire (int8 frames, index+value frames).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import collectives
from repro.core import registry
from repro.core import token as token_lib
from repro.core.comm import Communicator, resolve
from repro.core.p2p import Request, wait

#: Default keep fraction for the ``topk_ef`` lowering (k = frac·numel).
DEFAULT_TOPK_FRAC = 0.125

#: Lowerings that thread a CompressionState (the stateful front-ends below
#: accept exactly these names).
EF_ALGORITHMS = ("int8_ef", "topk_ef")


# ---------------------------------------------------------------------------
# Registry entry: stateless half-width wire for bandwidth-bound float sums.
# (The stateful error-feedback lowerings below are the training-grade path;
# this entry makes "halve the allreduce wire" a policy-table choice.)
# ---------------------------------------------------------------------------

def _bf16_supports(val, comm, *, op=None, **kw):
    """Float payloads only.  Integer and bool payloads must never be
    silently rounded through a bfloat16 wire, so they are rejected here —
    an explicit ``algorithm="bf16_wire"`` on such a payload raises the
    registry's uniform trace-time ValueError (message pinned in
    ``tests/test_registry.py``); policy-routed calls fall back."""
    dtype = jnp.dtype(val.dtype)
    if dtype == jnp.bool_ or jnp.issubdtype(dtype, jnp.integer):
        return False
    return jnp.issubdtype(dtype, jnp.floating)


@registry.register("allreduce", "bf16_wire", supports=_bf16_supports,
                   operators=(collectives.Operator.SUM,))
def _bf16_wire_allreduce(val, tok, comm, *, op=None):
    """SUM-allreduce with a bfloat16 wire: XLA keeps the psum payload in
    bf16, so collective bytes halve versus fp32 at ~3 decimal digits of
    mantissa.  Stateless (no error feedback) — select it only where the
    consumer tolerates bf16 rounding, e.g. via the tuned policy table."""
    out = jax.lax.psum(val.astype(jnp.bfloat16), comm.axes)
    return out.astype(val.dtype), tok


class CompressionState(NamedTuple):
    error: jax.Array  # send-side residual feedback buffer


def init_state(like: jax.Array) -> CompressionState:
    """Fresh error-feedback state for the compressed lowerings.

    Args:
        like: array whose shape the residual accumulator mirrors.
    Returns:
        A zeroed :class:`CompressionState`.
    """
    return CompressionState(error=jnp.zeros(like.shape, jnp.float32))


def _quantize(x32: jax.Array, qmax: float, comm: Communicator):
    amax = jax.lax.pmax(jnp.max(jnp.abs(x32)), comm.axes)
    scale = jnp.maximum(amax / qmax, 1e-30)
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Stateful EF lowerings (emulated backend).  Shared ``supports`` predicates
# are also used by the multiproc ``direct`` twins in transport/endpoint.py.
# ---------------------------------------------------------------------------

def _ef_supports(val, comm, **kw):
    """EF-lowering payload eligibility: real floating payloads only —
    quantizing an integer/bool payload would silently corrupt it, so the
    registry must reject (explicit name → uniform trace-time ValueError)."""
    dtype = jnp.dtype(val.dtype)
    if dtype == jnp.bool_ or jnp.issubdtype(dtype, jnp.integer):
        return False
    return jnp.issubdtype(dtype, jnp.floating)


def _ef_rs_supports(val, comm, **kw):
    """reduce_scatter additionally needs axis 0 divisible into rank chunks."""
    return (_ef_supports(val, comm) and val.ndim >= 1
            and val.shape[0] % comm.size() == 0)


def _int8_ef_exchange(g32, tok, comm):
    """Two-phase int8 wire schedule on the (EF-corrected) fp32 gradient:
    returns ``(summed_f32, new_error, tok)`` with explicit token threading
    so the kernel never touches the ambient chain of the outer dispatch."""
    n = comm.size()
    qmax = 127.0
    q, scale = _quantize(g32, qmax, comm)
    new_error = g32 - q.astype(jnp.float32) * scale

    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.int8)])
    seg_len = flat.shape[0] // n

    # Phase 1 (int8 wire): every rank receives its segment from all ranks.
    _, segs, tok = collectives.alltoall(flat.reshape(n, seg_len), comm=comm,
                                        token=tok)
    acc = segs.astype(jnp.int32).sum(axis=0).astype(jnp.float32) * scale

    # Requantize the reduced segment for the gather phase (int8 wire again).
    q2, scale2 = _quantize(acc, qmax, comm)

    # Phase 2 (int8 wire): collect every rank's reduced segment.
    _, gathered, tok = collectives.allgather(q2, comm=comm, token=tok)
    summed = gathered.astype(jnp.float32) * scale2
    if pad:
        summed = summed[:-pad]
    return summed.reshape(g32.shape), new_error, tok


def _topk_ef_exchange(g32, tok, comm, frac):
    """Top-k sparsified sum: allgather (int32 index, fp32 value) pairs and
    scatter-add; the dropped entries become the residual.  ``lax.top_k``
    breaks magnitude ties toward the lower index, so the selection is
    deterministic.  Returns ``(summed_f32, new_error, tok)``."""
    flat = g32.reshape(-1)
    k = max(1, int(round(frac * flat.shape[0])))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    vals = jnp.take(flat, idx)
    new_error = flat.at[idx].set(0.0).reshape(g32.shape)
    _, all_idx, tok = collectives.allgather(idx, comm=comm, token=tok)
    _, all_vals, tok = collectives.allgather(vals, comm=comm, token=tok)
    summed = jnp.zeros_like(flat).at[all_idx].add(all_vals)
    return summed.reshape(g32.shape), new_error, tok


def _ef_in(val, state):
    """fp32 working gradient with the EF residual folded in."""
    g32 = val.astype(jnp.float32)
    if state is not None:
        g32 = g32 + state.error.reshape(g32.shape).astype(jnp.float32)
    return g32


def _ef_out(val, out32, new_error, tok, *, state, mean, n):
    """Package a kernel result per the conditional contract: plain array
    when stateless, ``(reduced, CompressionState)`` when state was given."""
    out = (out32 / n if mean else out32).astype(val.dtype)
    if state is None:
        return out, tok
    return (out, CompressionState(error=new_error)), tok


@registry.register("allreduce", "int8_ef", supports=_ef_supports,
                   operators=(collectives.Operator.SUM,))
def _int8_ef_allreduce(val, tok, comm, *, op=None, state=None, mean=False,
                       **_kw):
    """SUM-allreduce over an int8 wire (two-phase schedule) with optional
    error-feedback state; ``mean=True`` divides by the group size after the
    exact int32 accumulation."""
    g32 = _ef_in(val, state)
    summed, new_error, tok = _int8_ef_exchange(g32, tok, comm)
    return _ef_out(val, summed, new_error, tok, state=state, mean=mean,
                   n=comm.size())


@registry.register("allreduce", "topk_ef", supports=_ef_supports,
                   operators=(collectives.Operator.SUM,))
def _topk_ef_allreduce(val, tok, comm, *, op=None, state=None, mean=False,
                       frac=DEFAULT_TOPK_FRAC, **_kw):
    """SUM-allreduce carrying only the top-k entries per rank as
    (index, value) pairs; the rest feeds the error-feedback residual."""
    g32 = _ef_in(val, state)
    summed, new_error, tok = _topk_ef_exchange(g32, tok, comm, frac)
    return _ef_out(val, summed, new_error, tok, state=state, mean=mean,
                   n=comm.size())


@registry.register("reduce_scatter", "int8_ef", supports=_ef_rs_supports,
                   operators=(collectives.Operator.SUM,))
def _int8_ef_reduce_scatter(val, tok, comm, *, op=None, state=None,
                            mean=False, **_kw):
    """reduce_scatter over the int8 wire: full two-phase sum, then this
    rank's axis-0 chunk.  The residual stays full-shape (it corrects the
    *input* gradient, which every rank holds whole)."""
    n = comm.size()
    g32 = _ef_in(val, state)
    summed, new_error, tok = _int8_ef_exchange(g32, tok, comm)
    chunk = val.shape[0] // n
    piece = jax.lax.dynamic_slice_in_dim(summed, comm.rank() * chunk, chunk,
                                         axis=0)
    return _ef_out(val, piece, new_error, tok, state=state, mean=mean, n=n)


@registry.register("reduce_scatter", "topk_ef", supports=_ef_rs_supports,
                   operators=(collectives.Operator.SUM,))
def _topk_ef_reduce_scatter(val, tok, comm, *, op=None, state=None,
                            mean=False, frac=DEFAULT_TOPK_FRAC, **_kw):
    """reduce_scatter carrying top-k (index, value) pairs: sparse sum, then
    this rank's axis-0 chunk; residual full-shape as for int8."""
    n = comm.size()
    g32 = _ef_in(val, state)
    summed, new_error, tok = _topk_ef_exchange(g32, tok, comm, frac)
    chunk = val.shape[0] // n
    piece = jax.lax.dynamic_slice_in_dim(summed, comm.rank() * chunk, chunk,
                                         axis=0)
    return _ef_out(val, piece, new_error, tok, state=state, mean=mean, n=n)


# ---------------------------------------------------------------------------
# Stateful front-ends: the select/tie/fn/advance sequence of the shared
# ``_issue`` dispatch, run by hand because the stateful kernel result is a
# (reduced, CompressionState) pair the plain dispatch cannot thread.
# ---------------------------------------------------------------------------

def _issue_compressed(op_name, g, state, *, comm, algorithm, mean, tag,
                      **algo_kw):
    if algorithm not in EF_ALGORITHMS:
        raise ValueError(
            f"stateful compression requires one of {EF_ALGORITHMS}, got "
            f"{algorithm!r} (stateless lowerings ride the plain collective "
            f"calls via algorithm=)")
    comm = resolve(comm)
    val = jnp.asarray(g)
    kw = dict(op=collectives.Operator.SUM, state=state, mean=mean, **algo_kw)
    algo = registry.select(op_name, val, comm, algorithm=algorithm, **kw)
    tok = token_lib.ambient().get()
    tok, val = token_lib.tie(tok, val)
    (out, new_state), tok = algo.fn(val, tok, comm, **kw)
    new_tok = token_lib.advance(tok, out)
    token_lib.ambient().set(new_tok)
    return Request(value=out, token=new_tok, tag=tag), new_state


def icompressed_allreduce(g, state: CompressionState, *,
                          comm: Communicator | None = None,
                          algorithm: str = "int8_ef", mean: bool = True,
                          tag: int = 0, frac: float = DEFAULT_TOPK_FRAC):
    """Nonblocking compressed allreduce: ``(Request, new_state)``.

    The EF residual depends only on this rank's local compression, so
    ``new_state`` is available at issue time; the reduced value completes
    at ``wait``/``waitall`` like any other Request.  This is what lets
    bucketed gradient sync put every bucket in flight before a single
    ``waitall`` ahead of the optimizer (``distributed.overlap``).

    Args:
        g: local gradient (any float dtype/shape).
        state: :class:`CompressionState` threaded across steps.
        algorithm: one of :data:`EF_ALGORITHMS`.
        mean: divide the sum by the group size.
        frac: keep fraction for ``topk_ef`` (ignored by ``int8_ef``).
    """
    algo_kw = {"frac": frac} if algorithm == "topk_ef" else {}
    return _issue_compressed("allreduce", g, state, comm=comm,
                             algorithm=algorithm, mean=mean, tag=tag,
                             **algo_kw)


def compressed_allreduce(g: jax.Array, state: CompressionState, *,
                         comm: Communicator | None = None,
                         bits: int = 8, mean: bool = True,
                         algorithm: str | None = None,
                         frac: float = DEFAULT_TOPK_FRAC):
    """(status, reduced, new_state) — mean/sum-allreduce with compressed wire.

    ``algorithm`` (preferred) names a registered EF lowering directly;
    ``bits`` keeps the historical selector: 8 → ``int8_ef`` (now routed
    through the registry), 16 → the inline bf16 send-side-EF path.
    """
    comm = resolve(comm)
    n = comm.size()

    if algorithm is None:
        if bits == 16:
            g32 = g.astype(jnp.float32) + state.error
            sent = g32.astype(jnp.bfloat16)
            status, summed = collectives.allreduce(sent, comm=comm)
            summed = summed.astype(jnp.float32)
            new_error = g32 - sent.astype(jnp.float32)  # send-side residual
            out = summed / n if mean else summed
            return status, out.astype(g.dtype), CompressionState(error=new_error)
        if bits != 8:
            raise ValueError(f"bits must be 8 or 16, got {bits}")
        algorithm = "int8_ef"

    req, new_state = icompressed_allreduce(g, state, comm=comm,
                                           algorithm=algorithm, mean=mean,
                                           frac=frac)
    status, out = wait(req)
    return status, out, new_state


def compressed_reduce_scatter(g: jax.Array, state: CompressionState, *,
                              comm: Communicator | None = None,
                              algorithm: str = "int8_ef", mean: bool = True,
                              frac: float = DEFAULT_TOPK_FRAC):
    """(status, chunk, new_state) — reduce_scatter over a compressed wire:
    this rank's axis-0 chunk of the (mean-)reduced gradient, with the EF
    residual threaded exactly as in :func:`compressed_allreduce`."""
    algo_kw = {"frac": frac} if algorithm == "topk_ef" else {}
    req, new_state = _issue_compressed("reduce_scatter", g, state, comm=comm,
                                       algorithm=algorithm, mean=mean, tag=0,
                                       **algo_kw)
    status, out = wait(req)
    return status, out, new_state


def wire_bytes_per_rank(numel: int, n: int, bits: int = 8,
                        baseline_dtype=jnp.float32,
                        topk_frac: float | None = None) -> tuple[float, float]:
    """(compressed, fp32-ring-psum) wire bytes per rank — used by §Perf math.

    ``topk_frac`` switches the compressed model to the ``topk_ef`` lowering:
    each rank allgathers k = max(1, round(frac·numel)) (int32 index, fp32
    value) pairs — the index bytes count toward the wire, so top-k only wins
    below frac ≈ base/(8·numel) of the dense payload.
    """
    base = 2 * (n - 1) / n * numel * jnp.dtype(baseline_dtype).itemsize
    if topk_frac is not None:
        k = max(1, int(round(topk_frac * numel)))
        comp = (n - 1) * k * (4 + 4)  # ring allgather of (idx i32, val f32)
    elif bits == 16:
        comp = 2 * (n - 1) / n * numel * 2
    else:
        comp = 2 * numel * 1
    return float(comp), float(base)
