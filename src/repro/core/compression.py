"""Gradient compression for data-parallel allreduce (beyond-paper feature).

Two wire-honest modes:

* ``bits=16`` — bf16 payload through native ``psum`` (XLA keeps the wire in
  bf16): 2× fewer collective bytes than fp32.
* ``bits=8``  — int8 wire format via the two-phase schedule
  ``all_to_all(int8) → local int32 accumulate → requantize → all_gather(int8)``.
  Per-rank wire bytes ≈ 2·|g|·1B versus ≈ 2·(n−1)/n·|g|·4B for an fp32 ring
  allreduce: a 4× reduction.  (A plain ``psum(int8→int32)`` would *not* be
  compressed — XLA moves int32 on the wire — which is why the schedule is
  explicit here.)

Error feedback (Seide et al. 2014; Karimireddy et al. 2019) is applied to the
send-side quantization: the residual e_t is added to g_{t+1} before the next
compression, keeping the accumulated transmitted gradient unbiased up to a
vanishing tail.  The second-stage (post-sum) quantization error is not fed
back (it is shared across ranks and one quantization level of an n-fold sum);
this matches common practice and is covered by the convergence test in
``tests/test_compression.py``.

The two-phase int8 schedule's inner collectives (alltoall, allgather) go
through the collective-algorithm registry like every other jmpi op, so a
tuned policy table applies to the compressed path too; the stateless
``bf16_wire`` allreduce below is itself a registry entry.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import collectives
from repro.core import registry
from repro.core.comm import Communicator, resolve


# ---------------------------------------------------------------------------
# Registry entry: stateless half-width wire for bandwidth-bound float sums.
# (The stateful error-feedback path below remains the training-grade API;
# this entry makes "halve the allreduce wire" a policy-table choice.)
# ---------------------------------------------------------------------------

def _bf16_supports(val, comm, *, op=None, **kw):
    return jnp.issubdtype(val.dtype, jnp.floating)


@registry.register("allreduce", "bf16_wire", supports=_bf16_supports,
                   operators=(collectives.Operator.SUM,))
def _bf16_wire_allreduce(val, tok, comm, *, op=None):
    """SUM-allreduce with a bfloat16 wire: XLA keeps the psum payload in
    bf16, so collective bytes halve versus fp32 at ~3 decimal digits of
    mantissa.  Stateless (no error feedback) — select it only where the
    consumer tolerates bf16 rounding, e.g. via the tuned policy table."""
    out = jax.lax.psum(val.astype(jnp.bfloat16), comm.axes)
    return out.astype(val.dtype), tok


class CompressionState(NamedTuple):
    error: jax.Array  # send-side residual feedback buffer


def init_state(like: jax.Array) -> CompressionState:
    """Fresh error-feedback state for :func:`compressed_allreduce`.

    Args:
        like: array whose shape the residual accumulator mirrors.
    Returns:
        A zeroed :class:`CompressionState`.
    """
    return CompressionState(error=jnp.zeros(like.shape, jnp.float32))


def _quantize(x32: jax.Array, qmax: float, comm: Communicator):
    amax = jax.lax.pmax(jnp.max(jnp.abs(x32)), comm.axes)
    scale = jnp.maximum(amax / qmax, 1e-30)
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def compressed_allreduce(g: jax.Array, state: CompressionState, *,
                         comm: Communicator | None = None,
                         bits: int = 8, mean: bool = True):
    """(status, reduced, new_state) — mean/sum-allreduce with compressed wire."""
    comm = resolve(comm)
    n = comm.size()
    g32 = g.astype(jnp.float32) + state.error

    if bits == 16:
        sent = g32.astype(jnp.bfloat16)
        status, summed = collectives.allreduce(sent, comm=comm)
        summed = summed.astype(jnp.float32)
        new_error = g32 - sent.astype(jnp.float32)  # send-side rounding residual
        out = summed / n if mean else summed
        return status, out.astype(g.dtype), CompressionState(error=new_error)

    if bits != 8:
        raise ValueError(f"bits must be 8 or 16, got {bits}")
    qmax = 127.0

    q, scale = _quantize(g32, qmax, comm)
    new_error = g32 - q.astype(jnp.float32) * scale

    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.int8)])
    seg_len = flat.shape[0] // n

    # Phase 1 (int8 wire): every rank receives its segment from all ranks.
    status, segs = collectives.alltoall(flat.reshape(n, seg_len), comm=comm)
    acc = segs.astype(jnp.int32).sum(axis=0).astype(jnp.float32) * scale  # (seg_len,)

    # Requantize the reduced segment for the gather phase (int8 wire again).
    q2, scale2 = _quantize(acc, qmax, comm)

    # Phase 2 (int8 wire): collect every rank's reduced segment.
    status, gathered = collectives.allgather(q2, comm=comm)
    summed = gathered.astype(jnp.float32) * scale2
    if pad:
        summed = summed[:-pad]
    out = summed.reshape(g.shape)
    if mean:
        out = out / n
    return status, out.astype(g.dtype), CompressionState(error=new_error)


def wire_bytes_per_rank(numel: int, n: int, bits: int = 8,
                        baseline_dtype=jnp.float32) -> tuple[float, float]:
    """(compressed, fp32-ring-psum) wire bytes per rank — used by §Perf math."""
    base = 2 * (n - 1) / n * numel * jnp.dtype(baseline_dtype).itemsize
    if bits == 16:
        comp = 2 * (n - 1) / n * numel * 2
    else:
        comp = 2 * numel * 1
    return float(comp), float(base)
