"""Pipeline parallelism over a mesh axis, built on jmpi point-to-point.

GPipe-style schedule under SPMD: every stage holds its own layer slice; the
activations travel stage→stage through ``jmpi.sendrecv`` along a
*non-periodic* 1-D Cartesian topology (``comm.cart_create((P,),
periods=(False,))``) — the stage chain is a line, not a ring, and
``cart_shift_perm`` expresses exactly that: the last stage's boundary send
is dropped (null-rank semantics) instead of wrapping stale activations back
to stage 0.  All communication is *inside* the jit program (JIT-resident —
the paper's thesis applied to pipelining).  With M microbatches and P
stages the steady-state rotation runs M+P−1 ticks; each tick every stage
processes one microbatch and the boundary activations shift one hop.

This is the alternative use of the multi-pod ``pod`` axis (DESIGN.md §7.5);
correctness is asserted against the single-device stacked forward in
tests/cases_pipeline.py.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

import repro.core as jmpi


def pipeline_forward(x_microbatches, stage_fn: Callable, comm: jmpi.Communicator):
    """Run a P-stage pipeline over M microbatches.

    x_microbatches: (M, ...) — every stage receives the same global inputs;
    stage 0 consumes them, later stages consume upstream activations.
    stage_fn(x) applies THIS stage's layer slice (per-device code under
    shard_map).  Returns (M, ...) final-stage outputs (valid on the last
    stage; earlier stages hold zeros), matching SPMD collective-output
    semantics.
    """
    p = comm.size()
    m = x_microbatches.shape[0]
    rank = comm.rank()
    # stage chain as a non-periodic 1-D Cartesian topology: the +1 shift
    # pattern drops the last stage's boundary send (PROC_NULL semantics)
    cart = comm.cart_create((p,), periods=(False,))
    fwd = cart.cart_shift_perm(0, +1)
    shape = x_microbatches.shape[1:]

    def tick(t, carry):
        inbuf, outbuf, tok = carry
        # which microbatch enters stage 0 at tick t
        mb_idx = jnp.clip(t, 0, m - 1)
        first_in = jax.lax.dynamic_index_in_dim(x_microbatches, mb_idx, 0,
                                                keepdims=False)
        x_in = jnp.where(rank == 0, first_in, inbuf)
        active = (t - rank >= 0) & (t - rank < m)
        y = stage_fn(x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # shift stage outputs one hop down the ring (explicit token: the
        # ordering chain lives in the loop carry, never the ambient context)
        status, nxt, tok = jmpi.sendrecv(y, pairs=fwd, comm=comm, token=tok)
        # last stage banks its finished microbatch (t - (p-1))
        done_idx = jnp.clip(t - (p - 1), 0, m - 1)
        bank = (rank == p - 1) & (t - (p - 1) >= 0) & (t - (p - 1) < m)
        outbuf = jax.lax.cond(
            bank,
            lambda ob: jax.lax.dynamic_update_index_in_dim(
                ob, y, done_idx, 0),
            lambda ob: ob, outbuf)
        return nxt, outbuf, tok

    inbuf = jnp.zeros(shape, x_microbatches.dtype)
    outbuf = jnp.zeros_like(x_microbatches)
    inbuf, outbuf, _ = jax.lax.fori_loop(
        0, m + p - 1, tick, (inbuf, outbuf, jmpi.new_token()))
    return outbuf
