"""Logical-axis sharding: one rule table, resolved per arch × shape × mesh.

Models annotate activations/params with *logical* axis names; the active
``ShardingContext`` maps them to mesh axes with divisibility fallback (a dim
is only sharded if the mesh axis size divides it — e.g. qwen2's 12 heads on a
16-wide model axis fall back to replication, DESIGN.md §4).  With no context
installed (single-device smoke tests) every helper is a no-op.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (first fit that divides wins; tuple
# entries request sharding over multiple mesh axes jointly)
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"), ("data",)),
    "batch_attn": (("pod", "data"), ("data",)),  # attention-section batch
    "seq_attn": (None,),             # attention-section query-sequence dim;
    # hillclimb A overrides to ("model",) when heads can't shard over model
    "seq": (None,),                  # context-parallel cells override
    "kv_seq": (None,),
    "embed": (None,),
    "fsdp_embed": (("pod", "data"), ("data",)),   # weight FSDP dim
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (None,),
    "ff": (("model",),),
    "vocab": (("model",),),
    "experts": (("model",),),
    "moe_groups": (("pod", "data"), ("data",)),  # MoE dispatch groups;
    # expert-2D variant sets this None and experts to ("model","data")
    "expert_ff": (None,),
    "inner": (("model",),),          # ssm/xlstm inner projections
    "state": (None,),
    "cond": (None,),
}


@dataclasses.dataclass
class ShardingContext:
    mesh: Mesh
    rules: dict[str, tuple]

    def spec_for(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor described by logical axis names.

        ``shape`` (if given) enables the divisibility fallback per dim.
        """
        axis_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        used: set[str] = set()
        entries = []
        for i, name in enumerate(logical):
            if name is None:
                entries.append(None)
                continue
            candidates = self.rules.get(name, (None,))
            picked = None
            for cand in candidates:
                if cand is None:
                    continue
                cand = tuple(a for a in cand if a in axis_sizes)
                if not cand or any(a in used for a in cand):
                    continue
                total = int(np.prod([axis_sizes[a] for a in cand]))
                if shape is not None and shape[i] % total != 0:
                    continue
                picked = cand
                break
            if picked:
                used.update(picked)
                entries.append(picked if len(picked) > 1 else picked[0])
            else:
                entries.append(None)
        return P(*entries)

    def sharding_for(self, logical, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical, shape))


_ACTIVE: list[Optional[ShardingContext]] = [None]


def active() -> Optional[ShardingContext]:
    return _ACTIVE[0]


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Install a sharding context (None mesh = no-op annotations)."""
    prev = _ACTIVE[0]
    if mesh is None:
        _ACTIVE[0] = None
    else:
        merged = dict(DEFAULT_RULES)
        if rules:
            merged.update(rules)
        _ACTIVE[0] = ShardingContext(mesh=mesh, rules=merged)
    try:
        yield _ACTIVE[0]
    finally:
        _ACTIVE[0] = prev


def shard(x, *logical: Optional[str]):
    """Constrain activation ``x`` to the logical layout (no-op w/o context)."""
    ctx = _ACTIVE[0]
    if ctx is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"{len(logical)} logical axes for rank-{x.ndim} tensor")
    spec = ctx.spec_for(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def param_spec(logical: Sequence[Optional[str]], shape) -> P:
    ctx = _ACTIVE[0]
    if ctx is None:
        return P()
    return ctx.spec_for(logical, shape)


# Context-parallel override used by long_500k decode cells: the KV/sequence
# dim spreads over the data axis (batch=1 leaves it idle otherwise).
CONTEXT_PARALLEL_RULES = {
    "kv_seq": (("data",),),
    "batch": (None,),
}

# Sequence-parallel residual stream (Megatron-SP analogue): hillclimb lever.
SEQUENCE_PARALLEL_RULES = {
    "seq": (("model",),),
}
