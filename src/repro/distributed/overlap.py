"""Overlapped communication schedules: the ring collective matmuls, and the
bucketed gradient sync that lifts the same issue-early/complete-late pattern
to the data-parallel gradient path (:func:`bucketed_grad_sync`).

The classic TPU optimization for tensor-parallel layers whose input is
sharded on the contraction-adjacent dim: instead of ``all_gather(x) @ w``
(ICI idle while the MXU waits, MXU idle while ICI moves x), rotate x's
shards around the ring and multiply each arriving shard immediately —
n−1 ppermute hops, each hidden under the concurrent (m/n)-sized matmul.

Wire bytes equal the plain allgather's; the win is *overlap*, which the
dry-run shows structurally: n small matmuls interleaved with n−1 permutes,
no serial allgather→matmul dependency (EXPERIMENTS.md §Perf hillclimb 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core as jmpi


def collective_matmul_ag(x_shard, w_full, comm: jmpi.Communicator):
    """y = allgather(x) @ w, ring-overlapped.

    x_shard: (m/n, k) this rank's row shard; w_full: (k, p) replicated.
    Returns (m, p) — identical on every rank.
    """
    n = comm.size()
    rank = comm.rank()
    fwd = comm.ring_perm(+1)
    m_shard = x_shard.shape[0]
    p = w_full.shape[1]
    out = jnp.zeros((n * m_shard, p), x_shard.dtype)

    cur = x_shard
    for hop in range(n):
        # multiply the shard we currently hold (arrived from rank - hop)
        src = (rank - hop) % n
        y = cur @ w_full
        out = jax.lax.dynamic_update_slice_in_dim(out, y, src * m_shard,
                                                  axis=0)
        if hop < n - 1:
            _, cur = jmpi.sendrecv(cur, pairs=fwd, comm=comm)
    return out


def collective_matmul_rs(x_full, w_shard, comm: jmpi.Communicator):
    """y_shard = reduce_scatter(x @ w_partial), ring-overlapped.

    x_full: (m, k/n) this rank's contraction shard; w_shard: (k/n, p).
    Returns (m/n, p): rank r holds rows r·m/n..(r+1)·m/n of x@w summed over
    the contraction.  The partial-sum accumulator travels the ring and picks
    up each rank's local matmul on arrival (comm hidden under compute).
    """
    n = comm.size()
    rank = comm.rank()
    m = x_full.shape[0]
    assert m % n == 0
    ms = m // n
    bwd = comm.ring_perm(-1)

    # At step t, this rank holds the in-flight accumulator of the chunk
    # destined for rank (rank + t + 1) mod n; it arrives home at t = n−1.
    acc = jnp.zeros((ms, w_shard.shape[1]), jnp.float32)
    for t in range(n):
        dst = (rank + t + 1) % n
        xs = jax.lax.dynamic_slice_in_dim(x_full, dst * ms, ms, axis=0)
        acc = acc + (xs @ w_shard).astype(jnp.float32)
        if t < n - 1:
            _, acc = jmpi.sendrecv(acc, pairs=bwd, comm=comm)
    return acc.astype(x_full.dtype)


# ---------------------------------------------------------------------------
# Plan-routed entry points: a persistent plan freezes the policy's choice
# for the payload signature once; ``ring`` plans take the overlapped matmul
# schedule, anything else starts the plan's own frozen lowering.
# ---------------------------------------------------------------------------

def matmul_allgather(x_shard, w_full, comm: jmpi.Communicator):
    """y = allgather(x) @ w, with the collective-algorithm policy choosing
    the schedule per payload: the allgather plan (cached per shape/dtype/
    comm) freezes the policy's trace-time choice — if it froze ``ring``,
    use the ring-overlapped collective matmul (comm hidden under the n
    partial matmuls); otherwise start the plan's lowering and matmul the
    gathered result, which XLA fuses best when the native allgather wins.
    """
    # Plan key = the per-shard payload handed to the collective (NOT the
    # gathered size) — identical to what a plain jmpi.allgather would see.
    plan = comm.allgather_init(
        jax.ShapeDtypeStruct(x_shard.shape, x_shard.dtype))
    if plan.algorithm == "ring":
        return collective_matmul_ag(x_shard, w_full, comm)
    _, gathered = jmpi.wait(plan.start(x_shard))
    return gathered @ w_full


def matmul_reduce_scatter(x_full, w_shard, comm: jmpi.Communicator):
    """y_shard = reduce_scatter(x @ w_partial), plan-routed like
    :func:`matmul_allgather` (ring → overlapped accumulator schedule)."""
    # Plan key: the (m, p) partial product that reduce_scatter receives.
    plan = comm.reduce_scatter_init(
        jax.ShapeDtypeStruct((x_full.shape[0], w_shard.shape[1]),
                             x_full.dtype))
    if plan.algorithm == "ring":
        return collective_matmul_rs(x_full, w_shard, comm)
    partial = (x_full @ w_shard).astype(x_full.dtype)
    _, out = jmpi.wait(plan.start(partial))
    return out


# ---------------------------------------------------------------------------
# Bucketed gradient sync — the overlap pattern lifted to the gradient path.
# ---------------------------------------------------------------------------

def _bucket_spans(leaves, buckets: int) -> list[tuple[int, int]]:
    """Contiguous [lo, hi) leaf spans, greedily balanced by element count.

    Deterministic (pure function of the static leaf shapes), so every rank
    and every re-trace carves identical buckets — a requirement for the
    collective payloads to match across the group.
    """
    import numpy as np
    buckets = max(1, min(buckets, len(leaves)))
    sizes = [int(np.prod(l.shape, dtype=int)) for l in leaves]
    total = sum(sizes)
    target = total / buckets
    spans, lo, acc = [], 0, 0
    for i, s in enumerate(sizes):
        acc += s
        # close the bucket once it reaches its share, keeping enough leaves
        # behind to give every remaining bucket at least one
        if (acc >= target * (len(spans) + 1) or
                len(leaves) - (i + 1) <= buckets - len(spans) - 1) \
                and len(spans) < buckets - 1:
            spans.append((lo, i + 1))
            lo = i + 1
    spans.append((lo, len(leaves)))
    return [s for s in spans if s[0] < s[1]]


def bucketed_grad_sync(grads, comp, *, comm: jmpi.Communicator,
                       algorithm: str = "", buckets: int = 1,
                       overlap: bool = False, mean: bool = True,
                       plan_algorithm: str | None = None,
                       trace_log: list | None = None):
    """Data-parallel gradient sync over contiguous leaf buckets, optionally
    compressed and overlap-issued.  Returns ``(reduced_tree, new_comp)``.

    Each bucket's leaves pack into one fp32 wire vector via a ``jmpi.pytree``
    derived datatype (NCCL-style bucketing as a datatype).  ``algorithm``:

    * ``""`` — fp32 buckets through persistent allreduce plans (the policy
      table picks the lowering per bucket size, or ``plan_algorithm``
      forces one); ``comp`` passes through.
    * ``"int8_ef"`` / ``"topk_ef"`` — the stateful compressed lowerings;
      ``comp`` must be a tree of :class:`jmpi.CompressionState` congruent
      with ``grads`` (``jax.tree.map(jmpi.init_state, params)``); per-bucket
      residual vectors ride their own fp32 pytree datatype.

    ``overlap=True`` issues every bucket's nonblocking allreduce first and
    completes them with ONE ``jmpi.waitall`` barrier — the Request model's
    issue-early/complete-late window, during which XLA's latency-hiding
    scheduler overlaps the remaining backward/optimizer-prep compute with
    the in-flight collectives.  ``overlap=False`` waits on each bucket
    before issuing the next.  Both orders chain the same collectives over
    the same payloads, so results are bitwise identical — pinned by the
    overlap-ordering case in ``tests/cases_compression.py``.

    ``trace_log``: optional Python list capturing trace-time scheduling
    events — ``("issue", b)``, ``("wait", b)``, ``("waitall",)`` — so tests
    can pin that every issue precedes the single waitall.
    """
    from repro.core.compression import EF_ALGORITHMS

    compressed = bool(algorithm)
    if compressed and algorithm not in EF_ALGORITHMS:
        raise ValueError(f"unknown gradient compression {algorithm!r}; "
                         f"expected one of {EF_ALGORITHMS} (or \"\" for fp32)")

    leaves, tdef = jax.tree.flatten(grads)
    spans = _bucket_spans(leaves, buckets)
    n = comm.size()
    out_leaves: list = [None] * len(leaves)
    if compressed:
        cstates = tdef.flatten_up_to(comp)
        new_cstates = list(cstates)

    pending = []  # (span, grad_dt, err_dt, Request)
    for b, (lo, hi) in enumerate(spans):
        sub = leaves[lo:hi]
        dt = jmpi.pytree(sub, wire_dtype=jnp.float32)
        vec = dt.pack(sub)
        if trace_log is not None:
            trace_log.append(("issue", b))
        if compressed:
            errs = [cs.error for cs in cstates[lo:hi]]
            edt = jmpi.pytree(errs, wire_dtype=jnp.float32)
            req, new_state = jmpi.icompressed_allreduce(
                vec, jmpi.CompressionState(error=edt.pack(errs)),
                comm=comm, algorithm=algorithm, mean=mean)
            # The residual depends only on the local quantization, so it is
            # available at issue time — thread it immediately.
            for i, ne in zip(range(lo, hi), edt.unpack(new_state.error)):
                new_cstates[i] = jmpi.CompressionState(error=ne)
        else:
            plan = comm.allreduce_init(
                jax.ShapeDtypeStruct(vec.shape, vec.dtype),
                algorithm=plan_algorithm)
            req = plan.start(vec)
        if overlap:
            pending.append(((lo, hi), dt, req))
        else:
            if trace_log is not None:
                trace_log.append(("wait", b))
            _, rvec = jmpi.wait(req)
            if not compressed and mean:
                rvec = rvec / n
            out_leaves[lo:hi] = dt.unpack(rvec)

    if overlap:
        if trace_log is not None:
            trace_log.append(("waitall",))
        _, rvecs = jmpi.waitall([req for _, _, req in pending])
        for ((lo, hi), dt, _), rvec in zip(pending, rvecs):
            if not compressed and mean:
                rvec = rvec / n
            out_leaves[lo:hi] = dt.unpack(rvec)

    reduced = jax.tree.unflatten(tdef, out_leaves)
    new_comp = jax.tree.unflatten(tdef, new_cstates) if compressed else comp
    return reduced, new_comp
