"""Collective matmul: ring allgather fused with partial matmuls (overlap).

The classic TPU optimization for tensor-parallel layers whose input is
sharded on the contraction-adjacent dim: instead of ``all_gather(x) @ w``
(ICI idle while the MXU waits, MXU idle while ICI moves x), rotate x's
shards around the ring and multiply each arriving shard immediately —
n−1 ppermute hops, each hidden under the concurrent (m/n)-sized matmul.

Wire bytes equal the plain allgather's; the win is *overlap*, which the
dry-run shows structurally: n small matmuls interleaved with n−1 permutes,
no serial allgather→matmul dependency (EXPERIMENTS.md §Perf hillclimb 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core as jmpi


def collective_matmul_ag(x_shard, w_full, comm: jmpi.Communicator):
    """y = allgather(x) @ w, ring-overlapped.

    x_shard: (m/n, k) this rank's row shard; w_full: (k, p) replicated.
    Returns (m, p) — identical on every rank.
    """
    n = comm.size()
    rank = comm.rank()
    fwd = comm.ring_perm(+1)
    m_shard = x_shard.shape[0]
    p = w_full.shape[1]
    out = jnp.zeros((n * m_shard, p), x_shard.dtype)

    cur = x_shard
    for hop in range(n):
        # multiply the shard we currently hold (arrived from rank - hop)
        src = (rank - hop) % n
        y = cur @ w_full
        out = jax.lax.dynamic_update_slice_in_dim(out, y, src * m_shard,
                                                  axis=0)
        if hop < n - 1:
            _, cur = jmpi.sendrecv(cur, pairs=fwd, comm=comm)
    return out


def collective_matmul_rs(x_full, w_shard, comm: jmpi.Communicator):
    """y_shard = reduce_scatter(x @ w_partial), ring-overlapped.

    x_full: (m, k/n) this rank's contraction shard; w_shard: (k/n, p).
    Returns (m/n, p): rank r holds rows r·m/n..(r+1)·m/n of x@w summed over
    the contraction.  The partial-sum accumulator travels the ring and picks
    up each rank's local matmul on arrival (comm hidden under compute).
    """
    n = comm.size()
    rank = comm.rank()
    m = x_full.shape[0]
    assert m % n == 0
    ms = m // n
    bwd = comm.ring_perm(-1)

    # At step t, this rank holds the in-flight accumulator of the chunk
    # destined for rank (rank + t + 1) mod n; it arrives home at t = n−1.
    acc = jnp.zeros((ms, w_shard.shape[1]), jnp.float32)
    for t in range(n):
        dst = (rank + t + 1) % n
        xs = jax.lax.dynamic_slice_in_dim(x_full, dst * ms, ms, axis=0)
        acc = acc + (xs @ w_shard).astype(jnp.float32)
        if t < n - 1:
            _, acc = jmpi.sendrecv(acc, pairs=bwd, comm=comm)
    return acc.astype(x_full.dtype)


# ---------------------------------------------------------------------------
# Plan-routed entry points: a persistent plan freezes the policy's choice
# for the payload signature once; ``ring`` plans take the overlapped matmul
# schedule, anything else starts the plan's own frozen lowering.
# ---------------------------------------------------------------------------

def matmul_allgather(x_shard, w_full, comm: jmpi.Communicator):
    """y = allgather(x) @ w, with the collective-algorithm policy choosing
    the schedule per payload: the allgather plan (cached per shape/dtype/
    comm) freezes the policy's trace-time choice — if it froze ``ring``,
    use the ring-overlapped collective matmul (comm hidden under the n
    partial matmuls); otherwise start the plan's lowering and matmul the
    gathered result, which XLA fuses best when the native allgather wins.
    """
    # Plan key = the per-shard payload handed to the collective (NOT the
    # gathered size) — identical to what a plain jmpi.allgather would see.
    plan = comm.allgather_init(
        jax.ShapeDtypeStruct(x_shard.shape, x_shard.dtype))
    if plan.algorithm == "ring":
        return collective_matmul_ag(x_shard, w_full, comm)
    _, gathered = jmpi.wait(plan.start(x_shard))
    return gathered @ w_full


def matmul_reduce_scatter(x_full, w_shard, comm: jmpi.Communicator):
    """y_shard = reduce_scatter(x @ w_partial), plan-routed like
    :func:`matmul_allgather` (ring → overlapped accumulator schedule)."""
    # Plan key: the (m, p) partial product that reduce_scatter receives.
    plan = comm.reduce_scatter_init(
        jax.ShapeDtypeStruct((x_full.shape[0], w_shard.shape[1]),
                             x_full.dtype))
    if plan.algorithm == "ring":
        return collective_matmul_rs(x_full, w_shard, comm)
    partial = (x_full @ w_shard).astype(x_full.dtype)
    _, out = jmpi.wait(plan.start(partial))
    return out
