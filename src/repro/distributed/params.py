"""Parameter / input / cache PartitionSpec assignment (per arch × mesh).

Any spec is *correct* under GSPMD (the partitioner reshards as needed) — the
rules here pick the memory/perf-right layout: TP dims (heads / ff / vocab /
experts) over ``model``, an FSDP dim (usually d_model) over (``pod``,
``data``), everything small replicated.  Divisibility fallback mirrors
DESIGN.md §4 (qwen2's 12 heads, mixtral's 8 experts, ...).
"""

from __future__ import annotations

import re

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import jax

from repro.core import registry


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


class ParamSharder:
    def __init__(self, cfg, mesh, fsdp: bool = True, expert_2d: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.sizes = _axis_sizes(mesh)
        self.model_n = self.sizes.get("model", 1)
        dp_axes = tuple(a for a in ("pod", "data") if a in self.sizes)
        self.dp_axes = dp_axes
        self.dp_n = int(np.prod([self.sizes[a] for a in dp_axes])) if dp_axes else 1
        self.fsdp = fsdp
        # 2-D expert parallelism (§Perf B7): experts shard over model×data
        # jointly (1 expert/device at deepseek's 256) — whole expert weights
        # live on their owner, zero FSDP gather per step.
        self.expert_2d = expert_2d

    def _model_ok(self, dim):
        return self.model_n > 1 and dim % self.model_n == 0

    def _dp_ok(self, dim):
        return self.fsdp and self.dp_n > 1 and dim % self.dp_n == 0

    def _dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def spec_for_param(self, path: str, shape) -> P:
        """path: '/'-joined key path, e.g. 'main/attn/wq'."""
        cfg = self.cfg
        s = list(shape)
        # stacked layer dim (from init_stack/vmap): leading dim == n_layers-ish
        # is never sharded; detect by path living under a stack.
        def spec(*entries):
            return P(*entries)

        model = lambda d: self._model_ok(d)
        dp = lambda d: self._dp_ok(d)
        DP = self._dp()

        # --- embeddings / heads ------------------------------------------
        if re.search(r"(embed|head)/table$", path):
            v, d = s[-2], s[-1]
            return spec(*(["model" if model(v) else None,
                           DP if dp(d) else None]))

        # --- attention ----------------------------------------------------
        if re.search(r"attn/w[qkv]$", path) or re.search(r"xattn/w[qkv]$", path):
            ld = [None] * (len(s) - 3)
            d, h, k = s[-3], s[-2], s[-1]
            return spec(*ld, DP if dp(d) else None,
                        "model" if model(h) else None, None)
        if re.search(r"attn/wo$", path) or re.search(r"xattn/wo$", path):
            ld = [None] * (len(s) - 3)
            h, k, d = s[-3], s[-2], s[-1]
            return spec(*ld, "model" if model(h) else None, None,
                        DP if dp(d) else None)
        if re.search(r"attn/b[qkv]$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, "model" if model(s[-2]) else None, None)
        # MLA pieces
        if re.search(r"attn/w_d(q|kv)$", path) or re.search(r"attn/w_kr$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, DP if dp(s[-2]) else None, None)
        if re.search(r"attn/w_u[qkv]$", path):
            ld = [None] * (len(s) - 3)
            return spec(*ld, None, "model" if model(s[-2]) else None, None)

        # --- MLP -----------------------------------------------------------
        if re.search(r"mlp/w_(in|gate)$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, DP if dp(s[-2]) else None,
                        "model" if model(s[-1]) else None)
        if re.search(r"mlp/w_out$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, "model" if model(s[-2]) else None,
                        DP if dp(s[-1]) else None)

        # --- MoE ------------------------------------------------------------
        if re.search(r"moe/router$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, DP if dp(s[-2]) else None, None)
        if re.search(r"moe/w_(in|gate)$", path):
            ld = [None] * (len(s) - 3)
            e, d, f = s[-3], s[-2], s[-1]
            if self.expert_2d and e % (self.model_n * self.dp_n) == 0:
                return spec(*ld, ("model",) + self.dp_axes, None, None)
            if model(e):            # EP (deepseek)
                return spec(*ld, "model", DP if dp(d) else None, None)
            return spec(*ld, None, DP if dp(d) else None,   # expert-TP
                        "model" if model(f) else None)
        if re.search(r"moe/w_out$", path):
            ld = [None] * (len(s) - 3)
            e, f, d = s[-3], s[-2], s[-1]
            if self.expert_2d and e % (self.model_n * self.dp_n) == 0:
                return spec(*ld, ("model",) + self.dp_axes, None, None)
            if model(e):
                return spec(*ld, "model", None, DP if dp(d) else None)
            return spec(*ld, None, "model" if model(f) else None,
                        DP if dp(d) else None)
        if re.search(r"moe/shared/w_(in|gate)$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, DP if dp(s[-2]) else None,
                        "model" if model(s[-1]) else None)
        if re.search(r"moe/shared/w_out$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, "model" if model(s[-2]) else None,
                        DP if dp(s[-1]) else None)

        # --- Mamba2 ----------------------------------------------------------
        if re.search(r"mamba/w_in$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, DP if dp(s[-2]) else None,
                        "model" if model(s[-1]) else None)
        if re.search(r"mamba/w_out$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, "model" if model(s[-2]) else None,
                        DP if dp(s[-1]) else None)
        if re.search(r"mamba/conv_w$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, None, "model" if model(s[-1]) else None)
        if re.search(r"mamba/(conv_b|norm_scale)$", path):
            ld = [None] * (len(s) - 1)
            return spec(*ld, "model" if model(s[-1]) else None)

        # --- xLSTM -----------------------------------------------------------
        if re.search(r"w_up$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, DP if dp(s[-2]) else None,
                        "model" if model(s[-1]) else None)
        if re.search(r"w_down$", path):
            ld = [None] * (len(s) - 2)
            return spec(*ld, "model" if model(s[-2]) else None,
                        DP if dp(s[-1]) else None)

        # --- MTP / generic 2D / default ---------------------------------------
        if re.search(r"mtp_proj$", path):
            return spec(*([None] * (len(s) - 1)), DP if dp(s[-1]) else None)
        return spec(*([None] * len(s)))

    # ------------------------------------------------------------------ #
    def tree_specs(self, tree):
        def path_str(kp):
            parts = []
            for e in kp:
                if hasattr(e, "key"):
                    parts.append(str(e.key))
                elif hasattr(e, "idx"):
                    parts.append(str(e.idx))
            return "/".join(parts)
        return jax.tree_util.tree_map_with_path(
            lambda kp, leaf: self.spec_for_param(path_str(kp), leaf.shape),
            tree)

    def tree_shardings(self, tree):
        return jax.tree.map(lambda spec: NamedSharding(self.mesh, spec),
                            self.tree_specs(tree))

    # ------------------------------------------------------------------ #
    # collective plan — which algorithm each gradient payload lowers to
    # ------------------------------------------------------------------ #

    def collective_plan(self, tree, grad_dtype=np.float32):
        """Per-parameter data-parallel gradient-reduction plan.

        For every leaf: the allreduce payload bytes (grads in
        ``grad_dtype``) and the algorithm the active policy table routes
        that payload to on this mesh's DP group.  Consumed by the launch
        report and by tests; the trace-time dispatch in
        ``repro.core.collectives`` makes the same choice, so this is the
        human-readable preview of what the compiled step will do.
        """
        itemsize = np.dtype(grad_dtype).itemsize
        n = self.dp_n

        def leaf_plan(kp, leaf):
            nbytes = int(np.prod(leaf.shape, dtype=int)) * itemsize
            return {"op": "allreduce", "bytes": nbytes, "ranks": n,
                    "algorithm": registry.choose_name("allreduce", nbytes, n)}

        return jax.tree_util.tree_map_with_path(leaf_plan, tree)

    def pytree_plan(self, tree, grad_dtype=np.float32):
        """The bucketed counterpart of :meth:`collective_plan`: ONE pytree
        derived datatype (``repro.core.datatypes.pytree``) carries the
        whole gradient tree as a single wire vector, so the step issues
        one allreduce instead of one per leaf.

        Returns the plan the trace-time dispatch will make for that single
        payload: the datatype's wire signature (leaf count, total wire
        bytes) and the algorithm the active policy routes it to on this
        mesh's DP group — the human-readable preview of the bucketed
        ``build_jmpi_train_step`` path.
        """
        from repro.core import datatypes
        dt = datatypes.pytree(tree, wire_dtype=grad_dtype)
        nbytes = dt.count * np.dtype(grad_dtype).itemsize
        n = self.dp_n
        return {"op": "allreduce", "datatype": "pytree",
                "leaves": len(dt.leaf_shapes), "count": dt.count,
                "bytes": int(nbytes), "ranks": n,
                "algorithm": registry.choose_name("allreduce", int(nbytes),
                                                  n)}

    # ------------------------------------------------------------------ #
    # data & caches
    # ------------------------------------------------------------------ #

    def batch_specs(self, batch_struct, context_parallel=False):
        DP = self._dp()
        out = {}
        for k, v in batch_struct.items():
            b = v.shape[0]
            batch_ok = self.dp_n > 1 and b % self.dp_n == 0
            lead = DP if batch_ok else (
                "data" if self.sizes.get("data", 1) > 1
                and b % self.sizes["data"] == 0 else None)
            out[k] = P(lead, *([None] * (len(v.shape) - 1)))
        return out

    def cache_specs(self, cache_struct, context_parallel=False):
        """KV/latent/SSM cache layout.

        * batch dim shards over (pod, data);
        * KV heads shard over `model` when divisible; otherwise the sequence
          dim shards over `model` (keeps 32k-deep GQA caches with few KV
          heads under the per-chip HBM budget — partial-KV attention with a
          psum combine, handled by GSPMD);
        * MLA latent caches always shard sequence over `model` (no heads dim);
        * context_parallel (long_500k, batch=1): sequence also over `data`.
        """
        DP = self._dp()
        data_n = self.sizes.get("data", 1)

        def leaf_spec(path, leaf):
            s = leaf.shape
            name = path[-1] if path else ""
            entries = [None] * len(s)

            def try_batch(axis=1):
                if context_parallel:
                    return
                if self.dp_n > 1 and s[axis] % self.dp_n == 0:
                    entries[axis] = DP
                elif data_n > 1 and s[axis] % data_n == 0:
                    entries[axis] = "data"

            def try_cp(axis):
                if context_parallel and data_n > 1 and s[axis] % data_n == 0:
                    entries[axis] = "data"

            if name == "slot_pos":                      # (L, S)
                return P(*entries)
            if name in ("k", "v"):                      # (L, B, S, KH, D)
                try_batch()
                if self._model_ok(s[3]):
                    entries[3] = "model"
                elif self._model_ok(s[2]) and not context_parallel:
                    entries[2] = "model"
                try_cp(2)
                return P(*entries)
            if name in ("ckv", "krope"):                # (L, B, S, R)
                try_batch()
                if not context_parallel and self._model_ok(s[2]):
                    entries[2] = "model"
                try_cp(2)
                return P(*entries)
            if name == "ssd":                           # (L, B, H, P, N)
                try_batch()
                if self._model_ok(s[2]):
                    entries[2] = "model"
                return P(*entries)
            if name == "conv":                          # (L, B, k-1, C)
                try_batch()
                if self._model_ok(s[3]):
                    entries[3] = "model"
                return P(*entries)
            if name in ("C", "n", "m", "c", "h"):       # xLSTM states
                try_batch()
                if len(s) >= 3 and self._model_ok(s[2]):
                    entries[2] = "model"
                return P(*entries)
            if len(s) >= 2:
                try_batch()
            return P(*entries)

        def path_of(kp):
            return [str(getattr(e, "key", getattr(e, "idx", ""))) for e in kp]

        return jax.tree_util.tree_map_with_path(
            lambda kp, leaf: leaf_spec(path_of(kp), leaf), cache_struct)
