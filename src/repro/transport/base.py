"""Wire framing + the ``Transport``/``Wire`` interfaces shared by the shm
and socket transports.

One frame = fixed header + optional JSON meta + raw payload bytes::

    header  <iqqii  kind(i32)  tag(i64)  epoch(i64)  meta_len(i32)  data_len(i32)
    meta    JSON (arrays: {"dtype": name, "shape": [...]}) — may be empty
    data    raw payload (``ndarray.tobytes()`` for arrays, pickle for objects)

``kind`` distinguishes the frame classes the endpoint multiplexes over
one ordered byte stream per directed peer pair: ARRAY (tensor payloads),
OBJ (pickled python objects — status exchange, object allgather), CTRL
(empty barrier/handshake probes), and CHAN (persistent-channel payloads:
``tag`` carries the negotiated channel id, ``meta_len`` is always zero —
dtype/shape were frozen at negotiation, so steady-state sends never parse
or even transmit meta).  ``epoch`` stamps every frame with the
sender's message epoch so a receiver can lazily discard stragglers from an
abandoned program region (e.g. a send whose matching wait raised a
trace-time error) after the case runner bumps the epoch — see
``repro.transport.endpoint.Endpoint.bump_epoch``.

A ``Wire`` is one directed, ordered, reliable byte stream (socket or shm
ring); a ``Transport`` owns the full peer mesh and hands out wires.  Both
are deliberately dumb — MatlabMPI ran MPI over plain files; everything
MPI-shaped (tag matching, collectives, datatypes) lives above, in the
endpoint.
"""

from __future__ import annotations

import json
import pickle
import struct
import time

import numpy as np

#: Frame kinds (header field 0).
KIND_ARRAY, KIND_OBJ, KIND_CTRL, KIND_CHAN = 0, 1, 2, 3

HEADER = struct.Struct("<iqqii")
HEADER_LEN = HEADER.size


class Backoff:
    """Adaptive wait strategy: spin, then yield the GIL, then sleep with
    exponential escalation.

    Replaces the fixed 200 µs poll the shm ring shipped with: a waiter
    whose condition flips within a few microseconds (the common case for
    a peer mid-copy) completes inside the spin phase at nanosecond
    granularity; a genuinely idle waiter escalates to ``max_sleep`` so it
    does not burn a core.  ``pause()`` returns True once it has entered
    the sleeping phase — callers use that to amortize their deadline
    check off the hot spin loop.

    ``time.sleep(0)`` is used for the yield steps (it reliably releases
    the GIL; ``os.sched_yield`` may not), which matters here: reader
    threads and app threads share one interpreter, so a spinning waiter
    that never yields can starve the very thread it waits on.
    """

    __slots__ = ("_spin", "_min_sleep", "_max_sleep", "_n", "_sleep")

    def __init__(self, spin: int = 200, min_sleep: float = 1e-6,
                 max_sleep: float = 1e-4):
        self._spin, self._min_sleep, self._max_sleep = spin, min_sleep, max_sleep
        self._n, self._sleep = 0, min_sleep

    def reset(self) -> None:
        """Re-arm after the awaited condition fired (reuse across waits)."""
        self._n, self._sleep = 0, self._min_sleep

    def pause(self) -> bool:
        """One adaptive wait step; True once in the sleeping phase."""
        self._n += 1
        if self._n <= self._spin:
            return False
        if self._n <= self._spin + 4:
            time.sleep(0.0)
            return False
        time.sleep(self._sleep)
        self._sleep = min(self._sleep * 2.0, self._max_sleep)
        return True


class Wire:
    """One directed, ordered, reliable byte stream to a single peer.

    Concrete transports implement ``sendall``/``recv_exactly``/``close``;
    the endpoint layers frames on top via :func:`send_frame` /
    :func:`recv_frame`.
    """

    #: Optional ``() -> bool`` polled inside blocking recv loops; the
    #: endpoint installs its stop flag here so dedicated reader threads
    #: unblock promptly at shutdown (an ``EOFError`` is raised when it
    #: fires) without racing buffer teardown.
    stop_check = None

    #: True when buffers returned by ``recv_exactly`` are freshly
    #: allocated and owned by the caller (never aliased or reused by the
    #: wire) — lets :func:`decode_array` skip its defensive copy.
    owns_recv = False

    def sendall(self, data: bytes) -> None:
        """Write ``data`` completely (blocking; may chunk internally)."""
        raise NotImplementedError

    def recv_exactly(self, n: int, deadline: float) -> bytes:
        """Read exactly ``n`` bytes, raising ``TimeoutError`` past
        ``deadline`` (absolute ``time.monotonic`` stamp)."""
        raise NotImplementedError

    def recv_into(self, buf, deadline: float) -> None:
        """Fill the writable buffer ``buf`` completely with stream bytes.

        The persistent-channel receive path: payload lands directly in a
        preallocated array with zero intermediate allocation.  The default
        falls back to ``recv_exactly`` + copy; transports override.
        """
        mv = memoryview(buf).cast("B")
        mv[:] = self.recv_exactly(len(mv), deadline)

    def close(self) -> None:
        """Release the stream (idempotent)."""
        raise NotImplementedError


class Transport:
    """The full peer mesh for one rank: a :class:`Wire` per other rank.

    Attributes:
        kind: transport name (``"shm"`` | ``"sock"``) — surfaces in the
            plan-cache key and the bench env fingerprint.
    """

    kind = "abstract"

    def wire(self, peer: int) -> Wire:
        """The directed stream pair shared with ``peer``."""
        raise NotImplementedError

    def close(self) -> None:
        """Tear down every wire and free transport resources."""
        raise NotImplementedError


def _dtype_from_name(name: str) -> np.dtype:
    """Reconstruct a numpy dtype from its wire name.

    ``np.dtype("bfloat16")`` raises (numpy has no such builtin); the
    extension dtypes jax registers live in ``ml_dtypes``, which jaxlib
    ships — fall back to looking the name up there.
    """
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def encode_array(arr: np.ndarray) -> tuple[bytes, bytes]:
    """(meta, data) for an ARRAY frame — dtype/shape JSON + raw bytes."""
    arr = np.asarray(arr)
    # shape before ascontiguousarray: it promotes 0-d scalars to (1,)
    meta = json.dumps({"dtype": arr.dtype.name,
                       "shape": list(arr.shape)}).encode()
    return meta, np.ascontiguousarray(arr).tobytes()


def decode_array(meta: bytes, data: bytes, owned: bool = False) -> np.ndarray:
    """Reverse of :func:`encode_array`.

    ``owned=True`` (the wire's ``owns_recv`` contract) skips the defensive
    copy and returns an array viewing ``data`` directly — correct when the
    buffer was freshly allocated for this frame and will never be reused.
    Borrowed buffers (``owned=False``) stay copied.
    """
    doc = json.loads(meta.decode())
    dtype = _dtype_from_name(doc["dtype"])
    arr = np.frombuffer(data, dtype=dtype).reshape(doc["shape"])
    return arr if owned else arr.copy()


def encode_obj(obj) -> tuple[bytes, bytes]:
    """(meta, data) for an OBJ frame (pickle; trusted same-job peers)."""
    return b"", pickle.dumps(obj)


def decode_obj(data: bytes):
    """Reverse of :func:`encode_obj`."""
    return pickle.loads(data)


def send_frame(wire: Wire, kind: int, tag: int, epoch: int,
               meta: bytes = b"", data: bytes = b"") -> None:
    """Write one framed message to ``wire``.

    Header + meta + data go out as a single buffer so concurrent frames
    from one sender can never interleave mid-frame.
    """
    wire.sendall(HEADER.pack(kind, tag, epoch, len(meta), len(data))
                 + meta + data)


def recv_frame(wire: Wire, deadline: float):
    """Read one framed message: ``(kind, tag, epoch, meta, data)``.

    Raises:
        TimeoutError: ``deadline`` passed mid-read.
        EOFError: the stream closed cleanly between frames (peer exit).
    """
    head = wire.recv_exactly(HEADER_LEN, deadline)
    kind, tag, epoch, meta_len, data_len = HEADER.unpack(head)
    meta = wire.recv_exactly(meta_len, deadline) if meta_len else b""
    data = wire.recv_exactly(data_len, deadline) if data_len else b""
    return kind, tag, epoch, meta, data
