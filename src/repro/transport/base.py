"""Wire framing + the ``Transport``/``Wire`` interfaces shared by the shm
and socket transports.

One frame = fixed header + optional JSON meta + raw payload bytes::

    header  <iqqii  kind(i32)  tag(i64)  epoch(i64)  meta_len(i32)  data_len(i32)
    meta    JSON (arrays: {"dtype": name, "shape": [...]}) — may be empty
    data    raw payload (``ndarray.tobytes()`` for arrays, pickle for objects)

``kind`` distinguishes the three frame classes the endpoint multiplexes over
one ordered byte stream per directed peer pair: ARRAY (tensor payloads),
OBJ (pickled python objects — status exchange, object allgather), CTRL
(empty barrier/handshake probes).  ``epoch`` stamps every frame with the
sender's message epoch so a receiver can lazily discard stragglers from an
abandoned program region (e.g. a send whose matching wait raised a
trace-time error) after the case runner bumps the epoch — see
``repro.transport.endpoint.Endpoint.bump_epoch``.

A ``Wire`` is one directed, ordered, reliable byte stream (socket or shm
ring); a ``Transport`` owns the full peer mesh and hands out wires.  Both
are deliberately dumb — MatlabMPI ran MPI over plain files; everything
MPI-shaped (tag matching, collectives, datatypes) lives above, in the
endpoint.
"""

from __future__ import annotations

import json
import pickle
import struct

import numpy as np

#: Frame kinds (header field 0).
KIND_ARRAY, KIND_OBJ, KIND_CTRL = 0, 1, 2

HEADER = struct.Struct("<iqqii")
HEADER_LEN = HEADER.size


class Wire:
    """One directed, ordered, reliable byte stream to a single peer.

    Concrete transports implement ``sendall``/``recv_exactly``/``close``;
    the endpoint layers frames on top via :func:`send_frame` /
    :func:`recv_frame`.
    """

    #: Optional ``() -> bool`` polled inside blocking recv loops; the
    #: endpoint installs its stop flag here so dedicated reader threads
    #: unblock promptly at shutdown (an ``EOFError`` is raised when it
    #: fires) without racing buffer teardown.
    stop_check = None

    def sendall(self, data: bytes) -> None:
        """Write ``data`` completely (blocking; may chunk internally)."""
        raise NotImplementedError

    def recv_exactly(self, n: int, deadline: float) -> bytes:
        """Read exactly ``n`` bytes, raising ``TimeoutError`` past
        ``deadline`` (absolute ``time.monotonic`` stamp)."""
        raise NotImplementedError

    def close(self) -> None:
        """Release the stream (idempotent)."""
        raise NotImplementedError


class Transport:
    """The full peer mesh for one rank: a :class:`Wire` per other rank.

    Attributes:
        kind: transport name (``"shm"`` | ``"sock"``) — surfaces in the
            plan-cache key and the bench env fingerprint.
    """

    kind = "abstract"

    def wire(self, peer: int) -> Wire:
        """The directed stream pair shared with ``peer``."""
        raise NotImplementedError

    def close(self) -> None:
        """Tear down every wire and free transport resources."""
        raise NotImplementedError


def _dtype_from_name(name: str) -> np.dtype:
    """Reconstruct a numpy dtype from its wire name.

    ``np.dtype("bfloat16")`` raises (numpy has no such builtin); the
    extension dtypes jax registers live in ``ml_dtypes``, which jaxlib
    ships — fall back to looking the name up there.
    """
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def encode_array(arr: np.ndarray) -> tuple[bytes, bytes]:
    """(meta, data) for an ARRAY frame — dtype/shape JSON + raw bytes."""
    arr = np.asarray(arr)
    # shape before ascontiguousarray: it promotes 0-d scalars to (1,)
    meta = json.dumps({"dtype": arr.dtype.name,
                       "shape": list(arr.shape)}).encode()
    return meta, np.ascontiguousarray(arr).tobytes()


def decode_array(meta: bytes, data: bytes) -> np.ndarray:
    """Reverse of :func:`encode_array`."""
    doc = json.loads(meta.decode())
    dtype = _dtype_from_name(doc["dtype"])
    return np.frombuffer(data, dtype=dtype).reshape(doc["shape"]).copy()


def encode_obj(obj) -> tuple[bytes, bytes]:
    """(meta, data) for an OBJ frame (pickle; trusted same-job peers)."""
    return b"", pickle.dumps(obj)


def decode_obj(data: bytes):
    """Reverse of :func:`encode_obj`."""
    return pickle.loads(data)


def send_frame(wire: Wire, kind: int, tag: int, epoch: int,
               meta: bytes = b"", data: bytes = b"") -> None:
    """Write one framed message to ``wire``.

    Header + meta + data go out as a single buffer so concurrent frames
    from one sender can never interleave mid-frame.
    """
    wire.sendall(HEADER.pack(kind, tag, epoch, len(meta), len(data))
                 + meta + data)


def recv_frame(wire: Wire, deadline: float):
    """Read one framed message: ``(kind, tag, epoch, meta, data)``.

    Raises:
        TimeoutError: ``deadline`` passed mid-read.
        EOFError: the stream closed cleanly between frames (peer exit).
    """
    head = wire.recv_exactly(HEADER_LEN, deadline)
    kind, tag, epoch, meta_len, data_len = HEADER.unpack(head)
    meta = wire.recv_exactly(meta_len, deadline) if meta_len else b""
    data = wire.recv_exactly(data_len, deadline) if data_len else b""
    return kind, tag, epoch, meta, data
