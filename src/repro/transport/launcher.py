"""Process launcher: spawn N workers, rendezvous them into a transport
mesh, and supervise the job (the ``mpiexec`` of the multiproc backend).

Failure containment is the point of this module: a worker that crashes or
hangs mid-collective must fail the *job* promptly — never leave the parent
blocked on a dead peer or orphan the surviving workers.  The monitor loop
polls every child; the first nonzero exit (including signal deaths, e.g.
−9 after an OOM kill) or the job deadline triggers terminate→kill of every
remaining child, followed by shared-memory unlink and a raised error
carrying the worker transcripts.  An ``atexit`` hook replays the same
teardown for any job still live when the parent exits, so an interrupted
pytest run cannot leak processes or shm segments.

The parent stays jax-free: workers are ``python -m repro.transport.worker``
subprocesses with their own 1-device XLA config (``repro.testing.child_env``
keeps the import path identical to the parent's).
"""

from __future__ import annotations

import atexit
import json
import os
import select
import shutil
import subprocess
import sys
import tempfile
import time
import uuid
from multiprocessing import shared_memory

from repro.testing import _repo_root, child_env
from repro.transport.shm import segment_name

_LIVE_JOBS: list["Job"] = []


class WorkerFailure(RuntimeError):
    """A worker exited nonzero (or died to a signal) before job completion."""


def _default_timeout() -> float:
    return float(os.environ.get("JMPI_TIMEOUT", "120"))


class Job:
    """A running multiproc job: one worker process per rank.

    Obtain via :func:`launch`; consume via :meth:`wait` (batch entries) or
    :meth:`command`/:meth:`read_line` (interactive entries, e.g. the bench
    workers).  :meth:`close` is idempotent and always reaps.
    """

    def __init__(self, nprocs: int, transport: str, session: str, rdv: str,
                 procs: list[subprocess.Popen], timeout: float,
                 interactive: bool):
        self.nprocs, self.transport = nprocs, transport
        self.session, self.rdv = session, rdv
        self.procs, self.timeout = procs, timeout
        self.interactive = interactive
        self._closed = False
        _LIVE_JOBS.append(self)

    # -- observation -------------------------------------------------------
    def transcript(self, rank: int = 0) -> str:
        """The captured stdout+stderr of ``rank`` (empty if interactive
        rank 0, whose stdout is a live pipe)."""
        path = os.path.join(self.rdv, f"out_{rank}.txt")
        try:
            with open(path, errors="replace") as f:
                return f.read()
        except FileNotFoundError:
            return ""

    def _transcripts(self) -> str:
        parts = []
        for r in range(self.nprocs):
            text = self.transcript(r).strip()
            if text:
                parts.append(f"--- rank {r} ---\n{text}")
        return "\n".join(parts) or "(no worker output captured)"

    def pids(self) -> list[int]:
        """Worker process ids (for orphan checks in tests)."""
        return [p.pid for p in self.procs]

    # -- supervision -------------------------------------------------------
    def wait(self) -> str:
        """Block until every worker exits 0; return rank 0's transcript.

        Raises:
            WorkerFailure: any worker exited nonzero / died to a signal —
                every other worker is terminated and reaped first.
            TimeoutError: the job deadline passed — all workers reaped.
        """
        deadline = time.monotonic() + self.timeout
        try:
            while True:
                codes = [p.poll() for p in self.procs]
                bad = [(r, c) for r, c in enumerate(codes)
                       if c is not None and c != 0]
                if bad:
                    self._reap()
                    rank, code = bad[0]
                    raise WorkerFailure(
                        f"worker rank {rank} exited with code {code}; "
                        f"job torn down.\n{self._transcripts()}")
                if all(c == 0 for c in codes):
                    return self.transcript(0)
                if time.monotonic() > deadline:
                    self._reap()
                    raise TimeoutError(
                        f"multiproc job exceeded {self.timeout:.0f}s "
                        f"(transport={self.transport}, n={self.nprocs}); "
                        f"workers killed.\n{self._transcripts()}")
                time.sleep(0.02)
        finally:
            if all(p.poll() is not None for p in self.procs):
                self._release_segments()

    # -- interactive mode --------------------------------------------------
    def command(self, obj) -> None:
        """Write one JSON command line to EVERY worker's stdin."""
        line = json.dumps(obj) + "\n"
        for p in self.procs:
            p.stdin.write(line)
            p.stdin.flush()

    def read_line(self, timeout: float | None = None) -> str:
        """One line from rank 0's stdout (its reply channel), with a
        deadline; reaps and raises if rank 0 dies or stays silent."""
        deadline = time.monotonic() + (timeout or self.timeout)
        p0 = self.procs[0]
        while True:
            budget = deadline - time.monotonic()
            if budget <= 0:
                self._reap()
                raise TimeoutError(
                    f"no reply from rank 0 within {timeout or self.timeout}s"
                    f"\n{self._transcripts()}")
            ready, _, _ = select.select([p0.stdout], [], [], min(budget, 0.5))
            if not ready:
                if p0.poll() is not None:
                    self._reap()
                    raise WorkerFailure(
                        f"rank 0 exited with code {p0.returncode} while a "
                        f"reply was pending\n{self._transcripts()}")
                continue
            line = p0.stdout.readline()
            if not line:
                self._reap()
                raise WorkerFailure(
                    f"rank 0 closed stdout (code {p0.poll()})"
                    f"\n{self._transcripts()}")
            return line.rstrip("\n")

    # -- teardown ----------------------------------------------------------
    def _reap(self) -> None:
        """Terminate every surviving worker; escalate to SIGKILL."""
        for p in self.procs:
            if p.poll() is None:
                try:
                    p.terminate()
                except OSError:
                    pass
        grace = time.monotonic() + 2.0
        while time.monotonic() < grace and \
                any(p.poll() is None for p in self.procs):
            time.sleep(0.02)
        for p in self.procs:
            if p.poll() is None:
                try:
                    p.kill()
                except OSError:
                    pass
        for p in self.procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                pass
        self._release_segments()

    def _release_segments(self) -> None:
        """Unlink any shm segments the workers left behind (backstop — the
        creating worker unlinks its own on a clean exit).  Ring segments
        are enumerable from (session, nprocs); persistent-channel segments
        carry dynamic channel ids, so those are swept by session-prefix
        scan of /dev/shm (best-effort: the scan is Linux-specific, and a
        clean worker exit already unlinked everything)."""
        if self.transport != "shm":
            return
        for i in range(self.nprocs):
            for j in range(self.nprocs):
                if i == j:
                    continue
                try:
                    seg = shared_memory.SharedMemory(
                        name=segment_name(self.session, i, j))
                    seg.close()
                    seg.unlink()
                except (FileNotFoundError, OSError):
                    pass
        try:
            leaked = [n for n in os.listdir("/dev/shm")
                      if n.startswith(f"{self.session}_c")]
        except OSError:
            leaked = []
        for name in leaked:
            try:
                seg = shared_memory.SharedMemory(name=name)
                seg.close()
                seg.unlink()
            except (FileNotFoundError, OSError):
                pass

    def close(self) -> None:
        """Reap workers and delete the rendezvous directory (idempotent)."""
        if self._closed:
            return
        self._closed = True
        if self.interactive:
            for p in self.procs:
                try:
                    p.stdin.close()
                except OSError:
                    pass
        self._reap()
        shutil.rmtree(self.rdv, ignore_errors=True)
        if self in _LIVE_JOBS:
            _LIVE_JOBS.remove(self)


def launch(nprocs: int, entry: str, *, transport: str = "sock", args=None,
           timeout: float | None = None, interactive: bool = False) -> Job:
    """Spawn ``nprocs`` workers running ``entry`` over a transport mesh.

    Args:
        nprocs: worker count (ranks 0..nprocs−1).
        entry: ``"module:function"``; the worker imports the module and
            calls ``function(comm)`` — or ``function(comm, args)`` when
            ``args`` is given — with a live :class:`MultiprocComm` whose
            backend is installed as the ambient WORLD.
        transport: ``"sock"`` (portable, default) or ``"shm"`` (fast path).
        args: JSON-serializable value forwarded to the entry function.
        timeout: job deadline in seconds (default env ``JMPI_TIMEOUT`` or
            120); also forwarded to the workers' endpoint deadline.
        interactive: keep every worker's stdin open as a command pipe and
            rank 0's stdout as a reply pipe (:meth:`Job.command` /
            :meth:`Job.read_line`); batch jobs capture all output to files.
    Returns:
        The supervised :class:`Job`.
    Raises:
        ValueError: unknown transport or a malformed entry.
    """
    if transport not in ("shm", "sock"):
        raise ValueError(f"unknown transport {transport!r}; "
                         "expected 'shm' or 'sock'")
    if ":" not in entry:
        raise ValueError(f"entry must be 'module:function', got {entry!r}")
    timeout = _default_timeout() if timeout is None else float(timeout)
    session = uuid.uuid4().hex[:8]
    rdv = tempfile.mkdtemp(prefix="jmpi_rdv_")
    procs: list[subprocess.Popen] = []
    try:
        for rank in range(nprocs):
            env = child_env(1)
            env.update({
                "JMPI_RANK": str(rank),
                "JMPI_NP": str(nprocs),
                "JMPI_TRANSPORT": transport,
                "JMPI_SESSION": session,
                "JMPI_RENDEZVOUS": rdv,
                "JMPI_ENTRY": entry,
                "JMPI_ENTRY_ARGS": json.dumps(args),
                "JMPI_BACKEND": "multiproc",
                "JMPI_TIMEOUT": str(timeout),
                "PYTHONUNBUFFERED": "1",
            })
            log = open(os.path.join(rdv, f"out_{rank}.txt"), "w")
            if interactive and rank == 0:
                stdout, stderr = subprocess.PIPE, log
            else:
                stdout, stderr = log, subprocess.STDOUT
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.transport.worker"],
                env=env, cwd=_repo_root(),
                stdin=subprocess.PIPE if interactive else subprocess.DEVNULL,
                stdout=stdout, stderr=stderr, text=True))
            log.close()  # the child holds its own dup of the fd
    except Exception:
        for p in procs:
            p.kill()
        shutil.rmtree(rdv, ignore_errors=True)
        raise
    return Job(nprocs, transport, session, rdv, procs, timeout, interactive)


@atexit.register
def _cleanup_live_jobs() -> None:
    for job in list(_LIVE_JOBS):
        job.close()
