"""Shared-memory transport: one SPSC ring buffer per directed rank pair.

The fast path.  Each directed pair (src → dst) gets its own
``multiprocessing.shared_memory`` segment named
``{session}_r{src}to{dst}`` with layout::

    [ head: u64 ][ tail: u64 ][ ring bytes: RING_SIZE ]

``head``/``tail`` are *monotonic* byte counters (they never wrap; the
ring index is ``counter % RING_SIZE``), which makes full/empty
unambiguous: ``head - tail`` is the number of unread bytes.  Exactly one
process writes ``head`` (the segment's creator, src) and exactly one
writes ``tail`` (dst), so the single-producer/single-consumer handshake
needs no locks — an 8-byte-aligned u64 store is a single atomic
instruction on x86-64/aarch64, and the counter update is published only
*after* the payload bytes it covers are in place.

Writers block briefly when the ring is full.  That is deadlock-safe
here because the endpoint dedicates a reader thread per inbound wire
that drains unconditionally into per-source queues — the consumer never
waits on the producer.
"""

from __future__ import annotations

import struct
import time
from multiprocessing import shared_memory

from repro.transport import base

#: Ring capacity per directed pair.  Frames larger than the ring still
#: flow — the writer chunks and the counters never wrap — 1 MiB just
#: bounds the per-pair footprint (n*(n-1) segments per job).
RING_SIZE = 1 << 20

_U64 = struct.Struct("<Q")
_HDR_BYTES = 16  # head + tail


def segment_name(session: str, src: int, dst: int) -> str:
    """Shared-memory segment name for the directed pair ``src → dst``.

    The launcher derives the same names for orphan-cleanup unlinking.
    """
    return f"{session}_r{src}to{dst}"


def _attach(name: str, create: bool, deadline: float) -> shared_memory.SharedMemory:
    if create:
        shm = shared_memory.SharedMemory(name=name, create=True,
                                         size=_HDR_BYTES + RING_SIZE)
        shm.buf[:_HDR_BYTES] = b"\x00" * _HDR_BYTES
        return shm
    backoff = base.Backoff(spin=0, min_sleep=5e-5, max_sleep=5e-3)
    while True:
        try:
            shm = shared_memory.SharedMemory(name=name)
            break
        except FileNotFoundError:
            if time.monotonic() > deadline:
                raise TimeoutError(f"shm rendezvous: segment {name} never "
                                   "appeared (creator died?)")
            backoff.pause()
    # The stdlib resource_tracker assumes every attacher owns the segment
    # and double-unlinks it at exit (bpo-38119).  Only the creator unlinks;
    # deregister the attach so teardown stays single-owner.
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass
    return shm


class _Ring:
    """One end of an SPSC ring (producer if ``writer`` else consumer)."""

    def __init__(self, shm: shared_memory.SharedMemory, writer: bool,
                 owner: bool):
        self._shm, self._writer, self._owner = shm, writer, owner

    def _head(self) -> int:
        return _U64.unpack_from(self._shm.buf, 0)[0]

    def _tail(self) -> int:
        return _U64.unpack_from(self._shm.buf, 8)[0]

    def write(self, data: bytes, deadline: float) -> None:
        mv, pos = memoryview(data), 0
        backoff = base.Backoff(spin=100)
        while pos < len(mv):
            head, tail = self._head(), self._tail()
            free = RING_SIZE - (head - tail)
            if free == 0:
                if backoff.pause() and time.monotonic() > deadline:
                    raise TimeoutError("shm ring stayed full (reader gone?)")
                continue
            backoff.reset()
            n = min(free, len(mv) - pos)
            start = head % RING_SIZE
            first = min(n, RING_SIZE - start)
            self._shm.buf[_HDR_BYTES + start:_HDR_BYTES + start + first] = \
                mv[pos:pos + first]
            if n > first:  # wrap-around: second chunk at ring offset 0
                self._shm.buf[_HDR_BYTES:_HDR_BYTES + n - first] = \
                    mv[pos + first:pos + n]
            # Publish AFTER the payload bytes are visible.
            _U64.pack_into(self._shm.buf, 0, head + n)
            pos += n

    def read(self, n: int, deadline: float, stop=None) -> bytearray:
        # Returned buffer is freshly built here and owned by the caller
        # (``ShmWire.owns_recv``) — no trailing bytes() copy.
        out = bytearray()
        backoff = base.Backoff(spin=100)
        while len(out) < n:
            head, tail = self._head(), self._tail()
            avail = head - tail
            if avail == 0:
                if stop is not None and stop():
                    raise EOFError("endpoint stopped")
                if backoff.pause() and time.monotonic() > deadline:
                    raise TimeoutError(f"shm recv timed out with "
                                       f"{n - len(out)} of {n} bytes "
                                       "outstanding")
                continue
            backoff.reset()
            take = min(avail, n - len(out))
            start = tail % RING_SIZE
            first = min(take, RING_SIZE - start)
            out += self._shm.buf[_HDR_BYTES + start:_HDR_BYTES + start + first]
            if take > first:
                out += self._shm.buf[_HDR_BYTES:_HDR_BYTES + take - first]
            _U64.pack_into(self._shm.buf, 8, tail + take)
        return out

    def close(self) -> None:
        try:
            self._shm.close()
            if self._owner:
                self._shm.unlink()
        except (OSError, FileNotFoundError):
            pass


class ShmWire(base.Wire):
    """Wire over a pair of directed rings (out: me→peer, in: peer→me)."""

    #: ``_Ring.read`` builds a fresh bytearray per call — the receiver
    #: owns it, so frame decoding may alias it instead of copying.
    owns_recv = True

    def __init__(self, out_ring: _Ring, in_ring: _Ring,
                 write_timeout: float = 120.0):
        self._out, self._in = out_ring, in_ring
        self._write_timeout = write_timeout

    def sendall(self, data: bytes) -> None:
        self._out.write(data, time.monotonic() + self._write_timeout)

    def recv_exactly(self, n: int, deadline: float) -> bytes:
        return self._in.read(n, deadline, stop=self._stopped)

    def _stopped(self) -> bool:
        return self.stop_check is not None and self.stop_check()

    def close(self) -> None:
        self._out.close()
        self._in.close()


class ShmTransport(base.Transport):
    """Full shm-ring mesh for one rank.

    Each rank *creates* its outbound segments (me → peer) and *attaches*
    to its inbound ones (peer → me); creation doubles as rendezvous.
    """

    kind = "shm"

    def __init__(self, rank: int, nprocs: int, session: str,
                 timeout: float = 60.0):
        self.rank, self.nprocs, self.session = rank, nprocs, session
        deadline = time.monotonic() + timeout
        self._wires: dict[int, ShmWire] = {}
        for peer in range(nprocs):
            if peer == rank:
                continue
            out_shm = _attach(segment_name(session, rank, peer),
                              create=True, deadline=deadline)
            in_shm = _attach(segment_name(session, peer, rank),
                             create=False, deadline=deadline)
            self._wires[peer] = ShmWire(
                _Ring(out_shm, writer=True, owner=True),
                _Ring(in_shm, writer=False, owner=False),
                write_timeout=timeout)

    def wire(self, peer: int) -> ShmWire:
        return self._wires[peer]

    def close(self) -> None:
        for w in self._wires.values():
            w.close()
        self._wires.clear()
