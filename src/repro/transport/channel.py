"""Persistent point-to-point channels: the zero-copy fast path behind the
MPI-4 persistent plans (``*_init``/``sendrecv_init``) on the multiproc
backend.

The eager wire pays, per message: JSON meta encode/decode, a full
``tobytes()`` staging copy, a ``frombuffer`` copy on the far side, a
reader-thread queue handoff, and (pre-backoff) fixed poll sleeps.  A
channel amortizes ALL of that into one negotiation when the plan is
built: both ends agree on a frozen ``(op, shape, dtype, extra)`` key, so
steady-state execution moves only payload bytes.

Two concrete flavors, chosen by the communicator's transport kind:

``ShmChannel`` — a dedicated shared-memory segment per directed channel,
bypassing the frame rings AND the reader threads entirely::

    [ gen: u64 ][ seq: u64 ][ ack: u64 ][ pad → 64 ][ slot0 ][ slot1 ]

``seq``/``ack`` are monotonic chunk counters (sender owns ``seq``,
receiver owns ``ack`` — the same SPSC publish-after-payload discipline
as the frame ring).  The sender writes payload straight from the source
array into slot ``k % NSLOTS`` through a cached numpy view (no staging
buffer, no header, no meta) and publishes ``seq = k+1``; the receiver
waits for ``seq``, reads the slot view directly, and acks.  Messages
larger than a slot are chunk-pipelined: with two slots the sender fills
chunk ``k+1`` while the receiver drains chunk ``k``.  ``gen`` carries
the endpoint epoch (+1, so a zeroed fresh segment is never a valid
generation): after ``bump_epoch`` the sender re-zeroes the counters and
publishes the new generation; the receiver waits for it — no handshake
frames, and stale in-flight state from an abandoned epoch is discarded
wholesale.

``SockSendChannel``/``SockRecvChannel`` — CHAN frames over the existing
TCP wire with a pre-encoded cached header (kind/chan-id/epoch/length are
all frozen, so the header is packed once per epoch, not per send) and no
meta bytes.  The endpoint's reader thread routes CHAN frames by channel
id and ``recv_into``-s the payload directly into a pooled, preallocated
receive array — single copy end to end, zero allocation and zero pickle
in steady state.

Negotiation (driven by ``Endpoint.open_channels``) is a batched
three-phase SYN/ACK over ordinary OBJ frames: every SYN goes out before
any blocking read, so any static SPMD channel pattern opens deadlock-
free; the receiver validates the sender's frozen key against its own at
negotiation time, making signature mismatches (and the plans layer's
static ERR_TRUNCATE) init-time errors rather than steady-state ones.
"""

from __future__ import annotations

import struct
import time

import numpy as np

from repro.transport import base

#: Slot payload capacity.  Messages above this are chunk-pipelined
#: through the slots; at or below it a message is a single zero-staging
#: slot write/read.
CHUNK_CAP = 256 << 10

#: Slots per shm channel: double buffering overlaps one producer copy
#: with one consumer copy, which is all a single shared segment can use.
NSLOTS = 2

_U64 = struct.Struct("<Q")
_GEN_OFF, _SEQ_OFF, _ACK_OFF = 0, 8, 16
_CTRL_BYTES = 64  # gen + seq + ack, padded out of false-sharing range


def channel_segment_name(session: str, src: int, dst: int, cid: int) -> str:
    """Shared-memory segment name for sender ``src``'s channel ``cid`` to
    ``dst``.  Shares the job session prefix so the launcher's orphan
    backstop can unlink leaked channel segments by prefix scan."""
    return f"{session}_c{cid}r{src}to{dst}"


def chunk_layout(nbytes: int) -> tuple[int, int]:
    """``(slot_capacity, nchunks)`` for a frozen message of ``nbytes``."""
    cap = min(max(nbytes, 1), CHUNK_CAP)
    return cap, max(1, -(-nbytes // cap))


def key_layout(key: tuple) -> tuple[tuple, np.dtype, int]:
    """``(shape, np_dtype, nbytes)`` from a channel key
    ``(op, shape, dtype_name, extra)``."""
    _, shape, dtype_name, _ = key
    dtype = base._dtype_from_name(dtype_name)
    nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return tuple(shape), dtype, nbytes


class ShmChannel:
    """One end (sender or receiver) of a directed shm slot channel."""

    def __init__(self, endpoint, peer: int, key: tuple, segment,
                 sender: bool, owner: bool):
        self._ep, self.peer, self.key = endpoint, peer, key
        self._shm, self._sender, self._owner = segment, sender, owner
        shape, dtype, self._nbytes = key_layout(key)
        self._cap, self._nchunks = chunk_layout(self._nbytes)
        buf = segment.buf
        count = int(np.prod(shape, dtype=np.int64))
        self._slots = []   # per-slot uint8 byte views (chunked transfer)
        self._typed = []   # per-slot dtype/shape views (single-chunk path)
        for i in range(NSLOTS):
            off = _CTRL_BYTES + i * self._cap
            self._slots.append(np.frombuffer(buf, np.uint8, self._cap, off))
            if self._nchunks == 1:
                self._typed.append(
                    np.frombuffer(buf, dtype, count, off).reshape(shape))
        self._count = 0                   # chunks through this end
        self._epoch = endpoint.epoch
        self._recv_buf = (np.empty(shape, dtype)
                          if not sender and self._nchunks > 1 else None)
        if sender:
            _U64.pack_into(buf, _SEQ_OFF, 0)
            _U64.pack_into(buf, _ACK_OFF, 0)
            _U64.pack_into(buf, _GEN_OFF, endpoint.epoch + 1)
        else:
            self._wait(_GEN_OFF, endpoint.epoch + 1, "generation")

    # -- counters ------------------------------------------------------------
    def _u64(self, off: int) -> int:
        return _U64.unpack_from(self._shm.buf, off)[0]

    def _wait(self, off: int, need: int, what: str) -> None:
        buf = self._shm.buf
        if _U64.unpack_from(buf, off)[0] >= need:
            return
        backoff = base.Backoff(spin=300)
        deadline = time.monotonic() + self._ep.timeout
        while _U64.unpack_from(buf, off)[0] < need:
            if backoff.pause() and time.monotonic() > deadline:
                raise TimeoutError(
                    f"rank {self._ep.rank}: persistent channel to rank "
                    f"{self.peer} stalled waiting for {what} >= {need} "
                    f"(key={self.key}, peer gone?)")

    def _sync_epoch(self) -> None:
        ep = self._ep.epoch
        if self._epoch == ep:
            return
        # Epoch moved since last use.  The case runner bumps epochs
        # collectively (bump + barrier), so neither end is mid-message
        # here; the sender resets the stream and publishes the new
        # generation, the receiver waits for it.  Both ends reach their
        # first post-bump use at the same epoch (same SPMD program).
        if self._sender:
            _U64.pack_into(self._shm.buf, _SEQ_OFF, 0)
            _U64.pack_into(self._shm.buf, _ACK_OFF, 0)
            _U64.pack_into(self._shm.buf, _GEN_OFF, ep + 1)
        else:
            self._wait(_GEN_OFF, ep + 1, "generation")
        self._count = 0
        self._epoch = ep

    # -- sender --------------------------------------------------------------
    def send(self, arr: np.ndarray) -> None:
        """Write one frozen-signature message straight into the slots."""
        self._sync_epoch()
        buf = self._shm.buf
        if self._nchunks == 1:
            k = self._count
            self._wait(_ACK_OFF, k + 1 - NSLOTS, "ack")
            np.copyto(self._typed[k % NSLOTS], arr, casting="no")
            _U64.pack_into(buf, _SEQ_OFF, k + 1)  # publish after payload
            self._count = k + 1
            self._ep._count_chan(self._nbytes, 0)
            return
        src = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
        for c in range(self._nchunks):
            k = self._count
            self._wait(_ACK_OFF, k + 1 - NSLOTS, "ack")
            lo = c * self._cap
            hi = min(lo + self._cap, self._nbytes)
            self._slots[k % NSLOTS][:hi - lo] = src[lo:hi]
            _U64.pack_into(buf, _SEQ_OFF, k + 1)
            self._count = k + 1
        self._ep._count_chan(self._nbytes, 0)

    # -- receiver ------------------------------------------------------------
    def recv(self) -> np.ndarray:
        """The next message.  Single-chunk messages return the slot view
        itself (borrowed: consume it, then :meth:`release`); chunked
        messages assemble into one persistent receive buffer, acking each
        chunk so the sender pipelines the next one behind it."""
        self._sync_epoch()
        if self._nchunks == 1:
            k = self._count
            self._wait(_SEQ_OFF, k + 1, "payload")
            self._count = k + 1
            return self._typed[k % NSLOTS]
        dst = self._recv_buf.reshape(-1).view(np.uint8)
        buf = self._shm.buf
        for c in range(self._nchunks):
            k = self._count
            self._wait(_SEQ_OFF, k + 1, "payload")
            lo = c * self._cap
            hi = min(lo + self._cap, self._nbytes)
            dst[lo:hi] = self._slots[k % NSLOTS][:hi - lo]
            _U64.pack_into(buf, _ACK_OFF, k + 1)  # slot free for the sender
            self._count = k + 1
        return self._recv_buf

    def release(self) -> None:
        """Done consuming the last :meth:`recv` — ack its slot back."""
        if self._nchunks == 1:
            _U64.pack_into(self._shm.buf, _ACK_OFF, self._count)

    def close(self) -> None:
        # Views alias the mmap; drop ours before closing it.  BufferError
        # means a caller still holds a borrowed recv() view — leave the
        # mapping for the interpreter to reclaim, but still unlink the
        # name so the segment cannot leak past the process.
        self._slots, self._typed, self._recv_buf = [], [], None
        try:
            self._shm.close()
        except (OSError, BufferError):
            pass
        if self._owner:
            try:
                self._shm.unlink()
            except (OSError, FileNotFoundError):
                pass


class SockSendChannel:
    """Sender end of a channel over the TCP wire: pre-encoded header,
    zero meta, payload streamed from the source array's own memory."""

    #: Below this, header + payload are concatenated into one sendall
    #: (one syscall beats avoiding one small copy); above, the payload
    #: memoryview goes out as-is.
    _INLINE = 16 << 10

    def __init__(self, endpoint, peer: int, key: tuple, cid: int, wire):
        self._ep, self.peer, self.key = endpoint, peer, key
        self._cid, self._wire = cid, wire
        _, _, self._nbytes = key_layout(key)
        self._hdr_epoch, self._hdr = None, b""

    def send(self, arr: np.ndarray) -> None:
        epoch = self._ep.epoch
        if epoch != self._hdr_epoch:  # re-pack only when the epoch moves
            self._hdr = base.HEADER.pack(base.KIND_CHAN, self._cid, epoch,
                                         0, self._nbytes)
            self._hdr_epoch = epoch
        flat = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
        if self._nbytes <= self._INLINE:
            self._wire.sendall(self._hdr + flat.tobytes())
        else:
            self._wire.sendall(self._hdr)
            self._wire.sendall(flat.data)
        self._ep._count_chan(self._nbytes, base.HEADER_LEN)

    def close(self) -> None:
        pass  # the wire belongs to the transport


class SockRecvChannel:
    """Receiver end over TCP: the endpoint's reader thread lands CHAN
    payloads directly in pooled preallocated arrays via ``recv_into``
    and signals a condition — no queue handoff, no parse, no pickle."""

    def __init__(self, endpoint, peer: int, key: tuple, cid: int):
        import threading

        self._ep, self.peer, self.key, self.cid = endpoint, peer, key, cid
        self._shape, self._dtype, self._nbytes = key_layout(key)
        self._cv = threading.Condition()
        self._ready: list = []   # (epoch, (arr, u8view)) in arrival order
        self._free: list = []    # returned buffers, reused round-robin
        self._cur = None

    def _buffer(self):
        with self._cv:
            if self._free:
                return self._free.pop()
        arr = np.empty(self._shape, self._dtype)
        return arr, arr.reshape(-1).view(np.uint8)

    def deliver(self, wire, epoch: int, data_len: int,
                deadline: float) -> None:
        """Reader-thread entry: land one CHAN payload."""
        if data_len != self._nbytes:
            raise RuntimeError(
                f"persistent channel {self.cid} from rank {self.peer}: "
                f"payload of {data_len} bytes does not match the "
                f"negotiated {self._nbytes} (key={self.key})")
        pair = self._buffer()
        wire.recv_into(pair[1], deadline)
        with self._cv:
            self._ready.append((epoch, pair))
            self._cv.notify()

    def recv(self) -> np.ndarray:
        """The next current-epoch message (borrowed buffer: consume, then
        :meth:`release`).  Stale-epoch messages are dropped in place;
        future-epoch ones stay queued until this rank catches up."""
        deadline = time.monotonic() + self._ep.timeout
        with self._cv:
            while True:
                epoch, keep = self._ep.epoch, []
                found = None
                for item in self._ready:
                    if item[0] < epoch:
                        self._free.append(item[1])  # stale: recycle
                    elif found is None and item[0] == epoch:
                        found = item[1]
                    else:
                        keep.append(item)
                self._ready = keep
                if found is not None:
                    self._cur = found
                    return found[0]
                if not self._cv.wait(timeout=0.2) and \
                        time.monotonic() > deadline:
                    raise TimeoutError(
                        f"rank {self._ep.rank}: persistent channel "
                        f"{self.cid} from rank {self.peer} received no "
                        f"payload within {self._ep.timeout:.0f}s")

    def release(self) -> None:
        pair, self._cur = self._cur, None
        if pair is not None:
            with self._cv:
                self._free.append(pair)

    def close(self) -> None:
        self._ep._chan_rx.pop((self.peer, self.cid), None)


# ---------------------------------------------------------------------------
# Persistent issue closures — what the plans layer binds instead of the
# generic kernel closure when a MultiprocComm can negotiate channels.
# The builders return None when the op/algorithm has no channel lowering
# (the plan then falls back to the eager kernel unchanged).
# ---------------------------------------------------------------------------

def _take(chan) -> np.ndarray:
    """Copy a borrowed channel buffer out and release the slot.

    The copy is what makes slot recycling safe around JAX's async
    dispatch: a jnp op may read its operand after issue returns, so the
    channel buffer must never be aliased past release().
    """
    out = np.array(chan.recv())
    chan.release()
    return out


def sendrecv_issue(comm, shape: tuple, dtype_name: str, perm):
    """Persistent ``sendrecv`` issue closure over negotiated channels,
    or None when the pattern is purely local.

    The closure is host-synchronous and numpy-native end to end (the plan
    layer marks such plans ``host=True``): no token ops, no jnp dispatch —
    those per-call costs are milliseconds against a µs-scale channel.
    """
    ep, me = comm.endpoint, comm.rank_id

    key = ("sendrecv", tuple(shape), dtype_name, None)
    dsts = [d for s, d in perm if s == me and d != me]
    srcs = [s for s, d in perm if d == me]
    local = bool(srcs) and srcs[0] == me
    # One batched negotiation: every SYN leaves before any blocking read,
    # so a symmetric pattern (e.g. a ring) opens deadlock-free.
    tx, rx = ep.open_channels([(d, key) for d in dsts],
                              [(s, key) for s in srcs if s != me])
    zeros = np.zeros(shape, base._dtype_from_name(dtype_name))
    zeros.setflags(write=False)  # shared across starts, like a jnp const

    def issue(v, t):
        arr = np.asarray(v)
        for d in dsts:
            tx[d].send(arr)
        if local:
            out = np.array(arr)  # own the buffer: v may be a device view
        elif srcs:
            out = _take(rx[srcs[0]])
        else:
            out = zeros
        return out, t

    return issue


def collective_issue(comm, op_name: str, algo_name: str, shape: tuple,
                     dtype_name: str, kw: dict):
    """Persistent issue closure for a ``direct``-algorithm collective, or
    None when no channel lowering exists for ``(op_name, algo_name)``."""
    if algo_name != "direct":
        return None
    builder = _COLLECTIVE_BUILDERS.get(op_name)
    if builder is None:
        return None
    return builder(comm, tuple(shape), dtype_name, kw)


def _open_symmetric(ep, peers, key):
    """One channel each way with every peer (the all-to-all pattern)."""
    return ep.open_channels([(p, key) for p in peers],
                            [(p, key) for p in peers])


def _allreduce_issue(comm, shape, dtype_name, kw):
    from repro.core.operators import combiner

    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    key = ("allreduce", shape, dtype_name, None)
    peers = [r for r in range(n) if r != me]
    tx, rx = _open_symmetric(ep, peers, key)
    combine, pre, post = combiner(kw["op"])

    def issue(v, t):
        arr = np.asarray(v)
        for p in peers:
            tx[p].send(arr)
        acc = None
        for r in range(n):  # reduce-on-receive, rank order (bit-identical)
            part = arr if r == me else _take(rx[r])
            if pre is not None:
                part = pre(part)
            acc = part if acc is None else combine(acc, part)
        if post is not None:
            acc = post(acc, v.dtype)
        return acc, t

    return issue


def _reduce_scatter_issue(comm, shape, dtype_name, kw):
    from repro.core.operators import combiner

    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    chunk = shape[0] // n
    key = ("reduce_scatter", (chunk,) + shape[1:], dtype_name, None)
    peers = [r for r in range(n) if r != me]
    tx, rx = _open_symmetric(ep, peers, key)
    combine, pre, post = combiner(kw["op"])

    def issue(v, t):
        arr = np.asarray(v)
        for d in peers:  # each destination gets only ITS chunk
            tx[d].send(arr[d * chunk:(d + 1) * chunk])
        acc = None
        for r in range(n):
            part = (arr[me * chunk:(me + 1) * chunk] if r == me
                    else _take(rx[r]))
            if pre is not None:
                part = pre(part)
            acc = part if acc is None else combine(acc, part)
        if post is not None:
            acc = post(acc, v.dtype)
        return acc, t

    return issue


def _bcast_issue(comm, shape, dtype_name, kw):
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    root = kw["root"]
    key = ("bcast", shape, dtype_name, root)
    if me == root:
        tx, _ = ep.open_channels(
            [(p, key) for p in range(n) if p != root], [])

        def issue(v, t):
            arr = np.asarray(v)
            for p in range(n):
                if p != root:
                    tx[p].send(arr)
            return arr, t
    else:
        _, rx = ep.open_channels([], [(root, key)])

        def issue(v, t):
            return _take(rx[root]), t

    return issue


def _allgather_issue(comm, shape, dtype_name, kw):
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    key = ("allgather", shape, dtype_name, None)
    peers = [r for r in range(n) if r != me]
    tx, rx = _open_symmetric(ep, peers, key)
    scalar = len(shape) == 0

    def issue(v, t):
        arr = np.asarray(v)
        for p in peers:
            tx[p].send(arr)
        parts = [arr if r == me else _take(rx[r]) for r in range(n)]
        out = np.stack(parts) if scalar else np.concatenate(parts, axis=0)
        return out, t

    return issue


def _alltoall_issue(comm, shape, dtype_name, kw):
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    split_axis = kw.get("split_axis", 0)
    concat_axis = kw.get("concat_axis", 0)
    chunk_shape = list(shape)
    chunk_shape[split_axis] //= n
    key = ("alltoall", tuple(chunk_shape), dtype_name,
           (split_axis, concat_axis))
    peers = [r for r in range(n) if r != me]
    tx, rx = _open_symmetric(ep, peers, key)

    def issue(v, t):
        chunks = np.split(np.asarray(v), n, axis=split_axis)
        for d in peers:
            tx[d].send(chunks[d])
        got = [chunks[s] if s == me else _take(rx[s]) for s in range(n)]
        return np.concatenate(got, axis=concat_axis), t

    return issue


_COLLECTIVE_BUILDERS = {
    "allreduce": _allreduce_issue,
    "reduce_scatter": _reduce_scatter_issue,
    "bcast": _bcast_issue,
    "allgather": _allgather_issue,
    "alltoall": _alltoall_issue,
}
