"""Multi-process test harness: run the existing ``tests/cases_*.py``
oracle modules across real worker processes.

The contract mirrors ``repro.testing`` exactly — same ``PASS {case}`` /
``FAIL {case}: {err}`` transcript protocol, same run-the-module-once
caching — but the module executes on every rank of a launched multiproc
job instead of inside one XLA trace.  The case *functions* are untouched:
the case modules read ``JMPI_BACKEND``/``JMPI_NP`` at import to size ``N``
and to route their ``spmd_collective`` helper to :func:`run_collective`,
so one oracle body is the parity test for both backends.

Worker-side entries (referenced by ``module:function`` name from the
launcher): :func:`_case_entry` (case runner), :func:`_bench_worker`
(interactive OMB-style p2p timing loop), :func:`_spin_entry` (barrier
heartbeat for launcher kill/orphan tests).
"""

from __future__ import annotations

import functools
import importlib
import json
import os
import sys
import time


def run_collective(fn, shards):
    """Multiproc twin of the case modules' ``spmd_collective``.

    Each rank applies ``fn`` eagerly to its own shard (the ambient WORLD
    is this worker's :class:`~repro.transport.endpoint.MultiprocComm`, so
    every jmpi op inside ``fn`` goes over the wire), then object-allgathers
    the results — every rank returns the full per-rank list, exactly like
    the emulated helper, so case assertions run unmodified on all ranks.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import comm as comm_lib
    from repro.core import token as token_lib

    comm = comm_lib.world()
    token_lib.reset_ambient()  # fresh ordering chain, like each spmd trace
    out = fn(jnp.asarray(shards[comm.rank_id]))
    gathered = comm.endpoint.allgather_obj(np.asarray(out))
    return [np.asarray(g) for g in gathered]


def _case_entry(comm, args) -> None:
    """Worker entry: run every ``case_*`` of ``args["module"]`` on all
    ranks, agree on the outcome, and have rank 0 emit the transcript.

    Outcome agreement (a status-allgather of the per-rank error string —
    pre-encoded CTRL frames for the common all-ok vote, pickle only on
    failure) makes a failure on ANY rank visible in rank 0's transcript;
    the epoch bump + barrier between cases guarantees a case that raised
    mid-exchange cannot leak a stale frame into the next case.
    """
    mod = importlib.import_module(args["module"])
    ep = comm.endpoint
    for name in sorted(n for n in dir(mod) if n.startswith("case_")):
        err = None
        try:
            getattr(mod, name)()
        except Exception as e:  # noqa: BLE001 — reported per case
            err = f"{type(e).__name__}: {e}"
        errs = ep.allgather_status(err)
        ep.bump_epoch()
        ep.barrier()
        if comm.rank_id == 0:
            bad = next(((r, x) for r, x in enumerate(errs) if x), None)
            if bad is None:
                print(f"PASS {name}", flush=True)
            else:
                print(f"FAIL {name}: [rank {bad[0]}] {bad[1]}", flush=True)


@functools.lru_cache(maxsize=None)
def module_results_multiproc(module: str, nprocs: int, transport: str,
                             timeout: float = 900.0
                             ) -> dict[str, tuple[bool, str]]:
    """Run ``module`` once under a (nprocs, transport) job; {case: (ok, log)}.

    Cached per configuration for the life of the test process, mirroring
    ``repro.testing.module_results`` (including the ``__import__`` /
    ``__timeout__`` failure sentinels).
    """
    from repro.transport import launcher

    job = launcher.launch(nprocs, "repro.transport.testing:_case_entry",
                          transport=transport, args={"module": module},
                          timeout=timeout)
    try:
        transcript = job.wait()
    except TimeoutError as e:
        return {"__timeout__": (False, str(e))}
    except launcher.WorkerFailure as e:
        return {"__import__": (False, str(e))}
    finally:
        job.close()
    results: dict[str, tuple[bool, str]] = {}
    for line in transcript.splitlines():
        if line.startswith(("PASS ", "FAIL ")):
            name = line.split()[1].rstrip(":")
            results[name] = (line.startswith("PASS "), line)
    if not results:
        results["__import__"] = (
            False, f"case module {module} produced no transcript under "
                   f"multiproc n={nprocs} ({transport}):\n{transcript}")
    return results


def assert_case_multiproc(module: str, case: str, nprocs: int,
                          transport: str) -> None:
    """Assert one case passed under a real-process job (module runs once
    per (nprocs, transport) configuration, cached)."""
    results = module_results_multiproc(module, nprocs, transport)
    for sentinel in ("__import__", "__timeout__"):
        if sentinel in results:
            raise AssertionError(results[sentinel][1])
    assert case in results, (
        f"case {case} not found in {module} under multiproc n={nprocs} "
        f"({transport}); known: {sorted(results)}")
    passed, log = results[case]
    assert passed, (f"case {case} of {module} failed under multiproc "
                    f"n={nprocs} ({transport}):\n{log}")


# ---------------------------------------------------------------------------
# interactive bench worker (driven by repro.bench.suites.p2p)
# ---------------------------------------------------------------------------

def _bench_worker(comm, args=None) -> None:
    """Interactive OMB-style timing loop over the jmpi p2p surface.

    Reads one JSON command per stdin line (the launcher writes each
    command to every rank, so all ranks execute the same schedule)::

        {"op": "pingpong",   "size": <bytes>, "inner": <iters>}
        {"op": "window",     "size": <bytes>, "window": <w>, "inner": <iters>}
        {"op": "pingpong_persistent", "size": <bytes>, "inner": <iters>}
        {"op": "window_persistent",   "size": <bytes>, "window": <w>,
                             "inner": <iters>}
        {"op": "gradsync",   "total": <floats>, "algorithm": ""|"int8_ef"|
                             "topk_ef", "buckets": <b>, "overlap": <bool>,
                             "inner": <iters>}
        {"op": "wire_bytes", "total": <floats>}
        {"op": "exit"}

    The ``*_persistent`` twins run the same exchange through cached
    ``sendrecv_init`` plans — first command per size pays the channel
    negotiation (outside the timed region), steady state runs the
    zero-copy channel fast path.

    Rank 0 replies ``DONE {"secs": ...}`` per command on stdout
    (``wire_bytes`` replies the per-rank transmitted payload bytes of one
    fp32 / int8_ef / topk_ef(1/32) allreduce instead — the endpoint spy
    measuring the compressed frames' literal size, ISSUE 8).
    """
    import jax.numpy as jnp
    import numpy as np

    import repro.core as jmpi
    from repro.core import p2p, token as token_lib
    from repro.distributed import overlap as overlap_lib

    def grad_tree(total):
        # synthetic uneven leaf split of one rank's `total`-float gradient
        fr = (0.4, 0.2, 0.1, 0.1, 0.08, 0.06, 0.04, 0.02)
        sizes = [int(total * f) for f in fr]
        sizes[0] += total - sum(sizes)
        rng = np.random.default_rng(comm.rank_id)
        return [jnp.asarray(rng.standard_normal(s), jnp.float32)
                for s in sizes]

    ep = comm.endpoint
    grads_cache: dict[int, list] = {}
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        cmd = json.loads(line)
        if cmd["op"] == "exit":
            return
        if cmd["op"] == "gradsync":
            grads = grads_cache.setdefault(int(cmd["total"]),
                                           grad_tree(int(cmd["total"])))
            comp = [jmpi.init_state(g) for g in grads]
            token_lib.reset_ambient()
            ep.barrier()
            t0 = time.perf_counter()
            for _ in range(int(cmd.get("inner", 3))):
                _, comp = overlap_lib.bucketed_grad_sync(
                    grads, comp, comm=comm,
                    algorithm=cmd.get("algorithm", ""),
                    buckets=int(cmd.get("buckets", 4)),
                    overlap=bool(cmd.get("overlap", False)), mean=True)
            secs = time.perf_counter() - t0
            ep.barrier()
            if comm.rank_id == 0:
                print("DONE " + json.dumps({"secs": secs}), flush=True)
            continue
        if cmd["op"] == "wire_bytes":
            g = jnp.asarray(
                np.random.default_rng(3).standard_normal(int(cmd["total"])),
                jnp.float32)
            token_lib.reset_ambient()
            ep.barrier()
            out = {}
            for name, run in (
                    ("fp32", lambda: jmpi.allreduce(g, comm=comm)),
                    ("int8", lambda: jmpi.compressed_allreduce(
                        g, jmpi.init_state(g), comm=comm,
                        algorithm="int8_ef")),
                    ("topk", lambda: jmpi.compressed_allreduce(
                        g, jmpi.init_state(g), comm=comm,
                        algorithm="topk_ef", frac=1 / 32))):
                ep.reset_wire_stats()
                run()
                out[name] = ep.wire_stats()["data_bytes"]
            ep.barrier()
            if comm.rank_id == 0:
                print("DONE " + json.dumps(out), flush=True)
            continue
        n_f32 = max(1, int(cmd["size"]) // 4)
        x = jnp.zeros((n_f32,), jnp.float32)
        inner = int(cmd.get("inner", 10))
        token_lib.reset_ambient()
        if cmd["op"].endswith("_persistent"):
            # Plan/channel setup (negotiation on first use per size;
            # process-global plan cache makes repeats free) happens here,
            # BEFORE the barrier and the clock — steady state is timed.
            from repro.core import plans as plans_lib
            sig = ((n_f32,), jnp.float32)
            fwd = plans_lib.sendrecv_init(sig, pairs=[(0, 1)], comm=comm)
            bwd = plans_lib.sendrecv_init(sig, pairs=[(1, 0)], comm=comm)
            ack = plans_lib.sendrecv_init(((1,), jnp.float32),
                                          pairs=[(1, 0)], comm=comm)
        ep.barrier()
        t0 = time.perf_counter()
        if cmd["op"] == "pingpong":
            for _ in range(inner):
                _, y = p2p.sendrecv(x, pairs=[(0, 1)], comm=comm)
                _, x = p2p.sendrecv(y, pairs=[(1, 0)], comm=comm)
        elif cmd["op"] == "window":
            window = int(cmd.get("window", 16))
            for _ in range(inner):
                reqs = [p2p.isendrecv(x, pairs=[(0, 1)], tag=i, comm=comm)
                        for i in range(window)]
                p2p.waitall(reqs)
                p2p.sendrecv(x[:1], pairs=[(1, 0)], comm=comm)  # completion ack
        elif cmd["op"] == "pingpong_persistent":
            for _ in range(inner):
                _, y = p2p.wait(fwd.start(x))
                _, x = p2p.wait(bwd.start(y))
        elif cmd["op"] == "window_persistent":
            window = int(cmd.get("window", 16))
            for _ in range(inner):
                reqs = [fwd.start(x, tag=i) for i in range(window)]
                p2p.waitall(reqs)
                p2p.wait(ack.start(x[:1]))  # completion ack
        else:
            raise ValueError(f"unknown bench op {cmd['op']!r}")
        secs = time.perf_counter() - t0
        ep.barrier()
        if comm.rank_id == 0:
            print("DONE " + json.dumps({"secs": secs}), flush=True)


def _spin_entry(comm, args) -> None:
    """Barrier heartbeat loop for launcher hardening tests: workers stay
    collectively synchronized until the parent kills one (the survivor's
    barrier then times out) or ``seconds`` elapse."""
    deadline = time.monotonic() + float((args or {}).get("seconds", 60))
    while time.monotonic() < deadline:
        comm.endpoint.barrier()
        time.sleep(0.02)


def backend_name() -> str:
    """The backend this process is configured for (env ``JMPI_BACKEND``,
    default ``emulated``) — the bench env fingerprint reads this."""
    return os.environ.get("JMPI_BACKEND", "emulated")
