"""Socket transport: length-prefixed frames over loopback TCP.

The portable wire.  Each rank opens one listening socket on 127.0.0.1
(port 0 — the OS picks) and publishes ``addr_{rank}.json`` into the
job's rendezvous directory (written atomically: tmp file + rename).
Connection establishment is deterministic to avoid crossed dials: for
every pair (i, j) with i < j, rank j connects to rank i, and the
connector opens its hello with its own rank so the acceptor can map the
inbound socket to a peer.  One socket per pair carries both directions
(TCP is full duplex); TCP_NODELAY is set so small latency-bench frames
are not Nagle-delayed.
"""

from __future__ import annotations

import json
import os
import socket
import time

from repro.transport import base

_HELLO = len("hello 00000000")  # fixed-width hello: "hello %08d"


class SockWire(base.Wire):
    """One connected TCP socket to a peer (both directions)."""

    #: ``recv_exactly`` allocates a fresh buffer per call — the receiver
    #: owns it, so frame decoding may alias it instead of copying.
    owns_recv = True

    def __init__(self, sock: socket.socket):
        self._sock = sock
        try:
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # non-TCP stream socket (e.g. a test socketpair)

    def sendall(self, data) -> None:
        self._sock.sendall(data)

    def recv_exactly(self, n: int, deadline: float) -> bytearray:
        # One allocation, zero joins: chunks land directly in the final
        # buffer as they arrive (large messages pipeline through the TCP
        # window instead of accumulating a chunk list + join copy).
        out = bytearray(n)
        self.recv_into(out, deadline)
        return out

    def recv_into(self, buf, deadline: float) -> None:
        mv = memoryview(buf).cast("B")
        pos, n = 0, len(mv)
        while pos < n:
            budget = deadline - time.monotonic()
            if budget <= 0:
                raise TimeoutError(f"socket recv timed out with {n - pos} "
                                   f"of {n} bytes outstanding")
            # Slice the wait so a revoked deadline is honored promptly even
            # when the peer never writes.
            self._sock.settimeout(min(budget, 0.5))
            try:
                got = self._sock.recv_into(mv[pos:])
            except socket.timeout:
                if self.stop_check is not None and self.stop_check():
                    raise EOFError("endpoint stopped")
                continue
            if not got:
                raise EOFError("peer closed the socket")
            pos += got

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


def _publish_addr(rdv: str, rank: int, host: str, port: int) -> None:
    tmp = os.path.join(rdv, f".addr_{rank}.tmp")
    with open(tmp, "w") as f:
        json.dump({"host": host, "port": port}, f)
    os.replace(tmp, os.path.join(rdv, f"addr_{rank}.json"))


def _read_addr(rdv: str, rank: int, deadline: float) -> tuple[str, int]:
    path = os.path.join(rdv, f"addr_{rank}.json")
    backoff = base.Backoff(spin=0, min_sleep=1e-4, max_sleep=1e-2)
    while True:
        try:
            with open(path) as f:
                doc = json.load(f)
            return doc["host"], doc["port"]
        except (FileNotFoundError, json.JSONDecodeError):
            if time.monotonic() > deadline:
                raise TimeoutError(f"rendezvous: rank {rank} never published "
                                   f"its address at {path}")
            backoff.pause()


class SockTransport(base.Transport):
    """Full TCP mesh for one rank, built through file rendezvous.

    Args:
        rank / nprocs: this worker's identity.
        rendezvous: shared directory for address publication.
        timeout: seconds allowed for the whole mesh to come up.
    """

    kind = "sock"

    def __init__(self, rank: int, nprocs: int, rendezvous: str,
                 timeout: float = 60.0):
        self.rank, self.nprocs = rank, nprocs
        deadline = time.monotonic() + timeout
        self._wires: dict[int, SockWire] = {}
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.bind(("127.0.0.1", 0))
        listener.listen(nprocs)
        _publish_addr(rendezvous, rank, *listener.getsockname())
        # Lower ranks accept from higher ranks; higher ranks dial lower.
        pending = {j for j in range(rank + 1, nprocs)}
        for i in range(rank):
            host, port = _read_addr(rendezvous, i, deadline)
            s = socket.create_connection((host, port),
                                         timeout=max(deadline - time.monotonic(), 1))
            s.sendall((f"hello {rank:08d}").encode())
            self._wires[i] = SockWire(s)
        listener.settimeout(0.5)
        while pending:
            if time.monotonic() > deadline:
                listener.close()
                raise TimeoutError(f"rank {rank}: peers {sorted(pending)} "
                                   "never connected")
            try:
                s, _ = listener.accept()
            except socket.timeout:
                continue
            hello = s.recv(_HELLO, socket.MSG_WAITALL)
            peer = int(hello.split()[1])
            pending.discard(peer)
            self._wires[peer] = SockWire(s)
        listener.close()

    def wire(self, peer: int) -> SockWire:
        return self._wires[peer]

    def close(self) -> None:
        for w in self._wires.values():
            w.close()
        self._wires.clear()
