"""repro.transport — real inter-process backend behind the jmpi API.

The emulated backend (default) runs MPI semantics inside ONE process over
shard_map mesh axes; this package is the other lowering of the same
surface: ``launch()`` spawns N real host processes, rendezvous wires them
into a transport mesh (shared-memory rings or loopback sockets), and each
worker's ambient WORLD becomes a ``MultiprocComm`` whose every op — p2p,
collectives, v-variants, persistent plans, derived datatypes — executes
over the wire through the same registry dispatch seam
(``registry.select(backend="multiproc")``).  Select per process with
``jmpi.set_backend("multiproc")`` (the worker bootstrap does) or per
communicator by constructing a ``MultiprocComm``.

Modules: ``base`` (frame format + Wire/Transport interfaces), ``shm`` /
``sock`` (the two wires), ``endpoint`` (tag matching, barrier, the
``direct`` collective kernels, ``MultiprocComm``), ``launcher`` (spawn /
supervise / reap), ``worker`` (per-rank bootstrap), ``testing`` (runs the
existing oracle case modules across a job).

This module stays import-light (no jax): the launcher side runs in the
parent test/bench process where pulling in jax is pure overhead.
"""

from repro.transport.launcher import Job, WorkerFailure, launch

__all__ = ["Job", "WorkerFailure", "launch"]
