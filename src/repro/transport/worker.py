"""Worker bootstrap: the ``__main__`` every launched rank executes.

Reads the ``JMPI_*`` environment the launcher injected, builds the
transport mesh + endpoint + :class:`~repro.transport.endpoint.MultiprocComm`,
installs it as the ambient WORLD (with a fresh ordering-token chain — the
same initialization :func:`repro.core.spmd` performs around an emulated
trace), and hands control to the ``module:function`` entry.  A final
barrier before teardown keeps a fast rank from unlinking shared state while
a slow peer is still draining; any exception prints its traceback to stdout
(the launcher's transcript channel) and exits 1, which the parent monitor
converts into a :class:`~repro.transport.launcher.WorkerFailure`.
"""

from __future__ import annotations

import importlib
import json
import os
import sys
import traceback


def main() -> int:
    """Bootstrap this rank and run the configured entry; 0 on success."""
    rank = int(os.environ["JMPI_RANK"])
    nprocs = int(os.environ["JMPI_NP"])
    transport_kind = os.environ["JMPI_TRANSPORT"]
    session = os.environ["JMPI_SESSION"]
    rdv = os.environ["JMPI_RENDEZVOUS"]
    entry = os.environ["JMPI_ENTRY"]
    args = json.loads(os.environ.get("JMPI_ENTRY_ARGS", "null"))
    timeout = float(os.environ.get("JMPI_TIMEOUT", "120"))

    from repro.core import comm as comm_lib
    from repro.core import token as token_lib
    from repro.transport import endpoint as ep_lib

    if transport_kind == "shm":
        from repro.transport.shm import ShmTransport
        transport = ShmTransport(rank, nprocs, session, timeout=timeout)
    else:
        from repro.transport.sock import SockTransport
        transport = SockTransport(rank, nprocs, rdv, timeout=timeout)

    comm = ep_lib.make_comm(transport, rank, nprocs, timeout=timeout)
    comm_lib.set_backend("multiproc")
    comm_lib.set_world(comm)
    token_lib.reset_ambient()

    mod_name, fn_name = entry.split(":", 1)
    fn = getattr(importlib.import_module(mod_name), fn_name)
    try:
        if args is None:
            fn(comm)
        else:
            fn(comm, args)
        comm.endpoint.barrier()  # nobody tears down while peers still drain
        return 0
    finally:
        comm_lib.set_world(None)
        comm.endpoint.close()


if __name__ == "__main__":
    try:
        code = main()
    except Exception:
        traceback.print_exc(file=sys.stdout)
        sys.stdout.flush()
        code = 1
    sys.exit(code)
