"""The MPI-shaped layer over a wire transport: tag-matched message queues,
a dissemination barrier, and :class:`MultiprocComm` — the communicator that
runs the *existing* jmpi surface (p2p, collectives, v-variants, plans,
derived datatypes) across real host processes.

Layering (docs/ARCHITECTURE.md, transport section)::

    comm.allreduce / isendrecv / plan.start       (unchanged user surface)
        └─ registry.select(backend="multiproc")   (same dispatch seam)
            └─ "direct" kernels below             (eager, rank-order exact)
                └─ Endpoint.send_* / recv_*       (tag-matched frame queues)
                    └─ ShmTransport | SockTransport  (dumb byte streams)

Semantics notes:

* MPI-level tag matching (ANY_TAG, trace-time mismatch errors) lives in
  ``repro.core.p2p`` on the Request, exactly as on the emulated backend —
  the endpoint only matches its own internal tags, so both backends share
  one matching implementation and one error text.
* Every multiproc kernel reduces/concatenates in rank order 0..n−1, so all
  ranks compute bit-identical results (MPI's reproducibility guarantee for
  a fixed algorithm) and match the emulated oracle within float tolerance.
* Reader threads drain every inbound wire unconditionally into per-source
  queues.  Consequence: a sender never blocks on an unposted receive, so
  the eager kernels can use the simple send-then-receive schedule without
  deadlock — the classic eager-protocol trade (memory for progress).
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.core.comm import Communicator
from repro.core.compression import (DEFAULT_TOPK_FRAC, CompressionState,
                                    _ef_rs_supports, _ef_supports)
from repro.core.operators import Operator, combiner
from repro.core.vcollectives import (_alltoallv_supports, _gatherv_supports,
                                     _offsets, _scatterv_supports,
                                     _valid_rows)
from repro.transport import base
from repro.transport import channel as channel_lib
from repro.transport.base import KIND_ARRAY, KIND_CHAN, KIND_CTRL, KIND_OBJ

#: Internal wire tags (negative: the public tag space is user-visible and
#: non-negative by convention; p2p payloads, collective payloads and object
#: frames each get their own stream so kernels can interleave).
TAG_P2P = -10
TAG_COLL = -11
TAG_OBJ = -12
TAG_CHAN = -13   # persistent-channel negotiation (SYN/ACK OBJ frames)
TAG_STAT = -14   # status agreement (CTRL when ok, OBJ when failed)
_TAG_BARRIER = -101  # round k uses _TAG_BARRIER - k


def default_timeout() -> float:
    """Seconds an endpoint waits on a missing frame before declaring the
    peer hung (env ``JMPI_TIMEOUT``; the launcher forwards its own job
    timeout here so a wedged worker dies before the parent gives up)."""
    return float(os.environ.get("JMPI_TIMEOUT", "120"))


class Endpoint:
    """Tag-matched messaging for one rank over a :class:`~.base.Transport`.

    One dedicated reader thread per inbound wire drains frames into a
    per-source queue; :meth:`recv` matches (kind, tag, epoch) FIFO against
    the queue plus a pending list of not-yet-claimed frames.  Frames from
    an older epoch are discarded lazily (see :meth:`bump_epoch`); frames
    from a *newer* epoch stay pending until this rank catches up.
    """

    def __init__(self, transport: base.Transport, rank: int, nprocs: int,
                 timeout: float | None = None):
        self.transport, self.rank, self.nprocs = transport, rank, nprocs
        self.timeout = default_timeout() if timeout is None else timeout
        self._epoch = 0
        self._tx = {"frames": 0, "bytes": 0, "data_bytes": 0,
                    "meta_bytes": 0, "chan_msgs": 0, "chan_bytes": 0}
        self._stop = threading.Event()
        self._queues: dict[int, queue.Queue] = {}
        self._pending: dict[int, list] = {}
        self._threads: list[threading.Thread] = []
        self._ctrl_cache: dict = {}       # (tag, epoch) -> pre-packed frame
        self._chan_rx: dict = {}          # (peer, cid) -> SockRecvChannel
        self._chan_cache: dict = {}       # (peer, role, key) -> channel
        self._channels: list = []         # every open channel, for close()
        self._chan_next = 0               # next channel id this rank issues
        for peer in range(nprocs):
            if peer == rank:
                continue
            self._queues[peer] = queue.Queue()
            self._pending[peer] = []
            wire = transport.wire(peer)
            wire.stop_check = self._stop.is_set
            t = threading.Thread(target=self._reader, args=(peer, wire),
                                 daemon=True, name=f"jmpi-read-r{peer}")
            t.start()
            self._threads.append(t)

    # -- reader threads ----------------------------------------------------
    def _reader(self, peer: int, wire: base.Wire) -> None:
        while not self._stop.is_set():
            try:
                head = wire.recv_exactly(base.HEADER_LEN,
                                         time.monotonic() + 86400.0)
                kind, tag, epoch, meta_len, data_len = base.HEADER.unpack(
                    bytes(head))
                if kind == KIND_CHAN:
                    # Persistent-channel payload: route by channel id into
                    # the channel's pooled receive buffer — no meta parse,
                    # no allocation, no queue handoff.
                    chan = self._chan_rx.get((peer, tag))
                    deadline = time.monotonic() + self.timeout
                    if chan is None:  # channel closed: drain and drop
                        wire.recv_exactly(data_len, deadline)
                    else:
                        chan.deliver(wire, epoch, data_len, deadline)
                    continue
                deadline = time.monotonic() + 86400.0
                meta = wire.recv_exactly(meta_len, deadline) \
                    if meta_len else b""
                data = wire.recv_exactly(data_len, deadline) \
                    if data_len else b""
                frame = (kind, tag, epoch, meta, data)
            except EOFError:
                if not self._stop.is_set():
                    self._queues[peer].put(("eof", None))
                return
            except Exception as e:  # noqa: BLE001 — surfaced at recv()
                if not self._stop.is_set():
                    self._queues[peer].put(("err", f"{type(e).__name__}: {e}"))
                return
            self._queues[peer].put(("frame", frame))

    # -- send side ---------------------------------------------------------
    def _count_tx(self, meta_len: int, data_len: int) -> None:
        self._tx["frames"] += 1
        self._tx["bytes"] += base.HEADER_LEN + meta_len + data_len
        self._tx["data_bytes"] += data_len
        self._tx["meta_bytes"] += meta_len

    def _count_chan(self, payload: int, overhead: int) -> None:
        # Persistent-channel sends: counted apart from the eager frame
        # counters so the wire spy can assert the fast path carries zero
        # meta and zero eager frames in steady state.
        self._tx["chan_msgs"] += 1
        self._tx["chan_bytes"] += payload + overhead

    def wire_stats(self) -> dict[str, int]:
        """Snapshot of this endpoint's transmit counters: eager ``frames``
        sent, their total wire ``bytes`` (header + meta + data), raw eager
        payload ``data_bytes``, JSON ``meta_bytes``, and the persistent
        fast path's ``chan_msgs``/``chan_bytes``.  The frame-size spy for
        the compressed-wire and zero-meta parity tests — bracket an op
        with :meth:`reset_wire_stats` and a read to measure exactly what
        it put on the wire."""
        return dict(self._tx)

    def reset_wire_stats(self) -> None:
        """Zero the transmit counters (see :meth:`wire_stats`)."""
        for k in self._tx:
            self._tx[k] = 0

    def send_array(self, dst: int, arr, tag: int) -> None:
        """Frame ``arr`` (dtype/shape preserved) to rank ``dst``."""
        meta, data = base.encode_array(np.asarray(arr))
        self._count_tx(len(meta), len(data))
        base.send_frame(self.transport.wire(dst), KIND_ARRAY, tag,
                        self._epoch, meta, data)

    def send_obj(self, dst: int, obj, tag: int = TAG_OBJ) -> None:
        """Frame a pickled python object to rank ``dst``."""
        meta, data = base.encode_obj(obj)
        self._count_tx(len(meta), len(data))
        base.send_frame(self.transport.wire(dst), KIND_OBJ, tag,
                        self._epoch, meta, data)

    def send_ctrl(self, dst: int, tag: int) -> None:
        """Frame an empty control probe (barrier rounds, ok-status votes)
        to rank ``dst``.  The 28-byte frame is fully determined by
        ``(tag, epoch)``, so it is packed once and cached — steady-state
        control traffic never re-serializes."""
        frame = self._ctrl_cache.get((tag, self._epoch))
        if frame is None:
            if len(self._ctrl_cache) > 128:
                self._ctrl_cache.clear()  # old epochs never come back
            frame = base.HEADER.pack(KIND_CTRL, tag, self._epoch, 0, 0)
            self._ctrl_cache[(tag, self._epoch)] = frame
        self._count_tx(0, 0)
        self.transport.wire(dst).sendall(frame)

    # -- receive side ------------------------------------------------------
    def _match(self, src: int, tag: int, kinds: tuple):
        found, keep = None, []
        for fr in self._pending[src]:
            k, t, ep, _, _ = fr
            if ep < self._epoch:
                continue  # stale frame from an abandoned program region
            if found is None and ep == self._epoch and k in kinds \
                    and t == tag:
                found = fr
            else:
                keep.append(fr)
        self._pending[src] = keep
        return found

    def _recv_frame(self, src: int, tag: int, kind):
        kinds = (kind,) if isinstance(kind, int) else tuple(kind)
        deadline = time.monotonic() + self.timeout
        while True:
            fr = self._match(src, tag, kinds)
            if fr is not None:
                return fr
            try:
                sort, payload = self._queues[src].get(timeout=0.2)
            except queue.Empty:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"rank {self.rank}: no frame (kind={kinds}, "
                        f"tag={tag}, epoch={self._epoch}) from rank {src} "
                        f"within {self.timeout:.0f}s")
                continue
            if sort == "eof":
                raise RuntimeError(f"rank {self.rank}: peer {src} closed its "
                                   "wire (worker exited early?)")
            if sort == "err":
                raise RuntimeError(f"rank {self.rank}: reader for peer {src} "
                                   f"failed: {payload}")
            self._pending[src].append(payload)

    def recv_array(self, src: int, tag: int) -> np.ndarray:
        """Next ARRAY frame from ``src`` with ``tag`` (blocking, FIFO)."""
        _, _, _, meta, data = self._recv_frame(src, tag, KIND_ARRAY)
        # Both wires hand over freshly allocated buffers (owns_recv), so
        # decoding aliases them instead of paying a second full copy.
        return base.decode_array(meta, data,
                                 owned=self.transport.wire(src).owns_recv)

    def recv_obj(self, src: int, tag: int = TAG_OBJ):
        """Next OBJ frame from ``src`` with ``tag`` (blocking, FIFO)."""
        _, _, _, _, data = self._recv_frame(src, tag, KIND_OBJ)
        return base.decode_obj(data)

    # -- group operations --------------------------------------------------
    def barrier(self) -> None:
        """Dissemination barrier: ⌈log₂n⌉ rounds; in round k rank i probes
        rank ``(i+2^k) mod n`` and waits on ``(i−2^k) mod n``.  Exiting
        implies every rank entered — the textbook butterfly argument."""
        n, k = self.nprocs, 0
        while (1 << k) < n:
            self.send_ctrl((self.rank + (1 << k)) % n, _TAG_BARRIER - k)
            self._recv_frame((self.rank - (1 << k)) % n, _TAG_BARRIER - k,
                             KIND_CTRL)
            k += 1

    def allgather_obj(self, obj) -> list:
        """Every rank's ``obj`` in rank order (python objects, pickled).

        The testing harness uses this to agree on per-case outcomes so a
        failure on any rank is visible in rank 0's transcript.
        """
        out = [None] * self.nprocs
        out[self.rank] = obj
        for peer in self._queues:
            self.send_obj(peer, obj)
        for peer in sorted(self._queues):
            out[peer] = self.recv_obj(peer)
        return out

    def allgather_status(self, err: str | None) -> list:
        """Rank-ordered outcome agreement with pickle kept off the hot
        path: the overwhelmingly common ``None`` (ok) vote travels as a
        pre-encoded empty CTRL frame; only actual failures pickle their
        error string into an OBJ frame."""
        out: list = [None] * self.nprocs
        out[self.rank] = err
        for peer in self._queues:
            if err is None:
                self.send_ctrl(peer, TAG_STAT)
            else:
                self.send_obj(peer, err, tag=TAG_STAT)
        for peer in sorted(self._queues):
            kind, _, _, _, data = self._recv_frame(peer, TAG_STAT,
                                                   (KIND_CTRL, KIND_OBJ))
            out[peer] = None if kind == KIND_CTRL else base.decode_obj(data)
        return out

    # -- persistent channels -------------------------------------------------
    def open_channels(self, sends, recvs) -> tuple[dict, dict]:
        """Negotiate (or fetch cached) persistent channels.

        ``sends``/``recvs`` are lists of ``(peer, key)`` with
        ``key = (op, shape, dtype_name, extra)`` — the frozen signature
        both ends derive independently from the same SPMD plan-init call.
        Returns ``({peer: send_channel}, {peer: recv_channel})``.

        The negotiation is a batched three-phase SYN/ACK over OBJ frames:
        (1) create sender-side resources and SYN every new send channel,
        (2) service the expected inbound SYNs — validating the announced
        key against the locally derived one — attach, and ACK, (3) collect
        ACKs.  No phase blocks before all of this rank's phase-1 frames
        are out, so any static pattern opens deadlock-free.  Channels are
        cached per ``(peer, direction, key)`` on the endpoint — distinct
        plans with the same frozen signature share channels (safe: both
        ends issue in the same SPMD program order), and rebuilt plans
        (e.g. ``recv_into`` variants, which skip the plan cache) never
        leak new segments.
        """
        tx, rx, new_tx, new_rx = {}, {}, [], []
        for peer, key in sends:
            cached = self._chan_cache.get((peer, "tx", key))
            (tx.__setitem__(peer, cached) if cached is not None
             else new_tx.append((peer, key)))
        for peer, key in recvs:
            cached = self._chan_cache.get((peer, "rx", key))
            (rx.__setitem__(peer, cached) if cached is not None
             else new_rx.append((peer, key)))
        if not new_tx and not new_rx:
            return tx, rx
        shm_kind = self.transport.kind == "shm"
        deadline = time.monotonic() + self.timeout
        pending = []
        for peer, key in new_tx:  # phase 1: resources up, SYNs out
            cid = self._chan_next
            self._chan_next += 1
            spec = {"cid": cid, "key": key}
            if shm_kind:
                from multiprocessing import shared_memory
                cap, _ = channel_lib.chunk_layout(channel_lib.key_layout(key)[2])
                name = channel_lib.channel_segment_name(
                    self.transport.session, self.rank, peer, cid)
                seg = shared_memory.SharedMemory(
                    name=name, create=True,
                    size=channel_lib._CTRL_BYTES + channel_lib.NSLOTS * cap)
                spec["segment"] = name
                chan = channel_lib.ShmChannel(self, peer, key, seg,
                                              sender=True, owner=True)
            else:
                chan = channel_lib.SockSendChannel(self, peer, key, cid,
                                                   self.transport.wire(peer))
            self.send_obj(peer, ("chan-syn", spec), tag=TAG_CHAN)
            pending.append((peer, key, chan))
        for peer, key in new_rx:  # phase 2: service inbound SYNs, ACK
            sort, spec = self.recv_obj(peer, tag=TAG_CHAN)
            if sort != "chan-syn" or spec["key"] != key:
                raise RuntimeError(
                    f"rank {self.rank}: persistent-channel negotiation "
                    f"mismatch with rank {peer} — peer announced "
                    f"{spec.get('key') if sort == 'chan-syn' else sort!r}, "
                    f"this rank expected {key}")
            if shm_kind:
                from repro.transport.shm import _attach
                seg = _attach(spec["segment"], create=False,
                              deadline=deadline)
                chan = channel_lib.ShmChannel(self, peer, key, seg,
                                              sender=False, owner=False)
            else:
                chan = channel_lib.SockRecvChannel(self, peer, key,
                                                   spec["cid"])
                self._chan_rx[(peer, spec["cid"])] = chan
            self._chan_cache[(peer, "rx", key)] = chan
            self._channels.append(chan)
            rx[peer] = chan
            self.send_obj(peer, ("chan-ack", spec["cid"]), tag=TAG_CHAN)
        for peer, key, chan in pending:  # phase 3: collect ACKs
            sort, cid = self.recv_obj(peer, tag=TAG_CHAN)
            if sort != "chan-ack":
                raise RuntimeError(
                    f"rank {self.rank}: expected channel ACK from rank "
                    f"{peer}, got {sort!r}")
            self._chan_cache[(peer, "tx", key)] = chan
            self._channels.append(chan)
            tx[peer] = chan
        return tx, rx

    def bump_epoch(self) -> None:
        """Advance the message epoch: frames already in flight with the old
        stamp will be lazily discarded.  The case runner calls this (plus a
        barrier) between test cases so a case that raised mid-exchange
        cannot leak a matching-but-wrong frame into the next case."""
        self._epoch += 1

    @property
    def epoch(self) -> int:
        """The current message epoch (stamped on every outbound frame)."""
        return self._epoch

    def close(self) -> None:
        """Stop the readers, release every persistent channel, and tear
        down the transport (idempotent).  Channel owners unlink their shm
        segments here — the worker's final barrier has already run, so no
        peer is still reading them."""
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        for chan in self._channels:
            chan.close()
        self._channels.clear()
        self._chan_cache.clear()
        self._chan_rx.clear()
        self.transport.close()


# ---------------------------------------------------------------------------
# MultiprocComm — the Communicator subtype that selects the wire kernels
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultiprocComm(Communicator):
    """A communicator whose ops execute across real host processes.

    Drop-in for :class:`~repro.core.comm.Communicator`: same frozen-
    dataclass identity semantics (``dup()`` still bumps ``context``; plan
    caches key on it), but ``backend = "multiproc"`` routes every
    ``registry.select`` to the ``direct`` wire kernels and the ``_ppermute``
    / ``_barrier_probe`` hooks to the endpoint.  ``transport_kind``
    participates in equality/hash — and hence in every plan-cache key — so
    shm and socket plans never alias.  The ``endpoint`` handle is excluded
    from comparison: it is per-process runtime state, not identity.
    """

    rank_id: int = 0
    nprocs: int = 1
    transport_kind: str = "sock"
    endpoint: Any = dataclasses.field(default=None, compare=False, repr=False)

    backend = "multiproc"  # plain class attribute, not a dataclass field

    # -- topology / identity ------------------------------------------------
    def size(self) -> int:
        """Number of worker processes. Static Python int."""
        return self.nprocs

    def axis_sizes(self) -> tuple[int, ...]:
        """Single proc axis: ``(nprocs,)``."""
        return (self.nprocs,)

    def rank(self):
        """This process's rank (int32 scalar, eager)."""
        return jnp.asarray(self.rank_id, jnp.int32)

    def coords(self):
        """Single-axis coordinates: ``(rank(),)``."""
        return (self.rank(),)

    def split(self, axes):
        """Sub-communicator over an axis subset.

        The multiproc world spans one proc axis, so only the identity
        split is defined (MPI_Comm_split with a single color).
        """
        if tuple(axes) == self.axes:
            return self
        raise ValueError(f"multiproc communicator spans the single axis "
                         f"{self.axes}; cannot split to {tuple(axes)}")

    # -- wire hooks ---------------------------------------------------------
    def _ppermute(self, payload, perm):
        """Real inter-process (src, dst) exchange.

        Matches ``lax.ppermute`` semantics exactly: each listed src sends
        its payload, each listed dst receives the unique message addressed
        to it (injectivity is validated upstream by ``pairwise_perm``), and
        ranks absent from the dst set get zeros.
        """
        ep, me = self.endpoint, self.rank_id
        arr = np.asarray(payload)
        local = None
        for s, d in perm:
            if s == me:
                if d == me:
                    local = arr
                else:
                    ep.send_array(d, arr, TAG_P2P)
        srcs = [s for s, d in perm if d == me]
        if not srcs:
            return jnp.zeros_like(payload)
        if srcs[0] == me:
            got = local
        else:
            got = ep.recv_array(srcs[0], TAG_P2P)
        if got.shape != arr.shape or got.dtype != arr.dtype:
            raise RuntimeError(f"rank {me}: wire payload mismatch — sent "
                               f"{arr.dtype}{arr.shape}, received "
                               f"{got.dtype}{got.shape}")
        return jnp.asarray(got)

    def _barrier_probe(self, tok):
        """Wire-level dissemination barrier; the token passes through."""
        self.endpoint.barrier()
        return tok

    # -- persistent-channel fast path ----------------------------------------
    # Duck-typed hooks the plans layer probes with getattr: *_init on a
    # MultiprocComm negotiates fixed-signature channels once and binds an
    # issue closure that moves only payload bytes in steady state.  Both
    # return None (plans fall back to the generic issue closure) when no
    # channel lowering applies — or when this comm object carries no live
    # endpoint (identity-only instances, e.g. plan-cache key tests).

    def persistent_sendrecv_factory(self, shape, dtype_name, perm):
        """Channel-backed issue closure for a frozen sendrecv pattern."""
        if self.endpoint is None:
            return None
        return channel_lib.sendrecv_issue(self, shape, dtype_name, perm)

    def persistent_issue_factory(self, op_name, algo_name, shape,
                                 dtype_name, kw):
        """Channel-backed issue closure for a frozen direct collective."""
        if self.endpoint is None:
            return None
        return channel_lib.collective_issue(self, op_name, algo_name,
                                            shape, dtype_name, kw)


def make_comm(transport: base.Transport, rank: int, nprocs: int,
              timeout: float | None = None) -> MultiprocComm:
    """Endpoint + communicator for one worker (the bootstrap entry point).

    Args:
        transport: a connected :class:`~.shm.ShmTransport` or
            :class:`~.sock.SockTransport` mesh.
        rank / nprocs: this worker's identity.
        timeout: endpoint frame-wait deadline (None = env default).
    Returns:
        A :class:`MultiprocComm` over the ``("proc",)`` axis.
    """
    ep = Endpoint(transport, rank, nprocs, timeout=timeout)
    return MultiprocComm(("proc",), 0, rank_id=rank, nprocs=nprocs,
                         transport_kind=transport.kind, endpoint=ep)


# ---------------------------------------------------------------------------
# "direct" wire kernels — registered for every collective op on the
# multiproc backend.  All eager: ``val`` is a concrete array, ``comm`` a
# MultiprocComm.  Reductions/concatenations run in rank order 0..n−1 on
# every rank, so results are bit-identical across the group.
# ---------------------------------------------------------------------------

def _exchange_all(comm: MultiprocComm, arr: np.ndarray) -> list[np.ndarray]:
    """Every rank's buffer, rank order (the allgather building block)."""
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    for peer in range(n):
        if peer != me:
            ep.send_array(peer, arr, TAG_COLL)
    return [arr if r == me else ep.recv_array(r, TAG_COLL) for r in range(n)]


@registry.register("allreduce", "direct", backend="multiproc")
def _direct_allreduce(val, tok, comm, *, op):
    """Send to all peers, then reduce-on-receive in rank order — n−1
    messages per rank and never more than one peer buffer plus the
    accumulator live at once (the old gather-then-reduce held all n).
    The combine order is unchanged (0..n−1), so results stay bit-identical
    across ranks and with the previous kernel (all six Operators honored
    via the shared combiner algebra, like the emulated ring kernel)."""
    combine, pre, post = combiner(op)
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    arr = np.asarray(val)
    for peer in range(n):
        if peer != me:
            ep.send_array(peer, arr, TAG_COLL)
    acc = None
    for r in range(n):
        part = jnp.asarray(arr if r == me else ep.recv_array(r, TAG_COLL))
        if pre is not None:
            part = pre(part)
        acc = part if acc is None else combine(acc, part)
    if post is not None:
        acc = post(acc, val.dtype)
    return acc, tok


@registry.register("bcast", "direct", backend="multiproc")
def _direct_bcast(val, tok, comm, *, root):
    """Linear broadcast: root frames its buffer to every other rank."""
    ep, me = comm.endpoint, comm.rank_id
    arr = np.asarray(val)
    if me == root:
        for peer in range(comm.nprocs):
            if peer != root:
                ep.send_array(peer, arr, TAG_COLL)
        out = arr
    else:
        out = ep.recv_array(root, TAG_COLL)
    return jnp.asarray(out), tok


@registry.register("allgather", "direct", backend="multiproc")
def _direct_allgather(val, tok, comm):
    """Direct exchange + rank-order concatenation (tiled layout, matching
    the emulated ``all_gather(..., tiled=True)`` contract)."""
    parts = _exchange_all(comm, np.asarray(val))
    if parts[0].ndim == 0:
        return jnp.stack([jnp.asarray(p) for p in parts]), tok
    return jnp.concatenate([jnp.asarray(p) for p in parts], axis=0), tok


def _rs_supports(val, comm, **kw):
    return val.ndim >= 1 and val.shape[0] % comm.size() == 0


@registry.register("reduce_scatter", "direct", backend="multiproc",
                   supports=_rs_supports)
def _direct_reduce_scatter(val, tok, comm, *, op):
    """Send each destination only ITS axis-0 chunk and reduce-on-receive
    in rank order — n× fewer wire bytes than the old allreduce-then-slice
    form, elementwise-identical results (the combiner ops are all
    elementwise, so summing chunks equals slicing the summed whole)."""
    combine, pre, post = combiner(op)
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    arr = np.asarray(val)
    chunk = arr.shape[0] // n
    for d in range(n):
        if d != me:
            ep.send_array(d, arr[d * chunk:(d + 1) * chunk], TAG_COLL)
    acc = None
    for r in range(n):
        part = jnp.asarray(arr[me * chunk:(me + 1) * chunk] if r == me
                           else ep.recv_array(r, TAG_COLL))
        if pre is not None:
            part = pre(part)
        acc = part if acc is None else combine(acc, part)
    if post is not None:
        acc = post(acc, val.dtype)
    return acc, tok


def _a2a_supports(val, comm, *, split_axis=0, concat_axis=0, **kw):
    return val.ndim > split_axis and val.shape[split_axis] % comm.size() == 0


@registry.register("alltoall", "direct", backend="multiproc",
                   supports=_a2a_supports)
def _direct_alltoall(val, tok, comm, *, split_axis=0, concat_axis=0):
    """Carve ``split_axis`` into per-destination chunks, exchange pairwise,
    concatenate received chunks along ``concat_axis`` in rank order."""
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    chunks = np.split(np.asarray(val), n, axis=split_axis)
    for d in range(n):
        if d != me:
            ep.send_array(d, chunks[d], TAG_COLL)
    got = [chunks[me] if s == me else ep.recv_array(s, TAG_COLL)
           for s in range(n)]
    return jnp.concatenate([jnp.asarray(g) for g in got],
                           axis=concat_axis), tok


@registry.register("scatterv", "direct", backend="multiproc",
                   supports=_scatterv_supports)
def _direct_scatterv(val, tok, comm, *, counts, root):
    """Root frames each rank its padded ``(max(counts), ...)`` chunk —
    ``counts[r]`` valid rows, zeros beyond (the v-variant contract)."""
    ep, me = comm.endpoint, comm.rank_id
    maxc = max(counts) if counts else 0
    arr = np.asarray(val)

    def chunk_for(r):
        offs = _offsets(counts)
        out = np.zeros((maxc,) + arr.shape[1:], arr.dtype)
        out[:counts[r]] = arr[offs[r]:offs[r] + counts[r]]
        return out

    if me == root:
        for r in range(comm.nprocs):
            if r != root:
                ep.send_array(r, chunk_for(r), TAG_COLL)
        out = chunk_for(root)
    else:
        out = ep.recv_array(root, TAG_COLL)
    return jnp.asarray(out), tok


@registry.register("gatherv", "direct", backend="multiproc",
                   supports=_gatherv_supports)
@registry.register("allgatherv", "direct", backend="multiproc",
                   supports=_gatherv_supports)
def _direct_gatherv(val, tok, comm, *, counts, root=0):
    """Exchange padded buffers + static valid-row gather — materialized on
    every rank, exactly like the emulated lowering (gatherv's result is
    contractually valid at root only)."""
    parts = _exchange_all(comm, np.asarray(val))
    flat = np.concatenate(parts, axis=0)
    return jnp.asarray(np.take(flat, _valid_rows(counts), axis=0)), tok


# ---------------------------------------------------------------------------
# Compressed wire kernels — the multiproc twins of the ``int8_ef`` /
# ``topk_ef`` registry lowerings in ``repro.core.compression``.  Here the
# byte win is *literal*: the ARRAY frames carry int8 payloads (numel bytes
# vs 4·numel for fp32) or (int32 index, fp32 value) pairs (8·k bytes), and
# the endpoint's wire_stats() spy measures exactly that.  Reductions run in
# rank order 0..n−1 so every rank computes bit-identical results.
# ---------------------------------------------------------------------------

def _int8_ef_sum(comm: MultiprocComm, g32: np.ndarray):
    """(summed_f32, new_error): agree on a global amax scale (one fp32
    scalar per peer), exchange int8 frames, accumulate in int32 rank order."""
    amax = np.float32(np.max(np.abs(g32))) if g32.size else np.float32(0.0)
    amaxes = _exchange_all(comm, np.asarray([amax], np.float32))
    scale = max(float(max(float(a[0]) for a in amaxes)) / 127.0, 1e-30)
    q = np.clip(np.rint(g32 / scale), -127, 127).astype(np.int8)
    new_error = g32 - q.astype(np.float32) * np.float32(scale)
    parts = _exchange_all(comm, q)
    acc = np.zeros(q.shape, np.int32)
    for p in parts:
        acc += p.astype(np.int32)
    return acc.astype(np.float32) * np.float32(scale), new_error


def _topk_ef_sum(comm: MultiprocComm, g32: np.ndarray, frac: float):
    """(summed_f32, new_error): each rank frames its k largest-magnitude
    entries as (int32 index, fp32 value) pairs; scatter-add in rank order.
    ``argsort(kind="stable")`` breaks ties toward the lower index, matching
    the emulated ``lax.top_k`` selection."""
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    flat = g32.reshape(-1)
    k = max(1, int(round(frac * flat.size)))
    idx = np.argsort(-np.abs(flat), kind="stable")[:k].astype(np.int32)
    vals = flat[idx]
    new_error = flat.copy()
    new_error[idx] = 0.0
    for peer in range(n):
        if peer != me:
            ep.send_array(peer, idx, TAG_COLL)
            ep.send_array(peer, vals, TAG_COLL)
    summed = np.zeros_like(flat)
    for r in range(n):
        if r == me:
            ri, rv = idx, vals
        else:
            ri = ep.recv_array(r, TAG_COLL)
            rv = ep.recv_array(r, TAG_COLL)
        np.add.at(summed, ri, rv)
    return summed.reshape(g32.shape), new_error.reshape(g32.shape)


def _ef_eager(val, comm, state, mean, reducer):
    """Shared EF wrapper: fold the residual in, reduce over the wire, apply
    the mean, cast back — returns ``(out_jnp, new_error_np)``."""
    arr = np.asarray(val)
    g32 = arr.astype(np.float32)
    if state is not None:
        g32 = g32 + np.asarray(state.error).astype(np.float32).reshape(
            g32.shape)
    summed, new_error = reducer(g32)
    out = summed / comm.nprocs if mean else summed
    return jnp.asarray(out.astype(arr.dtype)), new_error


def _ef_pack(out, new_error, state, tok):
    """Conditional kernel contract (see repro.core.compression): plain
    array when stateless, (reduced, CompressionState) when state given."""
    if state is None:
        return out, tok
    return (out, CompressionState(error=jnp.asarray(new_error))), tok


@registry.register("allreduce", "int8_ef", backend="multiproc",
                   supports=_ef_supports, operators=(Operator.SUM,))
def _direct_int8_ef_allreduce(val, tok, comm, *, op=None, state=None,
                              mean=False, **_kw):
    """int8-wire allreduce across real processes: ~4× fewer payload bytes
    than the fp32 direct kernel for the same gradient."""
    out, new_error = _ef_eager(val, comm, state, mean,
                               lambda g: _int8_ef_sum(comm, g))
    return _ef_pack(out, new_error, state, tok)


@registry.register("allreduce", "topk_ef", backend="multiproc",
                   supports=_ef_supports, operators=(Operator.SUM,))
def _direct_topk_ef_allreduce(val, tok, comm, *, op=None, state=None,
                              mean=False, frac=DEFAULT_TOPK_FRAC, **_kw):
    """Sparse top-k allreduce across real processes: wire bytes scale with
    k = round(frac·numel), not the gradient size."""
    out, new_error = _ef_eager(val, comm, state, mean,
                               lambda g: _topk_ef_sum(comm, g, frac))
    return _ef_pack(out, new_error, state, tok)


def _ef_chunk(out, comm):
    """This rank's axis-0 reduce_scatter chunk of a full reduced array."""
    chunk = out.shape[0] // comm.nprocs
    me = comm.rank_id
    return out[me * chunk:(me + 1) * chunk]


@registry.register("reduce_scatter", "int8_ef", backend="multiproc",
                   supports=_ef_rs_supports, operators=(Operator.SUM,))
def _direct_int8_ef_reduce_scatter(val, tok, comm, *, op=None, state=None,
                                   mean=False, **_kw):
    """int8-wire reduce_scatter: full compressed sum, keep own chunk; the
    residual stays full-shape (it corrects the whole input gradient)."""
    out, new_error = _ef_eager(val, comm, state, mean,
                               lambda g: _int8_ef_sum(comm, g))
    return _ef_pack(_ef_chunk(out, comm), new_error, state, tok)


@registry.register("reduce_scatter", "topk_ef", backend="multiproc",
                   supports=_ef_rs_supports, operators=(Operator.SUM,))
def _direct_topk_ef_reduce_scatter(val, tok, comm, *, op=None, state=None,
                                   mean=False, frac=DEFAULT_TOPK_FRAC, **_kw):
    """Sparse top-k reduce_scatter: sparse sum, keep own axis-0 chunk."""
    out, new_error = _ef_eager(val, comm, state, mean,
                               lambda g: _topk_ef_sum(comm, g, frac))
    return _ef_pack(_ef_chunk(out, comm), new_error, state, tok)


@registry.register("alltoallv", "direct", backend="multiproc",
                   supports=_alltoallv_supports)
def _direct_alltoallv(val, tok, comm, *, counts):
    """Slot exchange: send slot ``d`` (invalid rows zeroed before the wire)
    to rank ``d``; returned slot ``s`` holds rank ``s``'s rows for us."""
    ep, me, n = comm.endpoint, comm.rank_id, comm.nprocs
    arr = np.asarray(val)
    out = np.zeros_like(arr)
    for d in range(n):
        slot = arr[d].copy()
        slot[counts[me][d]:] = 0
        if d == me:
            out[me] = slot
        else:
            ep.send_array(d, slot, TAG_COLL)
    for s in range(n):
        if s != me:
            out[s] = ep.recv_array(s, TAG_COLL)
    return jnp.asarray(out), tok
