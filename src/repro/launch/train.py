"""Training launcher: ``--arch <id>`` selects an assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 50 \
        [--tiny] [--ranks 4] [--microbatch 2] [--ckpt DIR] [--comm jmpi]

``--tiny`` (default) runs the reduced config on host devices; without it the
full config is used (sized for real accelerators — on CPU it is only
feasible via the dry-run).  Fault tolerance is on: watchdog + periodic async
checkpoints + resume-from-latest.
"""

import argparse
import os


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ranks", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", type=int, default=0,
                    choices=[0, 8, 16])
    ap.add_argument("--comm", default="gspmd", choices=["gspmd", "jmpi"])
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    return ap.parse_args()


def main():
    args = parse_args()
    if args.ranks > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.ranks}"

    import jax
    import jax.numpy as jnp

    import repro.core as jmpi
    from repro.configs import get_config, get_tiny
    from repro.configs.base import RunConfig, ShapeCell
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm as lm_lib
    from repro.train import checkpoint as ckpt
    from repro.train import optim
    from repro.train.data import SyntheticLM
    from repro.train.ft import Watchdog
    from repro.train.trainer import build_jmpi_train_step, build_train_step

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    rc = RunConfig(learning_rate=args.lr, microbatch=args.microbatch,
                   grad_compression_bits=args.grad_compression,
                   comm_backend=args.comm)
    mesh = make_host_mesh(args.ranks, axes=("data",))
    cell = ShapeCell("cli", args.seq, args.batch, "train")

    params = lm_lib.init_params(cfg, jax.random.PRNGKey(rc.seed))
    opt = optim.init(params, rc)
    data = SyntheticLM(cfg, args.batch, args.seq, seed=rc.seed)
    wd = Watchdog()
    saver = ckpt.AsyncSaver()

    start = 0
    if args.ckpt:
        latest = ckpt.latest_step(args.ckpt)
        if latest is not None:
            (params, opt), start, _ = ckpt.restore(args.ckpt, (params, opt))
            start += 1
            print(f"[train] resumed from step {start}")

    if args.comm == "jmpi":
        step = build_jmpi_train_step(cfg, rc, mesh, None)
        comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
    else:
        step = build_train_step(cfg, rc, mesh, cell).jitted()

    import time
    for i in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        if args.comm == "jmpi":
            params, opt, comp, loss = step(params, opt, comp, batch)
            loss_v = float(loss)
        else:
            params, opt, metrics = step(params, opt, batch)
            loss_v = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if wd.observe(i, dt):
            print(f"[train] straggler flagged at step {i} ({dt:.2f}s)")
        if i % 10 == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} loss={loss_v:.4f} ({dt*1e3:.0f} ms)")
        if args.ckpt and i % args.ckpt_every == args.ckpt_every - 1:
            saver.save_async(args.ckpt, (params, opt), i)
    saver.wait()
    if args.ckpt:
        ckpt.save(args.ckpt, (params, opt), args.steps - 1)
    print("[train] done")


if __name__ == "__main__":
    main()
