"""Render the §Dry-run / §Roofline markdown tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
                                                 [--tag baseline] [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_, tag=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(f))
        if tag and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def fmt_bytes(n):
    return f"{n / 2**30:.2f}"


def roofline_table(recs, mesh="single"):
    rows = []
    hdr = ("| arch | shape | GiB/dev | compute s | memory s | collective s | "
           "dominant | roofline frac | model/HLO flops | collectives |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skip | — | — | {r['reason'][:40]}… |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
            continue
        t = r["roofline"]
        ck = r["collectives"]["per_kind_counts"]
        cks = " ".join(f"{k.split('-')[-1]}:{int(v)}" for k, v in
                       sorted(ck.items()) if v)
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_bytes(r['memory'].get('bytes_per_device', 0))} | "
            f"{t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {t['dominant']} | "
            f"{t['roofline_fraction_compute']:.2f} | "
            f"{t.get('model_vs_hlo_flops', 0):.2f} | {cks} |")
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | chips | compile s | GiB/dev | "
            "HLO flops/dev | coll bytes/dev | status |",
            "|" + "---|" * 9]
    for r in recs:
        if r["status"] == "ok":
            t = r["roofline"]
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
                f"{r['compile_s']} | "
                f"{fmt_bytes(r['memory'].get('bytes_per_device', 0))} | "
                f"{t['hlo_flops']:.2e} | {t['collective_bytes']:.2e} | ok |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                        f"| — | — | — | {r['status']} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir, args.tag)
    if args.table == "roofline":
        print(roofline_table(recs, args.mesh))
    else:
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
