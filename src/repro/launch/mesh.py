"""Production meshes (TPU v5e target).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the `pod` axis is
data-parallel by default (per-pod FSDP + DCN gradient reduction) and can run
pipeline stages instead (repro.distributed.pipeline).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets the emulated device count before first use).
"""

from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axes=("data",)):
    """Mesh over the locally visible (possibly emulated) devices — used by
    tests, examples and benchmarks."""
    n = n if n is not None else len(jax.devices())
    import numpy as np
    devs = np.array(jax.devices()[:n])
    if len(axes) == 1:
        shape = (n,)
    else:
        shape = (n // 2, 2) if n % 2 == 0 else (n, 1)
    return compat.make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
