"""Model input construction: concrete batches (smoke tests / training) and
ShapeDtypeStruct stand-ins (dry-run lowering, no allocation).

The modality frontends are stubs per the assignment: musicgen receives
precomputed EnCodec frame embeddings, internvl2 receives precomputed,
pre-projected ViT patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import lm as lm_lib


def batch_struct(cfg: ModelConfig, batch: int, seq: int, kind: str):
    """Pytree of ShapeDtypeStructs for one step's data inputs."""
    dt = cfg.act_dtype
    d = {}
    if cfg.embeds_input:  # audio
        s = 1 if kind == "decode" else seq
        d["embeds"] = jax.ShapeDtypeStruct((batch, s, cfg.d_model), dt)
        if cfg.cross_attn:
            d["cond"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_cond_tokens, cfg.d_model), dt)
    elif cfg.n_img_tokens and kind != "decode":  # vlm
        d["tokens"] = jax.ShapeDtypeStruct((batch, seq - cfg.n_img_tokens),
                                           jnp.int32)
        d["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_img_tokens, cfg.d_model), dt)
    else:
        s = 1 if kind == "decode" else seq
        d["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
    if kind == "train":
        d["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return d


def synth_batch(cfg: ModelConfig, batch: int, seq: int, kind: str, seed=0):
    """Concrete random batch matching ``batch_struct`` (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    structs = batch_struct(cfg, batch, seq, kind)
    out = {}
    for k, s in structs.items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size
            out[k] = jnp.asarray(rng.integers(0, hi, size=s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return out


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for the decode caches (dry-run serve_step input)."""
    return jax.eval_shape(lambda: lm_lib.init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Everything the step function consumes (data + caches), as structs."""
    if cell.kind == "train":
        return {"batch": batch_struct(cfg, cell.global_batch, cell.seq_len,
                                      "train")}
    if cell.kind == "prefill":
        return {"batch": batch_struct(cfg, cell.global_batch, cell.seq_len,
                                      "prefill")}
    # decode: one new token against a seq_len-deep cache
    return {
        "batch": batch_struct(cfg, cell.global_batch, cell.seq_len, "decode"),
        "caches": cache_struct(cfg, cell.global_batch, cell.seq_len),
    }
