"""Re-run the HLO cost analysis over saved .hlo.gz dumps (no recompiles).

    PYTHONPATH=src python -m repro.launch.reanalyze [--dir experiments/dryrun]

Updates each cell's JSON in place with fresh roofline terms — used when the
cost model itself is iterated (§Roofline methodology changes are replayable).
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch import hlo_cost
from repro.launch import roofline as rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    n = 0
    for jf in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__"
                f"{rec.get('tag', 'baseline')}")
        hf = os.path.join(args.dir, "hlo", name + ".hlo.gz")
        if not os.path.exists(hf):
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        cost = hlo_cost.analyze(hlo)
        coll = {"total_bytes": cost["collective_bytes"],
                "per_kind_bytes": cost["per_kind_bytes"],
                "per_kind_counts": cost["per_kind_counts"]}
        cfg = get_config(rec["arch"])
        mf = rl.model_flops_for(cfg, SHAPES[rec["shape"]])
        rec["collectives"] = coll
        rec["roofline"] = rl.roofline_terms(cost, coll, rec["chips"],
                                            model_flops=mf)
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
