import os
# Emulated device count (process-global, must be set before jax init).
# 512 = the dry-run pod mesh; set HILLCLIMB_DEVICES=8 for --tune-collectives
# so the tuner measures a realistic group size.
_N_DEV = os.environ.get("HILLCLIMB_DEVICES", "512")
os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_N_DEV} "
                           + os.environ.get("XLA_FLAGS", "")).strip()

# §Perf hillclimb driver: run named variants of the three chosen cells and
# record each as experiments/dryrun/<cell>__<variant>.json.  Iterations and
# their hypotheses live in EXPERIMENTS.md §Perf; this file is the
# reproducible harness.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb --only A1 B1 C1

import argparse      # noqa: E402
import json          # noqa: E402
import traceback     # noqa: E402

from repro.launch.dryrun import dryrun_cell   # noqa: E402

# variant registry: name -> (arch, shape, kwargs for dryrun_cell)
VARIANTS = {
    # ---- Cell A: qwen2-1.5b × train_4k (worst roofline fraction) --------
    # A1 as first tried (microbatch=4) left batch 64 — not divisible by
    # data×model=256, so the constraint fell back to data-only: REFUTED
    # (bit-identical HLO). A1b drops grad accumulation so the full 256
    # batch can spread over both axes during attention.
    "A1b-batch-attn-mb1": (
        "qwen2-1.5b", "train_4k",
        dict(rules_extra={"batch_attn": (("data", "model"), ("data",))},
             rc_overrides=dict(microbatch=1))),
    "A2-mb1-only-ablation": (      # isolate: how much is mb1 alone?
        "qwen2-1.5b", "train_4k",
        dict(rc_overrides=dict(microbatch=1))),
    # A1b refuted: batch-boundary reshard triggers involuntary full
    # remat in the SPMD partitioner (112 GiB, collective x4).  A3 shards the
    # attention QUERY-SEQUENCE over the model axis instead: entering the
    # section is a local slice (x replicated over model), leaving is a
    # plain all-gather — the pattern GSPMD handles natively.
    "A3-seq-attn-over-model": (
        "qwen2-1.5b", "train_4k",
        dict(rules_extra={"seq_attn": (("model",), None)})),

    # ---- Cell B: deepseek-v3-671b × train_4k (collective + memory) ------
    "B1-bf16-params": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"))),
    "B2-bf16+adafactor": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"),
             rc_overrides=dict(optimizer="adafactor"))),
    "B3-bf16+adafactor+mb2": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"),
             rc_overrides=dict(optimizer="adafactor", microbatch=2))),
    "B4-bf16+adafactor+mb8": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"),
             rc_overrides=dict(optimizer="adafactor", microbatch=8))),
    # B2 showed temp=77.8 GiB unchanged: the layer-scan carry stack + the
    # CPU-XLA hoisted whole-stack fp32 convert.  Barrier the carry so LICM
    # cannot commute the convert past the slice.
    "B5-bf16+adafactor+mb8+barrier": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16", carry_barrier=True),
             rc_overrides=dict(optimizer="adafactor", microbatch=8))),

    # B6: Megatron-style sequence parallelism — the residual stream (and
    # the 61-layer scan carry stack, the biggest temp) shards its seq dim
    # over `model`; attention gathers full seq at entry (seq_attn=None
    # boundary), MoE reshards tokens to data-groups.
    "B6-bf16+adafactor+mb4+seqpar": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"),
             rc_overrides=dict(optimizer="adafactor", microbatch=4),
             rules_extra={"seq": (("model",), None)})),

    # B7: 2-D expert parallelism — experts over model×data (1 expert per
    # device at E=256): expert weights never FSDP-gather; tokens move via
    # dispatch all-to-alls instead (napkin: ~1 TB/step of weight gathers
    # becomes ~30 GB/step of activation movement).
    "B7-expert2d": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"),
             rc_overrides=dict(optimizer="adafactor", microbatch=4),
             rules_extra={"_expert_2d": True,
                          "experts": (("model", "data"), ("model",)),
                          "moe_groups": (None,)})),
    "B8-expert2d+seqpar": (
        "deepseek-v3-671b", "train_4k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"),
             rc_overrides=dict(optimizer="adafactor", microbatch=4),
             rules_extra={"_expert_2d": True,
                          "experts": (("model", "data"), ("model",)),
                          "moe_groups": (None,),
                          "seq": (("model",), None)})),

    # ---- Cell C: h2o-danube-3-4b × decode_32k (paper's serving regime) --
    "C1-serve-nofsdp": (
        "h2o-danube-3-4b", "decode_32k",
        dict(decode_fsdp=False)),
    "C2-serve-nofsdp-bf16": (
        "h2o-danube-3-4b", "decode_32k",
        dict(decode_fsdp=False,
             cfg_overrides=dict(param_dtype="bfloat16"))),
    "C3-serve-bf16-fsdp": (                    # ablation: bf16 but keep FSDP
        "h2o-danube-3-4b", "decode_32k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"))),
    # C1–C3 left a 6.05 GB/step all-gather: the model-side kv_seq constraint
    # (default: replicated) un-sharded the seq-over-model KV cache every
    # step.  model_rules now matches the cache layout in decode cells →
    # partial-KV attention + tiny psum combine.  C4 = that fix alone
    # (paper-faithful layout otherwise); C5 = fix + serving mode.
    "C4-partialkv": (
        "h2o-danube-3-4b", "decode_32k", dict()),
    "C5-partialkv-serve-nofsdp-bf16": (
        "h2o-danube-3-4b", "decode_32k",
        dict(decode_fsdp=False,
             cfg_overrides=dict(param_dtype="bfloat16"))),
    # ---- mixtral train memory (43 GiB baseline): bigger grad-accum k ----
    "X1-mixtral-mb8": (
        "mixtral-8x22b", "train_4k",
        dict(rc_overrides=dict(microbatch=8))),

    # ---- serving-mode memory fixes for the remaining over-budget decode
    # cells (inherit C5's lever) ------------------------------------------
    "M1-musicgen-decode-serve": (
        "musicgen-large", "decode_32k",
        dict(decode_fsdp=False, cfg_overrides=dict(param_dtype="bfloat16"))),
    "M2-deepseek-decode-serve": (
        "deepseek-v3-671b", "decode_32k",
        dict(cfg_overrides=dict(param_dtype="bfloat16"))),
}


def tune_collectives(out_path: str, n_devices: int | None = None):
    """§Perf: bench-driven collective-algorithm tuning — sweep the registry's
    algorithms × payload sizes, emit the policy table consumed at trace time
    (``jmpi.load_policy`` / ``RunConfig.collective_policy``).  Run with
    ``HILLCLIMB_DEVICES=8`` so the emulated group matches the test topology."""
    from repro.launch import collective_tuner
    return collective_tuner.tune(out_path, n_devices=n_devices)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tune-collectives", action="store_true",
                    help="sweep collective algorithms and emit the policy "
                         "table instead of running dry-run variants")
    ap.add_argument("--tune-out", default="experiments/collective_policy.json")
    ap.add_argument("--tune-devices", type=int, default=None)
    args = ap.parse_args()
    if args.tune_collectives:
        os.makedirs(os.path.dirname(args.tune_out) or ".", exist_ok=True)
        tune_collectives(args.tune_out, n_devices=args.tune_devices)
        return
    os.environ["DRYRUN_OUT"] = args.out
    names = args.only or list(VARIANTS)
    for name in names:
        match = [k for k in VARIANTS if k.startswith(name)]
        if not match:
            print(f"unknown variant {name}")
            continue
        key = match[0]
        arch, shape, kw = VARIANTS[key]
        print(f"[hillclimb] {key}: {arch} × {shape} ...", flush=True)
        try:
            rec = dryrun_cell(arch, shape, args.mesh == "multi", tag=key,
                              **kw)
        except Exception:
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "tag": key, "status": "failed",
                   "traceback": traceback.format_exc()}
        path = os.path.join(args.out,
                            f"{arch}__{shape}__{args.mesh}__{key}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            t = rec["roofline"]
            print(f"  ok: GiB/dev="
                  f"{rec['memory'].get('bytes_per_device', -1)/2**30:.2f} "
                  f"compute={t['compute_s']:.3f} memory={t['memory_s']:.3f} "
                  f"collective={t['collective_s']:.3f} "
                  f"dom={t['dominant']} mf/hlo="
                  f"{t.get('model_vs_hlo_flops', 0):.2f}", flush=True)
        else:
            print("  FAILED\n" + rec.get("traceback", "")[-1500:], flush=True)


if __name__ == "__main__":
    main()
