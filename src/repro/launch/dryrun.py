import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", "")).strip()

# Multi-pod dry-run (deliverable e): lower + compile every
# (architecture × input shape × mesh) cell against the production meshes
# (16×16 single-pod, 2×16×16 multi-pod) with ShapeDtypeStruct inputs — no
# allocation — and extract memory_analysis / cost_analysis / the collective
# schedule for the roofline table (EXPERIMENTS.md §Dry-run, §Roofline).
#
# The two lines above run before ANY other import: jax locks the device count
# at first backend init.  Everything else (smoke tests, benches) sees 1 device
# because only this entrypoint sets the flag.

import argparse        # noqa: E402
import gzip            # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config          # noqa: E402
from repro.configs.base import RunConfig                     # noqa: E402
from repro.launch import hlo_cost                            # noqa: E402
from repro.launch import roofline as rl                      # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.train.trainer import build_step                   # noqa: E402

SKIP_LONG = {  # pure full-attention archs skip long_500k (DESIGN.md §4)
    "musicgen-large", "qwen2-1.5b", "minitron-8b", "yi-6b",
    "deepseek-v3-671b", "internvl2-1b",
}


def run_cfg_for(arch: str, kind: str = "train") -> RunConfig:
    rc = RunConfig()
    if arch == "deepseek-v3-671b":
        rc.opt_state_dtype = "bfloat16"   # DESIGN.md §5 memory plan
    if kind == "train":
        # activation memory /k via grad accumulation (EXPERIMENTS.md §Dry-run)
        rc.microbatch = 4
    return rc


def dryrun_cell(arch: str, shape: str, multi_pod: bool, rules_extra=None,
                tag: str = "baseline", cfg_overrides=None, rc_overrides=None,
                decode_fsdp: bool = True):
    """Lower+compile one cell. Returns the result record (dict).

    cfg_overrides / rc_overrides / rules_extra / decode_fsdp parameterize
    §Perf hillclimb variants; the default call is the paper-faithful
    baseline."""
    cell = SHAPES[shape]
    cfg = get_config(arch)
    if cell.needs_subquadratic and arch in SKIP_LONG:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "pure full-attention arch; long_500k needs "
                          "sub-quadratic attention (DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rc = run_cfg_for(arch, cell.kind)
    for k, v in (cfg_overrides or {}).items():
        setattr(cfg, k, v)
    for k, v in (rc_overrides or {}).items():
        setattr(rc, k, v)
    t0 = time.time()
    bundle = build_step(cfg, rc, mesh, cell, rules_extra,
                        decode_fsdp=decode_fsdp)
    lowered = bundle.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0))
            + int(getattr(mem, "argument_size_in_bytes", 0))
            + int(getattr(mem, "output_size_in_bytes", 0))
            - int(getattr(mem, "alias_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover - backend-dependent
        mem_rec = {"error": str(e)}

    xla_cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)   # trip-count-aware (§Roofline notes)
    coll = {"total_bytes": cost["collective_bytes"],
            "per_kind_bytes": cost["per_kind_bytes"],
            "per_kind_counts": cost["per_kind_counts"]}
    mf = rl.model_flops_for(cfg, cell)
    terms = rl.roofline_terms(cost, coll, chips, model_flops=mf)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "tag": tag, "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_rec,
        "cost_xla": {k: float(v) for k, v in xla_cost.items()
                     if isinstance(v, (int, float))},
        "collectives": coll,
        "roofline": terms,
        "hlo_bytes": len(hlo),
    }
    if os.environ.get("DRYRUN_SAVE_HLO", "1") == "1":
        hdir = os.path.join(os.environ.get("DRYRUN_OUT", "experiments/dryrun"),
                            "hlo")
        os.makedirs(hdir, exist_ok=True)
        name = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}__{tag}"
        with gzip.open(os.path.join(hdir, name + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    os.makedirs(args.out, exist_ok=True)
    os.environ["DRYRUN_OUT"] = args.out

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                name = f"{arch}__{shape}__{m}__{args.tag}.json"
                path = os.path.join(args.out, name)
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] exists, skipping {name}", flush=True)
                    continue
                print(f"[dryrun] {arch} × {shape} × {m} ...", flush=True)
                try:
                    rec = dryrun_cell(arch, shape, m == "multi",
                                      tag=args.tag)
                except Exception:
                    rec = {"arch": arch, "shape": shape, "mesh": m,
                           "tag": args.tag, "status": "failed",
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                if st == "ok":
                    r = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"bytes/dev={rec['memory'].get('bytes_per_device', -1)/2**30:.2f}GiB "
                          f"compute={r['compute_s']:.4f}s "
                          f"memory={r['memory_s']:.4f}s "
                          f"collective={r['collective_s']:.4f}s "
                          f"dominant={r['dominant']}", flush=True)
                elif st == "skipped":
                    print(f"  skipped: {rec['reason']}", flush=True)
                else:
                    print("  FAILED:\n" + rec["traceback"][-2000:], flush=True)
    print(f"[dryrun] done ok={n_ok} skipped={n_skip} failed={n_fail}",
          flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
