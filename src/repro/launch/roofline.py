"""Roofline term extraction from compiled dry-run artifacts.

compute    = HLO_FLOPs / (chips × 197e12)
memory     = HLO_bytes / (chips × 819e9)
collective = collective_bytes / (chips × 50e9)

``cost_analysis()`` provides FLOPs / bytes-accessed.  Collective bytes are
NOT in cost_analysis: we parse the (SPMD-partitioned, per-device-shaped) HLO
text and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.  Since post-partitioning shapes are
per-device, the operand-byte sum approximates bytes through one device's ICI
links; the assignment's formula divides the raw sum by `chips`, so we report
BOTH: `collective_bytes_sum` (per-device parse, no division) as the primary
per-device term and `collective_term_spec` (sum/chips) for the formula as
written.
"""

from __future__ import annotations

import re


from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' HLO shape literal."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nb = _DTYPE_BYTES.get(dt)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind (per-device shapes).

    HLO line shape: ``%x = TYPE op-name(...)`` — we take the result TYPE
    (incl. tuples) of each collective op; for all-gather and all-to-all the
    result size equals the data a device moves per op up to the (n−1)/n
    wire factor; for all-reduce we count the operand once (ring moves
    2·(n−1)/n ≈ 2× — recorded under `allreduce_wire_factor`).
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        for kind in _COLLECTIVES:
            marker = None
            for suffix in ("(", "-start("):
                if f" {kind}{suffix}" in ls:
                    marker = f" {kind}{suffix}"
                    break
            if marker is None:
                continue
            # result type(s) live between '=' and the op name; layouts
            # ({2,1,0}) and tuple parens are skipped by the shape regex.
            result_part = ls.split(marker, 1)[0].split("=", 1)[1]
            nb = sum(_shape_bytes(s.group(0))
                     for s in _SHAPE_RE.finditer(result_part))
            per_kind[kind] += nb
            counts[kind] += 1
            break
    total = sum(per_kind.values())
    return {"per_kind_bytes": per_kind, "per_kind_counts": counts,
            "total_bytes": total}


def roofline_terms(cost: dict, coll: dict, chips: int,
                   model_flops: float | None = None) -> dict:
    """Three-term roofline from PER-DEVICE aggregates.

    ``cost`` comes from repro.launch.hlo_cost.analyze (trip-count-aware;
    the builtin cost_analysis counts while bodies once — §Roofline notes) —
    its shapes are post-SPMD per-device, so per-chip terms do NOT divide by
    ``chips`` again.
    """
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes", cost.get("bytes accessed", 0.0)))
    cbytes = float(coll["total_bytes"])
    terms = {
        "hlo_flops": flops,
        "hlo_bytes": nbytes,
        "collective_bytes": cbytes,
        "chips": chips,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": nbytes / HBM_BW,
        "collective_s": cbytes / ICI_BW,
        # the assignment's literal formula (sum / chips) for reference:
        "collective_s_spec": cbytes / (chips * ICI_BW),
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant.replace("_s", "")
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction_compute"] = (
        terms["compute_s"] / bound if bound > 0 else 0.0)
    if model_flops is not None:
        terms["model_flops"] = model_flops
        total_hlo = flops * chips
        terms["model_vs_hlo_flops"] = (model_flops / total_hlo
                                       if total_hlo else 0.0)
    return terms


def model_flops_for(cfg, cell) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch
    tokens per step."""
    n = cfg.n_active_params() if cfg.n_experts else cfg.n_params()
    if cell.kind == "train":
        d = cell.global_batch * cell.seq_len
        return 6.0 * n * d
    if cell.kind == "prefill":
        d = cell.global_batch * cell.seq_len
        return 2.0 * n * d
    return 2.0 * n * cell.global_batch  # decode: one token per sequence
