"""Bench-driven collective-algorithm tuner.

Sweeps every registered algorithm of every logical collective over a payload
-size grid on the live backend, picks the fastest per (op, size) cell, and
emits the JSON :class:`repro.core.registry.PolicyTable` that the trace-time
dispatcher consumes (``jmpi.load_policy``).  This is the OMB-Py loop turned
into a build step: measure → table → every future trace picks the winning
schedule for its payload.

Entry points:
  * ``python -m repro.launch.hillclimb --tune-collectives`` (emits
    ``experiments/collective_policy.json``)
  * ``python benchmarks/bench_collectives.py --sweep-algorithms`` (prints
    the sweep CSV + the derived policy table with crossover points)
"""

from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

import repro.core as jmpi
from repro.core import registry

#: payload grid in fp32 elements: 256 B … 4 MiB — brackets the latency→
#: bandwidth crossover on every transport we target.
SIZES = (64, 1024, 16384, 262144, 1048576)
#: Flat equal-count collectives only: the neighborhood ops need a CartComm
#: topology (benchmarked by ``benchmarks/bench_halo.py --neighbor``) and
#: the v-variants need static counts arrays (benchmarked by the
#: ``coll_allgatherv``/``coll_alltoallv`` cases of the collectives suite);
#: both keep xla_native policy defaults rather than being silently skipped.
OPS = tuple(op for op in registry.OPS
            if not op.startswith("neighbor_") and not op.endswith("v"))
INNER = 20


def tune_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over the first ``n_devices`` local (possibly emulated)
    devices — capped at 8 by default so a 512-device dry-run environment
    still tunes on a realistic group size."""
    devs = jax.devices()
    n = n_devices or min(8, len(devs))
    return Mesh(np.array(devs[:n]), ("ranks",))


def _op_body(op: str, algo: str, n: int):
    def body(acc):
        if op == "allreduce":
            _, y = jmpi.allreduce(acc, algorithm=algo)
        elif op == "bcast":
            _, y = jmpi.bcast(acc, root=0, algorithm=algo)
        elif op == "allgather":
            _, g = jmpi.allgather(acc, algorithm=algo)
            y = g.reshape(n, -1).sum(0)
        elif op == "reduce_scatter":
            _, s = jmpi.reduce_scatter(acc, algorithm=algo)
            y = jnp.tile(s, n)
        elif op == "alltoall":
            _, y = jmpi.alltoall(acc, algorithm=algo)
        else:  # pragma: no cover
            raise ValueError(op)
        return y / jnp.maximum(jnp.abs(y).max(), 1.0)

    return body


def timed_loop(mesh, op: str, algo: str, numel: int,
               inner: int = INNER, repeat: int = 3) -> float:
    """Seconds per call of the JIT-resident collective (whole chained loop
    compiled; dispatch amortized across ``inner`` calls)."""

    @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
    def f(x):
        body = _op_body(op, algo, jmpi.size())
        return jax.lax.fori_loop(0, inner, lambda i, a: body(a), x)

    x = jnp.ones((numel,), jnp.float32)
    f(x).block_until_ready()
    t = min(timeit.repeat(lambda: f(x).block_until_ready(), number=1,
                          repeat=repeat))
    return t / inner


def sweep(mesh, sizes=SIZES, ops=OPS, inner: int = INNER) -> list[dict]:
    """algorithms × sizes grid; one record per measured cell.  Combinations
    an algorithm statically cannot handle (non-divisible payload, non-pow2
    group, multi-axis comm) are skipped."""
    n = int(np.prod([d for d in mesh.devices.shape]))
    records = []
    for op in ops:
        for numel in sizes:
            if op in ("alltoall", "reduce_scatter") and numel % n:
                continue
            for algo in registry.algorithms(op):
                try:
                    t = timed_loop(mesh, op, algo, numel, inner=inner)
                except ValueError:
                    continue  # supports() rejected the payload at trace time
                records.append({
                    "op": op, "algorithm": algo, "numel": numel,
                    "nbytes": numel * 4, "ranks": n,
                    "us_per_call": t * 1e6,
                })
    return records


def build_policy(records: list[dict]) -> registry.PolicyTable:
    """argmin over algorithms per (op, size) cell → byte-range rules.

    Bucket edges sit at the geometric midpoints between measured sizes;
    rules are emitted only where a non-default algorithm wins (the default
    column stays ``xla_native``), pinned to the measured rank count.
    """
    rules: list[registry.PolicyRule] = []
    ops = sorted({r["op"] for r in records})
    for op in ops:
        sizes = sorted({r["nbytes"] for r in records if r["op"] == op})
        edges = [0] + [int((a * b) ** 0.5) for a, b in zip(sizes, sizes[1:])] \
            + [None]
        for i, nbytes in enumerate(sizes):
            cell = [r for r in records
                    if r["op"] == op and r["nbytes"] == nbytes]
            winner = min(cell, key=lambda r: r["us_per_call"])
            if winner["algorithm"] == registry.DEFAULT_ALGORITHM:
                continue
            rules.append(registry.PolicyRule(
                op=op, algorithm=winner["algorithm"],
                min_bytes=edges[i], max_bytes=edges[i + 1],
                ranks=winner["ranks"]))
    return registry.PolicyTable(
        rules=rules,
        default={op: registry.DEFAULT_ALGORITHM for op in OPS})


def crossover_report(records: list[dict]) -> str:
    """Winner per (op, size) with the runner-up gap — the measured
    crossover points the ISSUE asks the bench to record."""
    lines = [f"{'op':<16}{'nbytes':>10}  {'winner':<20}{'us':>9}  gap_vs_next"]
    for op in sorted({r["op"] for r in records}):
        for nbytes in sorted({r["nbytes"] for r in records
                              if r["op"] == op}):
            cell = sorted((r for r in records
                           if r["op"] == op and r["nbytes"] == nbytes),
                          key=lambda r: r["us_per_call"])
            w = cell[0]
            gap = (f"{cell[1]['us_per_call'] / w['us_per_call']:.2f}x"
                   if len(cell) > 1 else "-")
            lines.append(f"{op:<16}{nbytes:>10}  {w['algorithm']:<20}"
                         f"{w['us_per_call']:>9.1f}  {gap}")
    return "\n".join(lines)


def tune(out_path: str, n_devices: int | None = None,
         sizes=SIZES) -> registry.PolicyTable:
    """Measure, build the policy table, save it, and make it active."""
    mesh = tune_mesh(n_devices)
    records = sweep(mesh, sizes=sizes)
    table = build_policy(records)
    table.save(out_path)
    registry.set_policy(table)
    print(crossover_report(records))
    print()
    print(table.describe())
    print(f"\npolicy table written to {out_path} "
          f"(consume with jmpi.load_policy)")
    return table
