"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies
ONCE — a 28–100× FLOP undercount for scan-over-layers / microbatch /
chunked-attention programs (measured in EXPERIMENTS.md §Roofline, iteration
0).  This analyzer parses the post-optimization HLO, recovers while-loop
trip counts from their condition computations, and accumulates per
computation:

  flops       — dot (2·|out|·k_contract via the operand symbol table),
                elementwise ≈ 1 flop/element
  hbm bytes   — per kernel-ish instruction (fusion / dot / copy / slice /
                collective): operand + result bytes (the TPU fusion model:
                each fused kernel reads its inputs once, writes its outputs
                once)
  collectives — result-shape bytes per op kind

each multiplied by the product of enclosing while-loop trip counts.  Shapes
are per-device (the module is SPMD-partitioned), so totals are per-device.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "u1": 1, "s1": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")


def _seg_shapes(segment: str):
    """[(elems, bytes, dims)] for every dtype[dims] literal in segment."""
    out = []
    for m in _SHAPE_RE.finditer(segment):
        dt, dims_s = m.groups()
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        out.append((n, n * nb, dims))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_seg: str
    operand_names: list
    attr_seg: str
    line: str
    elems: int
    bytes: int
    dims0: list  # dims of the first result shape


def parse_computations(hlo: str):
    comps = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and "(" in stripped and "=" not in \
                stripped.split("(", 1)[0]:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = {}
                if stripped.startswith("ENTRY"):
                    entry = cur
            continue
        if stripped.startswith("}"):
            continue
        m = _INSTR_RE.match(stripped)
        if not m or cur is None:
            continue
        name, rest = m.groups()
        om = _OP_RE.search(rest)
        if not om:
            continue
        op = om.group(1)
        result_seg = rest[:om.start()]
        tail = rest[om.start():]
        depth = 0
        end = len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_seg = tail[:end + 1]
        attr_seg = tail[end + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_seg)
        shapes = _seg_shapes(result_seg)
        elems = sum(s[0] for s in shapes)
        nbytes = sum(s[1] for s in shapes)
        dims0 = shapes[0][2] if shapes else []
        comps[cur][name] = Instr(name, op, result_seg, operands, attr_seg,
                                 stripped, elems, nbytes, dims0)
    return comps, entry


def _trip_count(while_ins: Instr, cond_instrs: dict):
    # preferred: XLA annotates the while op itself
    m = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)', while_ins.line)
    if m:
        return int(m.group(1))
    # fallback: constant operand of the compare (possibly via a fusion wrap)
    consts = {}
    for ins in cond_instrs.values():
        if ins.op == "constant":
            mc = re.search(r"constant\((-?\d+)\)", ins.line)
            if mc:
                consts[ins.name] = int(mc.group(1))
    for ins in cond_instrs.values():
        if ins.op in ("compare", "fusion"):
            for operand in ins.operand_names:
                if consts.get(operand, 0) > 0:
                    return consts[operand]
    return 1


class HloCost:
    def __init__(self, hlo: str):
        self.comps, self.entry = parse_computations(hlo)
        self._memo = {}

    def _attr_comp(self, ins, attr):
        m = re.search(attr + r"=%?([\w.\-]+)", ins.attr_seg)
        return m.group(1) if m and m.group(1) in self.comps else None

    def _operand_bytes(self, ins, symtab):
        return sum(symtab[o].bytes for o in ins.operand_names if o in symtab)

    def _fusion_io_bytes(self, ins, symtab):
        """HBM traffic of a fusion callsite.

        Operands that the fused computation consumes ONLY through
        dynamic-slice (and the in-place buffer of a root dynamic-update-
        slice) are charged at *slice* size, not buffer size — XLA reads the
        addressed window and aliases in-place updates; charging the whole
        stacked-layer buffer per scan iteration inflated memory terms ~20×
        (§Roofline methodology, iteration 2).
        """
        sub_name = self._attr_comp(ins, "calls") or self._attr_comp(
            ins, "to_apply")
        sub = self.comps.get(sub_name, {})
        # map parameter name -> its operand position
        param_pos = {}
        for s_ins in sub.values():
            if s_ins.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", s_ins.line)
                if m:
                    param_pos[s_ins.name] = int(m.group(1))
        # classify how each parameter is consumed
        sliced_bytes = {}      # param name -> charged bytes
        disqualified = set()   # param read in full somewhere
        for s_ins in sub.values():
            if s_ins.op == "parameter":
                continue
            for pos, opn in enumerate(s_ins.operand_names):
                if opn not in param_pos:
                    continue
                if s_ins.op == "dynamic-slice" and pos == 0:
                    sliced_bytes[opn] = sliced_bytes.get(opn, 0) + s_ins.bytes
                elif s_ins.op == "dynamic-update-slice" and pos == 0:
                    # in-place window write: charge the update size (read of
                    # the window is already the update operand's charge)
                    upd = s_ins.operand_names[1] if len(
                        s_ins.operand_names) > 1 else None
                    ub = sub[upd].bytes if upd in sub else 0
                    sliced_bytes[opn] = sliced_bytes.get(opn, 0) + ub
                else:
                    disqualified.add(opn)
        total = 0
        for param_name, pos in param_pos.items():
            if pos >= len(ins.operand_names):
                continue
            parent_op = ins.operand_names[pos]
            full = symtab[parent_op].bytes if parent_op in symtab else 0
            if param_name in sliced_bytes and param_name not in disqualified:
                total += min(sliced_bytes[param_name], full)
            else:
                total += full
        # result: a root dynamic-update-slice aliases its big operand —
        # charge the update window, not the buffer.
        root_dus = any(s.op == "dynamic-update-slice" and "ROOT" in s.line
                       for s in sub.values())
        if root_dus:
            upd_bytes = sum(s.bytes for s in sub.values()
                            if s.op == "dynamic-update-slice")
            total += min(upd_bytes, ins.bytes)
        else:
            total += ins.bytes
        return total

    def _dot_flops(self, ins, symtab):
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attr_seg)
        lhs = symtab.get(ins.operand_names[0]) if ins.operand_names else None
        if m and lhs is not None:
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(lhs.dims0):
                    k *= lhs.dims0[int(ci)]
        return 2.0 * ins.elems * max(k, 1)

    def comp_cost(self, name: str):
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = (0.0, 0.0, {}, {})  # cycle guard
        symtab = self.comps.get(name, {})
        flops = bytes_ = 0.0
        coll = defaultdict(float)
        ccnt = defaultdict(float)

        for ins in symtab.values():
            f = b = 0.0
            if ins.op == "dot":
                f = self._dot_flops(ins, symtab)
                b = ins.bytes + self._operand_bytes(ins, symtab)
            elif ins.op == "while":
                cond = self._attr_comp(ins, "condition")
                body = self._attr_comp(ins, "body")
                trips = _trip_count(ins, self.comps.get(cond, {}))
                bf, bb, bc, bcc = self.comp_cost(body) if body else (0, 0, {}, {})
                cf, cb, _, _ = self.comp_cost(cond) if cond else (0, 0, {}, {})
                flops += trips * (bf + cf)
                bytes_ += trips * (bb + cb)
                for k2, v in bc.items():
                    coll[k2] += trips * v
                for k2, v in bcc.items():
                    ccnt[k2] += trips * v
                continue
            elif ins.op in ("fusion", "call", "map"):
                if ins.op == "fusion":
                    b = self._fusion_io_bytes(ins, symtab)
                else:
                    b = ins.bytes + self._operand_bytes(ins, symtab)
                for attr in ("calls", "to_apply"):
                    sub = self._attr_comp(ins, attr)
                    if sub:
                        sf, _, sc, scc = self.comp_cost(sub)
                        f += sf
                        for k2, v in sc.items():
                            coll[k2] += v
                        for k2, v in scc.items():
                            ccnt[k2] += v
            elif ins.op == "conditional":
                branches = re.findall(r"(?:true_computation|false_computation|"
                                      r"branch_computations)=\{?%?([\w.\-,%\s]+)\}?",
                                      ins.attr_seg)
                names = []
                for b_ in branches:
                    names += [n.strip().lstrip("%") for n in b_.split(",")]
                costs = [self.comp_cost(n) for n in names if n in self.comps]
                if costs:
                    f = max(c[0] for c in costs)
                    b = max(c[1] for c in costs)
            elif any(ins.op == k or ins.op == k + "-start"
                     for k in _COLLECTIVES):
                base = next(k for k in _COLLECTIVES
                            if ins.op in (k, k + "-start"))
                coll[base] += ins.bytes
                ccnt[base] += 1
                b = ins.bytes
                sub = self._attr_comp(ins, "to_apply")
                if sub:
                    f += self.comp_cost(sub)[0]
            elif ins.op == "dynamic-slice":
                b = 2.0 * ins.bytes                 # window read + write out
            elif ins.op == "dynamic-update-slice":
                upd = (symtab[ins.operand_names[1]].bytes
                       if len(ins.operand_names) > 1
                       and ins.operand_names[1] in symtab else ins.bytes)
                b = 2.0 * upd                       # in-place window update
            elif ins.op in _FREE_OPS or ins.op.endswith("-done"):
                pass
            else:
                # standalone elementwise-ish op.  The CPU backend leaves many
                # of these unfused where TPU/XLA would fuse them into their
                # producer/consumer; count result bytes only (operands
                # assumed hot) — the fusion-calibrated middle ground
                # (§Roofline methodology note).
                f = float(ins.elems)
                b = ins.bytes
            flops += f
            bytes_ += b
        self._memo[name] = (flops, bytes_, dict(coll), dict(ccnt))
        return self._memo[name]

    def totals(self):
        f, b, c, cc = self.comp_cost(self.entry)
        return {"flops": f, "bytes": b,
                "collective_bytes": sum(c.values()),
                "per_kind_bytes": c, "per_kind_counts": cc}


def analyze(hlo: str) -> dict:
    return HloCost(hlo).totals()
