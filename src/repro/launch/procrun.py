"""``python -m repro.launch.procrun`` — mpiexec-style CLI for the
multiproc backend.

Examples::

    # run an entry function on 4 real processes over shared memory
    python -m repro.launch.procrun -n 4 --transport shm mypkg.mymod:main

    # run a test-case module across 2 socket-connected workers
    python -m repro.launch.procrun -n 2 --cases tests.cases_parity

The entry contract is the launcher's: ``function(comm)`` — or
``function(comm, args)`` with ``--args '<json>'`` — receives a live
:class:`~repro.transport.endpoint.MultiprocComm` installed as the ambient
WORLD.  Exit status is 0 only when every worker exits 0.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    """Parse args, run the job, relay rank 0's transcript; 0 on success."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.procrun",
        description="Launch a multi-process jmpi job (real inter-process "
                    "transport backend).")
    ap.add_argument("entry", nargs="?", default=None,
                    help="worker entry as module:function")
    ap.add_argument("-n", "--nprocs", type=int, default=2,
                    help="number of worker processes (default 2)")
    ap.add_argument("--transport", choices=("shm", "sock"), default="sock",
                    help="wire transport (default sock)")
    ap.add_argument("--timeout", type=float, default=None,
                    help="job deadline in seconds (default JMPI_TIMEOUT/120)")
    ap.add_argument("--args", default=None,
                    help="JSON value forwarded to the entry function")
    ap.add_argument("--cases", default=None, metavar="MODULE",
                    help="run a tests.cases_* module through the multiproc "
                         "case runner instead of a custom entry")
    ns = ap.parse_args(argv)

    import json

    from repro.transport import launcher

    if ns.cases is not None:
        entry = "repro.transport.testing:_case_entry"
        args = {"module": ns.cases}
    elif ns.entry is not None:
        entry = ns.entry
        args = json.loads(ns.args) if ns.args is not None else None
    else:
        ap.error("give an entry (module:function) or --cases MODULE")

    job = launcher.launch(ns.nprocs, entry, transport=ns.transport,
                          args=args, timeout=ns.timeout)
    try:
        transcript = job.wait()
    except (launcher.WorkerFailure, TimeoutError) as e:
        print(str(e), file=sys.stderr)
        return 1
    finally:
        job.close()
    if transcript.strip():
        print(transcript, end="" if transcript.endswith("\n") else "\n")
    if ns.cases is not None and "FAIL " in transcript:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
