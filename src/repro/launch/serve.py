"""Serving launcher: continuous batching over the paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
        --requests 8 --new-tokens 32 [--prompt-len 16] [--engine padded]

Drives :class:`repro.serve.engine.ContinuousEngine` on a mixed workload
(per-request budgets, staggered arrivals) and reports aggregate tokens/s
plus p50/p99 request latency; ``--engine padded`` runs the fixed-batch
baseline on the same prompts for an eyeball comparison.
"""

import argparse
import sys
import time


def main():
    """CLI entry point."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--engine", choices=("continuous", "padded"),
                    default="continuous")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_tiny
    from repro.models import lm as lm_lib
    from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

    cfg = get_tiny(args.arch)
    if cfg.embeds_input or cfg.n_img_tokens:
        sys.exit(f"{args.arch} needs modality frontend inputs; "
                 "pick a text arch for the CLI demo")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len), dtype=np.int32)

    if args.engine == "padded":
        eng = Engine(cfg, params,
                     ServeConfig(max_prompt=args.prompt_len + 8,
                                 max_new_tokens=args.new_tokens))
        eng.generate(prompts)                  # compile
        t0 = time.perf_counter()
        out = eng.generate(prompts)
        dt = time.perf_counter() - t0
        print(f"[serve/padded] {cfg.name}: {out.shape[0]}×{out.shape[1]} "
              f"tokens in {dt:.2f}s -> {out.size/dt:.0f} tok/s")
        print(out[: min(2, len(out))])
        return

    slots = min(8, args.requests)
    bs = 8
    max_seq = args.prompt_len + args.new_tokens
    pages = -(-max_seq // bs)
    sc = ServeConfig(max_prompt=args.prompt_len, eos_id=-1,
                     max_new_tokens=args.new_tokens, block_size=bs,
                     n_blocks=slots * pages + 1, max_slots=slots,
                     prefill_chunk=min(16, args.prompt_len),
                     prefill_batch=min(4, slots))
    eng = ContinuousEngine(cfg, params, sc)

    def workload():
        eng.reset()
        # mixed budgets + two arrivals per step: the traffic shape
        # continuous batching exists for
        wrng = np.random.default_rng(1)
        for i, p in enumerate(prompts):
            mnt = int(wrng.integers(max(1, args.new_tokens // 4),
                                    args.new_tokens + 1))
            eng.submit(p, mnt, arrival=i // 2)
        return eng.run()

    workload()                                 # compile
    t0 = time.perf_counter()
    res = workload()
    dt = time.perf_counter() - t0
    done = sum(len(v) for v in res.values())
    lat = np.sort(np.array(list(eng.latency.values()))) * 1e3
    print(f"[serve/continuous] {cfg.name}: {len(res)} requests, {done} "
          f"tokens in {dt:.2f}s -> {done/dt:.0f} tok/s "
          f"(p50 {np.percentile(lat, 50):.0f}ms, "
          f"p99 {np.percentile(lat, 99):.0f}ms; steps={eng.stats['steps']}, "
          f"peak_active={eng.stats['peak_active']})")
    for rid in sorted(res)[:2]:
        print(f"  rid {rid}: {[int(t) for t in res[rid][:12]]}"
              f"{' ...' if len(res[rid]) > 12 else ''}")


if __name__ == "__main__":
    main()
