"""Serving launcher: batched generation with the prefill/decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
        --batch 8 --new-tokens 32 [--prompt-len 16]
"""

import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_tiny
    from repro.models import lm as lm_lib
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_tiny(args.arch)
    if cfg.embeds_input or cfg.n_img_tokens:
        sys.exit(f"{args.arch} needs modality frontend inputs; "
                 "pick a text arch for the CLI demo")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params,
                 ServeConfig(max_prompt=args.prompt_len + 8,
                             max_new_tokens=args.new_tokens))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    eng.generate(prompts)                      # compile
    t0 = time.perf_counter()
    out = eng.generate(prompts)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: {out.shape[0]}×{out.shape[1]} tokens in "
          f"{dt:.2f}s -> {out.size/dt:.0f} tok/s")
    print(out[: min(2, len(out))])


if __name__ == "__main__":
    main()
