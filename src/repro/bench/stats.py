"""Robust statistics for benchmark samples.

Wall-clock samples from a shared CPU runner are contaminated by one-sided
noise (scheduler preemption, GC, turbo transitions): the distribution has a
hard lower bound near the "true" cost and a long right tail.  The helpers
here are the standard OMB/MatlabMPI-style summaries for that shape —
**median** (headline, tail-robust), **IQR** (spread, outlier-robust) and
**min-of-k** (best-case floor, the classic ``timeit`` reduction) — computed
without numpy so the compare gate stays importable host-side.

All quantile math uses linear interpolation on sorted samples, matching
``numpy.quantile``'s default method (the test suite checks this against
numpy oracles).
"""

from __future__ import annotations

from typing import Sequence


def quantile(samples: Sequence[float], q: float) -> float:
    """Linear-interpolated quantile of ``samples`` (numpy's default method).

    Args:
        samples: non-empty sequence of values.
        q: quantile in [0, 1].
    Returns:
        The interpolated quantile value.
    Raises:
        ValueError: on an empty sequence or ``q`` outside [0, 1].
    """
    if not samples:
        raise ValueError("quantile of empty sequence")
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    xs = sorted(samples)
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def median(samples: Sequence[float]) -> float:
    """Median (0.5 quantile, linear interpolation)."""
    return quantile(samples, 0.5)


def iqr(samples: Sequence[float]) -> float:
    """Interquartile range: q75 − q25."""
    return quantile(samples, 0.75) - quantile(samples, 0.25)


def min_of_k(samples: Sequence[float], k: int | None = None) -> float:
    """Minimum of the first ``k`` samples (all samples when ``k`` is None).

    Args:
        samples: non-empty sequence of values.
        k: how many leading samples to consider.
    Returns:
        The smallest considered sample.
    Raises:
        ValueError: on an empty sequence or non-positive ``k``.
    """
    if not samples:
        raise ValueError("min_of_k of empty sequence")
    if k is not None:
        if k <= 0:
            raise ValueError(f"min_of_k needs k >= 1, got {k}")
        samples = list(samples)[:k]
    return min(samples)


def summarize(samples: Sequence[float]) -> dict:
    """Full robust summary of a sample set.

    Args:
        samples: non-empty sequence of per-call values (any unit).
    Returns:
        Dict with ``n``, ``min``, ``max``, ``mean``, ``median``, ``p25``,
        ``p75`` and ``iqr`` — the stats block of a benchmark row.
    """
    xs = sorted(samples)
    return {
        "n": len(xs),
        "min": xs[0],
        "max": xs[-1],
        "mean": sum(xs) / len(xs),
        "median": median(xs),
        "p25": quantile(xs, 0.25),
        "p75": quantile(xs, 0.75),
        "iqr": iqr(xs),
    }
