"""``python -m repro.bench`` — the unified benchmark CLI.

Parent/child split: emulated device counts are process-global (XLA reads
``--xla_force_host_platform_device_count`` once, at backend init), so the
parent process never imports a suite; it spawns one child per requested
suite with the right count pinned, streams the child's human-readable rows,
and collects the child's schema artifact into ``BENCH_<suite>.json``.

Examples::

    python -m repro.bench --list
    python -m repro.bench --suite p2p --quick --json out.json
    python -m repro.bench --suite p2p,collectives --quick --out-dir bench-out
    python -m repro.bench                      # every suite, full grids

Gate the artifacts with ``python -m repro.bench.compare`` (see
docs/BENCHMARKS.md for the baseline-update workflow).
"""

from __future__ import annotations

import argparse
import importlib
import os
import subprocess
import sys
import tempfile

from repro.bench import schema
from repro.bench.core import BenchConfig, effective_sizes, format_row, \
    run_case
from repro.bench.suites import SUITES, resolve

CHILD_TIMEOUT_S = 3600


def repo_root() -> str:
    """The repository root (src/repro/bench → three levels up)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="unified OMB-style benchmark runner")
    ap.add_argument("--suite", default=None,
                    help="comma-separated suite names (default: all; "
                         "see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered suites and exit")
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids/steps (CI lane, smoke tests)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed steady-state samples per cell (default 5)")
    ap.add_argument("--warmup", type=int, default=1,
                    help="discarded calls before sampling (default 1)")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated size override for sweepable cases")
    ap.add_argument("--cases", default=None,
                    help="only run cases whose name contains one of these "
                         "comma-separated substrings")
    ap.add_argument("--backend", choices=("emulated", "multiproc"),
                    default=None,
                    help="tag the run's artifacts with a transport backend "
                         "(sets JMPI_BACKEND for the suite children; the "
                         "compare gate refuses cross-backend comparisons)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="artifact path (single suite only)")
    ap.add_argument("--out-dir", default=None, metavar="DIR",
                    help="directory for BENCH_<suite>.json artifacts "
                         "(default: repo root)")
    ap.add_argument("--in-child", action="store_true",
                    help=argparse.SUPPRESS)  # internal: run in-process
    return ap


def _config_from_args(args: argparse.Namespace) -> BenchConfig:
    sizes = tuple(int(s) for s in args.sizes.split(",")) if args.sizes \
        else None
    cases = tuple(c.strip() for c in args.cases.split(",") if c.strip()) \
        if args.cases else None
    return BenchConfig(quick=args.quick, repeats=args.repeats,
                       warmup=args.warmup, sizes=sizes, cases=cases)


def run_suite_inprocess(name: str, cfg: BenchConfig,
                        echo=print) -> dict:
    """Run one suite in this process and return its artifact document.

    The caller is responsible for the device count (the CLI child and the
    legacy ``benchmarks/bench_*.py`` wrappers pin XLA_FLAGS before jax is
    imported).

    Args:
        name: registered suite name.
        cfg: the effective configuration.
        echo: sink for human-readable progress rows.
    Returns:
        A schema-valid artifact dict.
    """
    spec = SUITES[name]
    mod = importlib.import_module(spec.module)
    rows: list[dict] = []
    for case in mod.build(cfg):
        if not cfg.wants(case.name):
            continue
        for size in effective_sizes(case, cfg):
            if case.size_ok is not None and not case.size_ok(size):
                echo(f"# skip {case.name}[{size}]: size rejected by case")
                continue
            row = run_case(case, size, cfg)
            rows.append(row)
            echo(format_row(row))
    invariants: dict = {}
    if hasattr(mod, "extras"):
        extra_rows, invariants = mod.extras(cfg, rows)
        for row in extra_rows:
            rows.append(row)
            echo(format_row(row))
        for key, ok in invariants.items():
            echo(f"# invariant {key}: {'OK' if ok else 'FAILED'}")
    doc = schema.make_doc(spec.name, rows, invariants, cfg.to_dict())
    return doc


def _child_argv(spec, args: argparse.Namespace, emit_path: str) -> list[str]:
    argv = [sys.executable, "-m", "repro.bench", "--suite", spec.name,
            "--in-child", "--json", emit_path,
            "--repeats", str(args.repeats), "--warmup", str(args.warmup)]
    if args.quick:
        argv.append("--quick")
    if args.sizes:
        argv += ["--sizes", args.sizes]
    if args.cases:
        argv += ["--cases", args.cases]
    return argv


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns the process exit code."""
    args = _build_parser().parse_args(argv)
    if args.list:
        for spec in SUITES.values():
            print(f"{spec.name:<14}n_devices={spec.n_devices:<3} "
                  f"{spec.description}")
        return 0

    specs = resolve(args.suite)
    cfg = _config_from_args(args)

    if args.in_child:
        # Child mode: one suite, devices already pinned by the parent env.
        assert len(specs) == 1 and args.json, "--in-child needs one " \
            "--suite and a --json path"
        doc = run_suite_inprocess(specs[0].name, cfg)
        schema.dump(doc, args.json)
        return 0

    if args.json and len(specs) != 1:
        raise SystemExit("--json needs exactly one --suite "
                         "(use --out-dir for multi-suite runs)")

    from repro.testing import child_env

    out_dir = args.out_dir or repo_root()
    os.makedirs(out_dir, exist_ok=True)
    failures: list[str] = []
    written: list[str] = []
    for spec in specs:
        print(f"# suite {spec.name} (n_devices={spec.n_devices}"
              f"{' quick' if args.quick else ''})", flush=True)
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            emit_path = f.name
        try:
            env = child_env(spec.n_devices)
            if args.backend:
                env["JMPI_BACKEND"] = args.backend
            proc = subprocess.run(
                _child_argv(spec, args, emit_path),
                env=env, capture_output=True,
                text=True, timeout=CHILD_TIMEOUT_S)
            sys.stdout.write(proc.stdout)
            if proc.returncode != 0:
                failures.append(spec.name)
                sys.stdout.write(
                    f"# FAILED {spec.name}\n{proc.stderr[-2000:]}\n")
                continue
            dest = args.json or os.path.join(out_dir,
                                             f"BENCH_{spec.name}.json")
            schema.dump(schema.load(emit_path), dest)
            written.append(dest)
            print(f"# wrote {dest}", flush=True)
        finally:
            if os.path.exists(emit_path):
                os.unlink(emit_path)
    if failures:
        print(f"# suite failures: {failures}", file=sys.stderr)
        return 1
    return 0


def legacy_main(suite_name: str, argv: list[str] | None = None) -> int:
    """Entry point for the thin ``benchmarks/bench_*.py`` wrappers.

    Runs the suite in-process (the wrapper pinned XLA_FLAGS before any jax
    import) with the shared CLI flags, printing rows to stdout.

    Args:
        suite_name: registered suite name.
        argv: CLI args (default ``sys.argv[1:]``).
    Returns:
        Process exit code (0 = all invariants held).
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--sizes", default=None)
    ap.add_argument("--cases", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    cfg = _config_from_args(args)
    doc = run_suite_inprocess(suite_name, cfg)
    if args.json:
        schema.dump(doc, args.json)
        print(f"# wrote {args.json}")
    bad = [k for k, ok in doc["invariants"].items() if not ok]
    if bad:
        print(f"# invariant failures: {bad}", file=sys.stderr)
        return 1
    return 0
