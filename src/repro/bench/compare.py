"""``python -m repro.bench.compare`` — the perf regression gate.

Compares current ``BENCH_<suite>.json`` artifacts against the committed
baselines in ``benchmarks/baselines/`` and fails (exit 1) when a suite's
median regresses beyond a noise-calibrated threshold:

* **suite-median gate** (``--threshold``, default 1.75): per-row ratios
  (current median / baseline median) are collected and the suite fails
  when their *median* exceeds the threshold.  Calibration data from the
  CPU container this gate was built on: back-to-back identical quick runs
  show *individual* collective rows swinging 0.3x–4.3x (bursty shared
  cores), while the per-suite median of ratios stays near 0.9 — so the
  suite median separates noise from a genuine uniform slowdown (an
  injected 2x moves it to exactly 2.0);
* **per-row hard cap** (``--row-cap``, default 3x the threshold): a single
  row regressing catastrophically fails even when the suite median holds —
  sized above the measured worst-case single-row noise (4.3x);
* **min-runtime floor** (``--floor-us``, default 30): rows whose baseline
  median is below the floor are reported but never gated — timer jitter
  dominates there;
* only rows with a **time unit** (us/ms/s) gate; ratio/counter rows are
  reported context;
* **stale-baseline detection**: when the current run carries gated rows
  the committed baseline predates (a suite grew new cases), the gate
  fails with ONE readable message naming the rows and the
  ``--update-baselines`` fix, instead of silently passing them or
  emitting a per-row wall;
* **missing-baseline detection**: a suite with *no committed baseline
  file at all* (a brand-new suite) fails the same way — one readable
  line naming ``--update-baselines`` — never a silent pass.

Modes::

    python -m repro.bench.compare                     # gate vs baselines
    python -m repro.bench.compare --smoke             # schema + invariants
    python -m repro.bench.compare --update-baselines  # intentional change

``--smoke`` replaces the old grep-based CI assertions: it validates every
current artifact against the schema and requires every recorded invariant
(plan-cache reuse, policy-table derivation, oracle agreement) to be true —
an exit code, not a string match.

The baseline-update workflow (for intentional perf changes) is documented
in docs/BENCHMARKS.md.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.bench import schema

DEFAULT_THRESHOLD = 1.75
DEFAULT_FLOOR_US = 30.0


def find_artifacts(current: str | None) -> list[str]:
    """Locate current artifacts.

    Args:
        current: a directory, a single file, or None (= repo root).
    Returns:
        Sorted list of ``BENCH_*.json`` paths (or the single file).
    """
    if current and os.path.isfile(current):
        return [current]
    from repro.bench.cli import repo_root
    root = current or repo_root()
    return sorted(glob.glob(os.path.join(root, "BENCH_*.json")))


def baseline_path(baselines_dir: str, suite: str) -> str:
    """The committed baseline file for ``suite``."""
    return os.path.join(baselines_dir, f"{suite}.json")


def default_baselines_dir() -> str:
    """``benchmarks/baselines`` under the repo root."""
    from repro.bench.cli import repo_root
    return os.path.join(repo_root(), "benchmarks", "baselines")


def gated_rows(doc: dict) -> dict:
    """Index a document's gate-able rows.

    Args:
        doc: a schema-valid artifact.
    Returns:
        ``{(name, size): value_in_us}`` for every time-unit row that has
        not opted out via ``"gate": false`` (reported-only extras rows).
    """
    out = {}
    for row in doc["rows"]:
        factor = schema.TIME_UNITS.get(row["unit"])
        if factor is not None and row.get("gate", True):
            out[(row["name"], row["size"])] = float(row["value"]) * factor
    return out


def compare_docs(current: dict, baseline: dict,
                 threshold: float = DEFAULT_THRESHOLD,
                 floor_us: float = DEFAULT_FLOOR_US,
                 row_cap: float | None = None
                 ) -> tuple[list[str], list[str]]:
    """Gate one current artifact against its baseline.

    The primary gate is the suite-level median of per-row ratios (see the
    module docstring for the noise calibration); a per-row hard cap
    catches catastrophic single-row regressions.

    Args:
        current: the just-measured artifact.
        baseline: the committed artifact for the same suite.
        threshold: max allowed suite-median ratio.
        floor_us: baseline medians below this are reported, never gated.
        row_cap: max allowed single-row ratio (None = 3x ``threshold``).
    Returns:
        ``(failures, report)`` — failure strings (empty = pass) and
        human-readable per-row report lines.
    """
    from repro.bench.stats import median as _median

    row_cap = row_cap if row_cap is not None else 3.0 * threshold
    failures: list[str] = []
    report: list[str] = []
    suite = current.get("suite")
    if suite != baseline.get("suite"):
        failures.append(f"suite mismatch: current={suite!r} "
                        f"baseline={baseline.get('suite')!r}")
        return failures, report
    # Backend is a hard wall, not an annotation: a multiproc (real process)
    # artifact and an emulated (single-process mesh) artifact measure
    # different transports and must never gate against each other.
    cur_bk = current["env"].get("backend", "emulated")
    base_bk = baseline["env"].get("backend", "emulated")
    if cur_bk != base_bk:
        failures.append(
            f"{suite}: backend mismatch — current artifact was measured "
            f"under {cur_bk!r}, baseline under {base_bk!r}; re-baseline "
            f"with the matching backend instead of comparing across them")
        return failures, report
    for key in ("device_count", "quick", "policy_hash"):
        cur, base = current["env"].get(key), baseline["env"].get(key)
        if cur != base:
            report.append(f"  note: env.{key} differs "
                          f"(current={cur!r} baseline={base!r})")
    cur_rows, base_rows = gated_rows(current), gated_rows(baseline)
    ratios: list[float] = []
    for key in sorted(base_rows, key=str):
        name = key[0] if not key[1] else f"{key[0]}[{key[1]}]"
        base_us = base_rows[key]
        if key not in cur_rows:
            failures.append(f"{suite}: row {name} present in baseline but "
                            f"missing from current run")
            continue
        cur_us = cur_rows[key]
        ratio = cur_us / base_us if base_us > 0 else float("inf")
        line = (f"  {name:<40} base={base_us:10.1f}us "
                f"cur={cur_us:10.1f}us ratio={ratio:5.2f}")
        if base_us < floor_us:
            report.append(line + "  (below floor, not gated)")
            continue
        ratios.append(ratio)
        if ratio > row_cap:
            failures.append(
                f"{suite}: {name} regressed {ratio:.2f}x "
                f"({base_us:.1f}us -> {cur_us:.1f}us, "
                f"row cap {row_cap:.2f}x)")
            report.append(line + "  REGRESSED (row cap)")
        elif ratio > threshold:
            report.append(line + "  above threshold (suite-median gated)")
        else:
            report.append(line)
    if ratios:
        suite_ratio = _median(ratios)
        report.append(f"  suite median ratio over {len(ratios)} gated "
                      f"row(s): {suite_ratio:.2f} "
                      f"(threshold {threshold:.2f})")
        if suite_ratio > threshold:
            failures.append(
                f"{suite}: suite median ratio {suite_ratio:.2f}x exceeds "
                f"threshold {threshold:.2f}x "
                f"({len(ratios)} gated rows)")
    new_keys = sorted(set(cur_rows) - set(base_rows), key=str)
    for key in new_keys:
        name = key[0] if not key[1] else f"{key[0]}[{key[1]}]"
        report.append(f"  {name:<40} new row (no baseline)")
    if new_keys:
        # Stale baseline: the suite grew rows the committed baseline
        # predates.  ONE readable failure naming the rows and the fix —
        # not a per-row wall — so CI tells the author exactly what to do.
        names = sorted({k[0] for k in new_keys})
        failures.append(
            f"{suite}: committed baseline predates {len(new_keys)} new "
            f"row(s) ({', '.join(names)}); refresh it with `python -m "
            f"repro.bench.compare --update-baselines` after a clean run "
            f"(workflow: docs/BENCHMARKS.md)")
    return failures, report


def smoke_check(paths: list[str]) -> list[str]:
    """Schema + invariant validation of current artifacts (no baselines).

    Args:
        paths: artifact files to check.
    Returns:
        Failure strings; empty means every artifact is schema-valid, has
        at least one row, and every recorded invariant is true.
    """
    failures = []
    if not paths:
        failures.append("no BENCH_*.json artifacts found")
    for path in paths:
        try:
            doc = schema.load(path)
        except (ValueError, OSError, json.JSONDecodeError) as e:
            failures.append(f"{path}: {e}")
            continue
        if not doc["rows"]:
            failures.append(f"{path}: artifact has no rows")
        for key, ok in doc["invariants"].items():
            if not ok:
                failures.append(f"{path}: invariant {key!r} is false")
    return failures


def update_baselines(paths: list[str], baselines_dir: str) -> list[str]:
    """Adopt the current artifacts as the new committed baselines.

    Args:
        paths: current artifact files.
        baselines_dir: destination directory.
    Returns:
        The written baseline paths.
    """
    os.makedirs(baselines_dir, exist_ok=True)
    written = []
    for path in paths:
        doc = schema.load(path)
        dest = baseline_path(baselines_dir, doc["suite"])
        schema.dump(doc, dest)
        written.append(dest)
    return written


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.compare",
        description="benchmark regression gate over BENCH_*.json artifacts")
    ap.add_argument("--current", default=None,
                    help="artifact file or directory (default: repo root)")
    ap.add_argument("--baselines", default=None,
                    help="baseline directory (default benchmarks/baselines)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help=f"max suite-median current/baseline ratio "
                         f"(default {DEFAULT_THRESHOLD})")
    ap.add_argument("--row-cap", type=float, default=None,
                    help="max single-row ratio (default 3x the threshold)")
    ap.add_argument("--floor-us", type=float, default=DEFAULT_FLOOR_US,
                    help=f"baseline medians below this many us are not "
                         f"gated (default {DEFAULT_FLOOR_US})")
    ap.add_argument("--smoke", action="store_true",
                    help="schema + invariant validation only (no baselines)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="adopt the current artifacts as baselines")
    args = ap.parse_args(argv)

    paths = find_artifacts(args.current)
    baselines_dir = args.baselines or default_baselines_dir()

    if args.smoke:
        failures = smoke_check(paths)
        for f in failures:
            print(f"SMOKE FAIL: {f}")
        if not failures:
            print(f"smoke OK: {len(paths)} artifact(s) schema-valid, all "
                  f"invariants hold")
        return 1 if failures else 0

    if args.update_baselines:
        for dest in update_baselines(paths, baselines_dir):
            print(f"baseline updated: {dest}")
        return 0

    if not paths:
        print("no current BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    all_failures: list[str] = []
    compared = 0
    for path in paths:
        try:
            current = schema.load(path)
        except ValueError as e:
            all_failures.append(str(e))
            continue
        base_file = baseline_path(baselines_dir, current["suite"])
        if not os.path.exists(base_file):
            # Same contract as the stale-baseline gate: a suite with no
            # committed baseline at all must fail with ONE readable line
            # naming the fix, not silently pass its rows.
            all_failures.append(
                f"{current['suite']}: no committed baseline ({base_file}); "
                f"adopt one with `python -m repro.bench.compare "
                f"--update-baselines` after a clean run "
                f"(workflow: docs/BENCHMARKS.md)")
            continue
        baseline = schema.load(base_file)
        failures, report = compare_docs(current, baseline,
                                        threshold=args.threshold,
                                        floor_us=args.floor_us,
                                        row_cap=args.row_cap)
        compared += 1
        print(f"# {current['suite']} vs {base_file}")
        for line in report:
            print(line)
        all_failures.extend(failures)
    for f in all_failures:
        print(f"REGRESSION: {f}")
    if not all_failures:
        print(f"compare OK: {compared} suite(s) within "
              f"{args.threshold:.2f}x of baseline")
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main())
