"""Benchmark engine: ``Case`` definitions and the measurement loop.

This replaces the copy-pasted ``timeit.repeat`` loops of the old
``benchmarks/bench_*.py`` scripts with one engine applying the OMB-style
methodology everywhere:

* **setup / trace / steady-state separation** — ``Case.build(size)`` does
  arbitrary setup (solvers, params) outside the clock; the *first* call of
  the returned thunk is timed separately as ``trace_ms`` (jit trace +
  compile + first run — where the plan cache earns its keep), then
  ``warmup`` discarded calls, then ``repeats`` timed steady-state samples.
* **amortized inner loops** — a thunk may chain ``Case.inner`` operations
  per call (e.g. a ``fori_loop`` of 50 collectives) so per-call dispatch
  cost is amortized; the engine divides samples by ``inner``.
* **robust statistics** — each row carries the full
  :func:`repro.bench.stats.summarize` block; the headline ``value`` is the
  median per-call cost in ``Case.unit``.

Suites (``repro.bench.suites``) build lists of cases; the runner in
:mod:`repro.bench.cli` drives them in a child process with the right
emulated device count and emits the :mod:`repro.bench.schema` artifact.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

from repro.bench import stats as stats_lib
from repro.bench.schema import TIME_UNITS


@dataclasses.dataclass
class BenchConfig:
    """Effective run configuration shared by every case of a suite run.

    Attributes:
        quick: reduced grids/steps for CI and smoke runs (suites decide
            what shrinks; the schema records the flag).
        repeats: timed steady-state samples per (case, size).
        warmup: discarded calls between the trace call and the samples.
        sizes: when set, overrides the size grid of every sweepable case.
        cases: when set, only cases whose name contains one of these
            substrings run.
    """

    quick: bool = False
    repeats: int = 5
    warmup: int = 1
    sizes: tuple[int, ...] | None = None
    cases: tuple[str, ...] | None = None

    def to_dict(self) -> dict:
        """The ``config`` block recorded in the artifact."""
        return {
            "quick": self.quick,
            "repeats": self.repeats,
            "warmup": self.warmup,
            "sizes": list(self.sizes) if self.sizes else None,
            "cases": list(self.cases) if self.cases else None,
        }

    def wants(self, case_name: str) -> bool:
        """Whether the ``cases`` filter admits ``case_name``."""
        if not self.cases:
            return True
        return any(sub in case_name for sub in self.cases)


@dataclasses.dataclass
class Case:
    """One benchmark case: a named, size-swept, self-contained measurement.

    Attributes:
        name: row name (stable across runs — the compare-gate key is
            ``(name, size)``).
        build: ``build(size) -> thunk``; the thunk performs ``inner``
            operations and blocks until they are done.  Setup happens in
            ``build`` (unclocked); the thunk's first call is the traced
            one.
        sizes: the size grid (elements, grid points, steps — case-defined).
        inner: operations per thunk call; samples are divided by it.
        unit: unit of the headline value (``us``/``ms``/``s`` gate-able
            time units, or a reported-only unit).
        nbytes: optional ``size -> payload bytes`` for the row's ``bytes``
            field and bandwidth-style derived values.
        derived: optional ``(size, seconds_per_call) -> dict`` of extra
            reported scalars.
        sweepable: whether a CLI ``--sizes`` override applies to this case.
        size_ok: optional predicate; sizes it rejects are skipped (with a
            note) instead of crashing the suite — e.g. alltoall payloads
            must divide by the rank count, which a ``--sizes`` override
            cannot know.
    """

    name: str
    build: Callable[[int], Callable[[], Any]]
    sizes: tuple[int, ...] = (0,)
    inner: int = 1
    unit: str = "us"
    nbytes: Callable[[int], int] | None = None
    derived: Callable[[int, float], dict] | None = None
    sweepable: bool = False
    size_ok: Callable[[int], bool] | None = None


def _now() -> float:
    return time.perf_counter()


def run_case(case: Case, size: int, cfg: BenchConfig) -> dict:
    """Measure one (case, size) cell and return its artifact row.

    Args:
        case: the case definition.
        size: one entry of the case's size grid.
        cfg: the effective run configuration.
    Returns:
        A schema-valid row dict (name/size/bytes/unit/value/trace_ms/
        stats/derived).
    """
    thunk = case.build(size)

    t0 = _now()
    thunk()                                   # trace + compile + first run
    trace_ms = (_now() - t0) * 1e3

    for _ in range(cfg.warmup):
        thunk()

    samples_s = []
    for _ in range(max(1, cfg.repeats)):
        t0 = _now()
        thunk()
        samples_s.append((_now() - t0) / max(1, case.inner))

    unit_s = TIME_UNITS.get(case.unit, 1.0) * 1e-6
    per_call = [s / unit_s for s in samples_s]
    summary = stats_lib.summarize(per_call)
    sec_med = stats_lib.median(samples_s)

    row = {
        "name": case.name,
        "size": int(size),
        "bytes": int(case.nbytes(size)) if case.nbytes else None,
        "unit": case.unit,
        "value": summary["median"],
        "trace_ms": trace_ms,
        "stats": summary,
        "derived": dict(case.derived(size, sec_med)) if case.derived
                   else None,
    }
    return row


def free_row(name: str, value: float, unit: str = "x", size: int = 0,
             derived: dict | None = None) -> dict:
    """A reported-only row (ratio/counter/one-shot timing) for suite
    ``extras`` hooks.

    The row carries ``"gate": false`` so the compare checker never gates
    it, even when ``unit`` is a time unit (trace-time measurements, sweep
    cells): only steady-state :class:`Case` rows enter the regression
    gate.

    Args:
        name: row name.
        value: the headline scalar.
        unit: a :data:`repro.bench.schema.FREE_UNITS` unit (default
            ratio) or a time unit for reported-only timings.
        size: optional size key (0 when not size-swept).
        derived: optional extra scalars.
    Returns:
        A schema-valid row dict with no stats/trace block.
    """
    return {"name": name, "size": int(size), "bytes": None, "unit": unit,
            "value": float(value), "trace_ms": None, "stats": None,
            "derived": derived, "gate": False}


def effective_sizes(case: Case, cfg: BenchConfig) -> Sequence[int]:
    """The size grid actually run: the CLI override for sweepable cases,
    the case's own grid otherwise."""
    if case.sweepable and cfg.sizes:
        return cfg.sizes
    return case.sizes


def format_row(row: dict) -> str:
    """One human-readable CSV-ish line per row (CLI/stdout rendering)."""
    key = row["name"] if not row["size"] else f"{row['name']}[{row['size']}]"
    parts = [key, f"{row['value']:.4g}", row["unit"]]
    st = row.get("stats")
    if st:
        parts.append(f"min={st['min']:.4g}")
        parts.append(f"iqr={st['iqr']:.3g}")
    if row.get("trace_ms") is not None:
        parts.append(f"trace_ms={row['trace_ms']:.1f}")
    for k, v in (row.get("derived") or {}).items():
        parts.append(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}")
    return ",".join(parts)
