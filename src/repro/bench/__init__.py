"""repro.bench — the unified OMB-style benchmark subsystem.

One engine (:mod:`repro.bench.core`), one schema
(:mod:`repro.bench.schema`), one CLI (``python -m repro.bench``) and one
regression gate (``python -m repro.bench.compare``) replace the six ad-hoc
timing scripts that used to live under ``benchmarks/``.  The methodology —
warmup + repeat control, per-size sweeps, robust statistics, trace vs
steady-state separation — follows OMB-Py (Alnaasan et al. 2021), which the
paper's own per-size send/recv timing loop mirrors.

This package root stays import-light (no jax): suite modules under
:mod:`repro.bench.suites` are only imported in the child process that runs
them with the right emulated device count.  See docs/BENCHMARKS.md.
"""

from repro.bench.core import BenchConfig, Case, free_row, run_case
from repro.bench.schema import SCHEMA, assert_valid, load, make_doc, validate
from repro.bench.stats import iqr, median, min_of_k, quantile, summarize
from repro.bench.suites import SUITES, SuiteSpec

__all__ = [
    "BenchConfig", "Case", "free_row", "run_case",
    "SCHEMA", "assert_valid", "load", "make_doc", "validate",
    "iqr", "median", "min_of_k", "quantile", "summarize",
    "SUITES", "SuiteSpec",
]
