"""Machine-readable benchmark artifact schema (``BENCH_<suite>.json``).

One artifact per suite per run.  The document is versioned and carries an
environment fingerprint (jax version, device count, active policy-table
hash, quick/full mode) so the compare gate can refuse or annotate
apples-to-oranges comparisons, plus per-row robust statistics and the
trace/steady-state split.

Document shape (``SCHEMA`` tag ``repro.bench/v2``; v1 was the bespoke
``benchmarks/run.py`` emitter this module replaces)::

    {
      "schema": "repro.bench/v2",
      "suite": "p2p",
      "env": {"jax": "...", "python": "...", "platform": "cpu",
              "device_count": 2, "policy_hash": "...", "quick": true},
      "config": {"repeats": 5, "warmup": 1, "sizes": null, "cases": null},
      "rows": [
        {"name": "p2p_latency", "size": 1024, "bytes": 4096,
         "unit": "us", "value": 123.4,          # headline = median/call
         "trace_ms": 87.0,                      # first call: trace+compile
         "stats": {"n": 5, "min": ..., "median": ..., "iqr": ...},
         "derived": {"GBps": 0.033}},           # free-form floats
        ...
      ],
      "invariants": {"plan_reuse": true, ...}   # machine-checked booleans
    }

``unit`` is the unit of ``value`` and ``stats``: a time unit (``us``,
``ms``, ``s`` — gated by the compare checker, lower is better) or a
unit-less derived quantity (``x`` for ratios, ``count`` — reported, never
gated).  A row may additionally carry ``"gate": false`` to opt out of the
regression gate even with a time unit (reported-only rows from suite
``extras`` hooks: trace-time measurements, single-shot sweep cells).
Validation is hand-rolled (no jsonschema dependency in the container).
"""

from __future__ import annotations

import hashlib
import json
import os
import sys

SCHEMA = "repro.bench/v2"

#: units the compare gate treats as "time per call, lower is better",
#: with the factor converting a value into microseconds.
TIME_UNITS = {"us": 1.0, "ms": 1e3, "s": 1e6}

#: reported-only units (ratios, counters) — never gated.
FREE_UNITS = ("x", "count", "B")


def policy_hash() -> str:
    """Short stable hash of the active collective policy table.

    Part of the env fingerprint: two artifacts measured under different
    policy tables are not comparing the same lowerings.
    """
    from repro.core import registry
    text = registry.active_policy().to_json()
    return hashlib.sha256(text.encode()).hexdigest()[:12]


def env_fingerprint(quick: bool) -> dict:
    """The environment block of an artifact (imports jax lazily).

    Args:
        quick: whether the run used the reduced quick-mode grids.
    Returns:
        Dict with jax/python versions, backend platform, device count, the
        active policy-table hash, and the jmpi transport backend the run
        was tagged with (``JMPI_BACKEND``, default ``emulated`` — the
        compare gate refuses cross-backend comparisons outright).
    """
    import jax
    return {
        "jax": jax.__version__,
        "python": "%d.%d.%d" % sys.version_info[:3],
        "platform": jax.default_backend(),
        "device_count": len(jax.devices()),
        "policy_hash": policy_hash(),
        "quick": bool(quick),
        "backend": os.environ.get("JMPI_BACKEND", "emulated"),
    }


def make_doc(suite: str, rows: list[dict], invariants: dict,
             config: dict, env: dict | None = None) -> dict:
    """Assemble a schema-valid artifact document.

    Args:
        suite: registered suite name.
        rows: row dicts (see module docstring).
        invariants: machine-checked boolean facts from the suite run.
        config: the effective run configuration (repeats, warmup, ...).
        env: environment block; None computes :func:`env_fingerprint`.
    Returns:
        The artifact dict (validate with :func:`validate`).
    """
    return {
        "schema": SCHEMA,
        "suite": suite,
        "env": env if env is not None else env_fingerprint(
            bool(config.get("quick", False))),
        "config": config,
        "rows": rows,
        "invariants": {k: bool(v) for k, v in invariants.items()},
    }


def _check_row(i: int, row: object, problems: list[str]) -> None:
    if not isinstance(row, dict):
        problems.append(f"rows[{i}]: not an object")
        return
    where = f"rows[{i}]"
    name = row.get("name")
    if not isinstance(name, str) or not name:
        problems.append(f"{where}: missing/empty 'name'")
    else:
        where = f"rows[{i}] ({name})"
    if not isinstance(row.get("size"), int):
        problems.append(f"{where}: 'size' must be an int")
    unit = row.get("unit")
    if unit not in TIME_UNITS and unit not in FREE_UNITS:
        problems.append(f"{where}: unknown unit {unit!r}")
    if not isinstance(row.get("value"), (int, float)):
        problems.append(f"{where}: 'value' must be a number")
    if row.get("bytes") is not None and not isinstance(row["bytes"], int):
        problems.append(f"{where}: 'bytes' must be int or null")
    if "gate" in row and not isinstance(row["gate"], bool):
        problems.append(f"{where}: 'gate' must be a boolean when present")
    if row.get("trace_ms") is not None and \
            not isinstance(row["trace_ms"], (int, float)):
        problems.append(f"{where}: 'trace_ms' must be a number or null")
    stats = row.get("stats")
    if stats is not None:
        if not isinstance(stats, dict):
            problems.append(f"{where}: 'stats' must be an object or null")
        else:
            for key in ("n", "min", "median", "iqr"):
                if not isinstance(stats.get(key), (int, float)):
                    problems.append(f"{where}: stats.{key} missing")
    derived = row.get("derived")
    if derived is not None:
        if not isinstance(derived, dict) or any(
                not isinstance(v, (int, float, str))
                for v in derived.values()):
            problems.append(f"{where}: 'derived' must map to scalars")


def validate(doc: object) -> list[str]:
    """Validate an artifact document against the schema.

    Args:
        doc: the parsed JSON document.
    Returns:
        A list of human-readable problems; empty means schema-valid.
    """
    problems: list[str] = []
    if not isinstance(doc, dict):
        return ["artifact is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema tag {doc.get('schema')!r} != {SCHEMA!r}")
    if not isinstance(doc.get("suite"), str) or not doc.get("suite"):
        problems.append("missing 'suite'")
    env = doc.get("env")
    if not isinstance(env, dict):
        problems.append("missing 'env' block")
    else:
        for key in ("jax", "python", "platform", "device_count",
                    "policy_hash", "quick"):
            if key not in env:
                problems.append(f"env.{key} missing")
    if not isinstance(doc.get("config"), dict):
        problems.append("missing 'config' block")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        problems.append("'rows' must be a list")
    else:
        for i, row in enumerate(rows):
            _check_row(i, row, problems)
    inv = doc.get("invariants")
    if not isinstance(inv, dict) or any(
            not isinstance(v, bool) for v in inv.values()):
        problems.append("'invariants' must map names to booleans")
    return problems


def assert_valid(doc: object, origin: str = "artifact") -> None:
    """Raise ``ValueError`` listing every schema problem of ``doc``."""
    problems = validate(doc)
    if problems:
        raise ValueError(f"{origin} is not schema-valid:\n  "
                         + "\n  ".join(problems))


def dump(doc: dict, path: str) -> None:
    """Validate then write ``doc`` to ``path`` as indented JSON."""
    assert_valid(doc, origin=path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")


def load(path: str) -> dict:
    """Read and validate an artifact from ``path``.

    Raises:
        ValueError: when the file is not schema-valid.
    """
    with open(path) as f:
        doc = json.load(f)
    assert_valid(doc, origin=path)
    return doc
