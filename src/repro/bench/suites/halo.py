"""Halo suite: Cahn–Hilliard strong scaling + halo-exchange lowering sweep.

Paper Fig. 2 (strong scaling): the 2-D Cahn–Hilliard solver at a fixed
grid, decomposed over n ∈ {1, 2, 4, 8} ranks — run in ONE 8-device child
via sub-meshes over the first n emulated devices (``case size`` = rank
count, value = µs/step).

Halo-exchange sweep (the PR-3 measurement, now a registered case set): the
MPI-3 neighborhood-collective lowerings (``xla_native`` ppermute shifts vs
the p2p-fused ``ring``) against the hand-built persistent-``sendrecv_init``
baseline the topology subsystem replaced (``case size`` = grid points per
side).

``extras`` derives the ``halo_neighbor_vs_p2p`` ratio row (best neighbor
lowering over the p2p baseline — the PR-3 result was 0.54x).  The ratio is
reported, not an invariant: wall-clock ratios on a shared CPU runner are a
compare-gate concern (thresholded), not a boolean fact.
"""

from __future__ import annotations

from repro.bench.core import BenchConfig, Case, free_row

SCALING_RANKS = (1, 2, 4, 8)


def _grid_steps(cfg: BenchConfig) -> tuple[int, int]:
    return (64, 10) if cfg.quick else (256, 100)


def _sweep_grid_steps(cfg: BenchConfig) -> tuple[int, int]:
    return (64, 10) if cfg.quick else (128, 50)


def _decomp(n: int) -> tuple[int, int]:
    rows = min(2, n)
    return rows, n // rows


def _scaling_build(steps: int, grid: int):
    def build(n_ranks: int):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import compat
        from repro.pde import cahn_hilliard as ch

        n = min(n_ranks, len(jax.devices()))
        rows, cols = _decomp(n)
        mesh = compat.make_mesh((rows, cols), ("px", "py"))
        rng = np.random.default_rng(0)
        c0 = jnp.asarray(0.5 + 0.01 * rng.standard_normal((grid, grid)),
                         jnp.float32)
        run = ch.make_solver(mesh, (rows, cols), inner_steps=steps)

        # correctness check on the first (trace) call only: a full-grid
        # isfinite reduction + host sync must not pollute the timed
        # steady-state samples
        checked: list[bool] = []

        def thunk():
            out = run(c0)
            out.block_until_ready()
            if not checked:
                assert bool(jnp.isfinite(out).all())
                checked.append(True)
            return out

        return thunk

    return build


def _p2p_exchange_2d(field, cart, h: int = 1):
    """The pre-topology halo exchange: persistent ``sendrecv_init`` plans
    along ``cart_shift_perm`` patterns — the baseline the neighborhood
    collectives are swept against."""
    import jax
    import jax.numpy as jnp
    import repro.core as jmpi

    def ax(d, lo, hi):
        if cart.dims[d] == 1:
            return hi, lo
        dn = cart.sendrecv_init(jax.ShapeDtypeStruct(hi.shape, hi.dtype),
                                pairs=cart.cart_shift_perm(d, +1))
        up = cart.sendrecv_init(jax.ShapeDtypeStruct(lo.shape, lo.dtype),
                                pairs=cart.cart_shift_perm(d, -1))
        return jmpi.wait(dn.start(hi))[1], jmpi.wait(up.start(lo))[1]

    lead, trail = ax(0, field[:h, :], field[-h:, :])
    field = jnp.concatenate([lead, field, trail], axis=0)
    lead, trail = ax(1, field[:, :h], field[:, -h:])
    return jnp.concatenate([lead, field, trail], axis=1)


def _sweep_build(variant: str, steps: int):
    def build(grid: int):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi
        from repro.core import compat
        from repro.pde.stencil import halo_exchange_2d, laplacian

        n_dev = len(jax.devices())
        rows, cols = _decomp(n_dev)
        mesh = compat.make_mesh((rows, cols), ("px", "py"))
        rng = np.random.default_rng(0)
        c0 = jnp.asarray(0.5 + 0.01 * rng.standard_normal((grid, grid)),
                         jnp.float32)

        if variant == "p2p_baseline":
            exchange = _p2p_exchange_2d
        else:
            exchange = lambda f, cart: halo_exchange_2d(  # noqa: E731
                f, cart, algorithm=variant)

        @jmpi.spmd(mesh, in_specs=P("px", "py"), out_specs=P("px", "py"))
        def run(c):
            cart = jmpi.world().cart_create((rows, cols),
                                            periods=(True, True))

            def body(i, f):
                fh = exchange(f, cart)
                return f + 1e-3 * laplacian(fh)

            return jax.lax.fori_loop(0, steps, body, c)

        checked: list[bool] = []

        def thunk():
            out = run(c0)
            out.block_until_ready()
            if not checked:
                assert bool(jnp.isfinite(out).all()), variant
                checked.append(True)
            return out

        return thunk

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """Build the scaling + sweep cases for ``cfg``."""
    grid, steps = _grid_steps(cfg)
    sweep_grid, sweep_steps = _sweep_grid_steps(cfg)
    ranks = (1, 8) if cfg.quick else SCALING_RANKS
    cases = [
        Case(name="cahn_hilliard", build=_scaling_build(steps, grid),
             sizes=ranks, inner=steps, unit="us"),
    ]
    for variant in ("xla_native", "ring", "p2p_baseline"):
        cases.append(Case(
            name=f"halo_{variant}", build=_sweep_build(variant, sweep_steps),
            sizes=(sweep_grid,), inner=sweep_steps, unit="us"))
    return cases


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Derive the neighbor-vs-p2p ratio row."""
    by_name = {r["name"]: r["value"] for r in rows}
    extra: list[dict] = []
    if "halo_p2p_baseline" in by_name and "halo_xla_native" in by_name:
        best = min(by_name["halo_xla_native"],
                   by_name.get("halo_ring", float("inf")))
        ratio = best / by_name["halo_p2p_baseline"]
        extra.append(free_row("halo_neighbor_vs_p2p", ratio,
                              derived={"best_us": best}))
    return extra, {}
