"""Collective microbenchmark suite (8 ranks).

Per op × payload size, µs/call of the JIT-resident collective (the whole
chained loop is ONE compiled program, amortizing dispatch):

* blocking ops — ``allreduce``, ``ring_allreduce``, ``allgather``,
  ``alltoall``, ``bcast``, ``compressed8`` (the int8-wire allreduce);
* nonblocking — ``iallreduce`` completed through the unified ``wait``;
* persistent — a frozen ``allreduce_init`` plan restarted per step, next
  to the ad-hoc chain it replaces (same lowering, same HLO);
* neighborhood — ``neighbor_alltoall`` on a periodic 2×4 Cartesian grid;
* v-variants — ``allgatherv``/``alltoallv`` with ragged static counts
  (padded-buffer SPMD form, ISSUE 5).

``extras`` adds the plan-cache reuse measurement (trace-time of the ad-hoc
vs plan program, cache hit/miss counters → the ``plan_reuse`` invariant)
and a mini algorithm sweep driving the tuner's policy derivation (the
``policy_derived`` invariant) — the two facts the CI smoke gate checks via
``repro.bench.compare --smoke`` instead of grepping stdout.
"""

from __future__ import annotations

import timeit

from repro.bench.core import BenchConfig, Case, free_row

FULL_SIZES = (1024, 65536, 1048576)
QUICK_SIZES = (1024, 65536)
OPS = ("allreduce", "ring_allreduce", "allgather", "alltoall", "bcast",
       "compressed8", "iallreduce")
PLAN_CHAIN = 24


def _inner(cfg: BenchConfig) -> int:
    return 10 if cfg.quick else 50


def _mesh1d():
    import jax
    from repro.core import compat
    return compat.make_mesh((len(jax.devices()),), ("ranks",))


def _op_build(op: str, inner: int):
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh1d()

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            def body(i, acc):
                if op == "allreduce":
                    _, y = jmpi.allreduce(acc)
                elif op == "ring_allreduce":
                    _, y = jmpi.ring_allreduce(acc)
                elif op == "allgather":
                    _, g = jmpi.allgather(acc)
                    y = g.reshape(jmpi.size(), -1).sum(0)
                elif op == "alltoall":
                    _, y = jmpi.alltoall(acc)
                elif op == "bcast":
                    _, y = jmpi.bcast(acc, root=0)
                elif op == "compressed8":
                    st = jmpi.init_state(acc)
                    _, y, _ = jmpi.compressed_allreduce(acc, st, bits=8)
                elif op == "iallreduce":
                    _, y = jmpi.wait(jmpi.iallreduce(acc))
                else:
                    raise ValueError(op)
                return y / jnp.maximum(jnp.abs(y).max(), 1.0)

            return jax.lax.fori_loop(0, inner, body, x)

        x = jnp.ones((size,), jnp.float32)
        return lambda: f(x).block_until_ready()

    return build


def _persistent_build(adhoc: bool, chain: int):
    """K chained allreduces per call: per-call registry dispatch (ad-hoc)
    vs one frozen plan restarted K times (persistent)."""
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh1d()
        n = mesh.devices.size

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            acc = x
            if adhoc:
                for _ in range(chain):
                    _, acc = jmpi.allreduce(acc)
                    acc = acc / n
            else:
                comm = jmpi.world()
                plan = comm.allreduce_init(
                    jax.ShapeDtypeStruct(x.shape, x.dtype))
                for _ in range(chain):
                    acc = jmpi.wait(plan.start(acc))[1] / n
            return acc

        x = jnp.ones((size,), jnp.float32)
        return lambda: f(x).block_until_ready()

    return build


def _vvariant_build(op: str, inner: int):
    """Ragged v-variant cases (ISSUE 5): allgatherv over per-rank counts
    alternating c and 2c, alltoallv over a (s+d)-parity counts matrix —
    the padded-buffer SPMD form, chained ``inner`` times per call."""
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh1d()
        n = mesh.devices.size
        c = max(size // (2 * n), 1)

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            comm = jmpi.world()
            if op == "allgatherv":
                counts = tuple(c * ((r % 2) + 1) for r in range(n))
                maxc = max(counts)

                def body(i, acc):
                    _, full = comm.allgatherv(acc, counts)
                    return full[:maxc] / jnp.maximum(
                        jnp.abs(full).max(), 1.0)

                return jax.lax.fori_loop(0, inner, body, x)
            counts = tuple(tuple(c * (((s + d) % 2) + 1) for d in range(n))
                           for s in range(n))

            def body(i, acc):
                _, out = comm.alltoallv(acc, counts)
                return out / jnp.maximum(jnp.abs(out).max(), 1.0) + acc * 0

            return jax.lax.fori_loop(0, inner, body, x)

        if op == "allgatherv":
            x = jnp.ones((2 * c,), jnp.float32)
        else:
            x = jnp.ones((n, 2 * c), jnp.float32)
        return lambda: f(x).block_until_ready()

    return build


def _neighbor_build(inner: int):
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi
        from repro.core import compat

        n_dev = len(jax.devices())
        rows = min(2, n_dev)
        mesh = compat.make_mesh((rows, n_dev // rows), ("px", "py"))

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            cart = jmpi.world().cart_create(mesh.devices.shape,
                                            periods=(True, True))

            def body(i, acc):
                _, out = cart.neighbor_alltoall(acc)
                return out / jnp.maximum(jnp.abs(out).max(), 1.0) + acc * 0

            return jax.lax.fori_loop(0, inner, body, x)

        x = jnp.ones((4, size), jnp.float32)  # 2·ndims stacked slots
        return lambda: f(x).block_until_ready()

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """Build the collective cases for ``cfg``."""
    sizes = QUICK_SIZES if cfg.quick else FULL_SIZES
    inner = _inner(cfg)
    nbytes = lambda size: size * 4  # noqa: E731 - float32 payload

    def gbps(op: str):
        def derived(size, sec, _op=op):
            import jax
            n = len(jax.devices())
            wire = size * 4 * (2 * (n - 1) / n if "allreduce" in _op else 1)
            return {"eff_GBps": wire / sec / 1e9}
        return derived

    def divisible(size: int) -> bool:
        import jax
        return size % len(jax.devices()) == 0

    cases = [
        Case(name=f"coll_{op}", build=_op_build(op, inner), sizes=sizes,
             inner=inner, unit="us", nbytes=nbytes, derived=gbps(op),
             sweepable=True,
             size_ok=divisible if op == "alltoall" else None)
        for op in OPS
    ]
    chain = 8 if cfg.quick else PLAN_CHAIN
    cases += [
        Case(name="coll_allreduce_adhoc_chain",
             build=_persistent_build(adhoc=True, chain=chain),
             sizes=(65536,), inner=chain, unit="us", nbytes=nbytes),
        Case(name="coll_allreduce_persistent",
             build=_persistent_build(adhoc=False, chain=chain),
             sizes=(65536,), inner=chain, unit="us", nbytes=nbytes),
        Case(name="coll_neighbor_alltoall", build=_neighbor_build(inner),
             sizes=QUICK_SIZES if cfg.quick else (1024, 65536, 262144),
             inner=inner, unit="us", nbytes=lambda s: 4 * s * 4,
             sweepable=True),
        # v-variant ragged collectives (ISSUE 5): per-rank wire volume is
        # ~1.5·size/n rows average (counts alternate c and 2c)
        Case(name="coll_allgatherv", build=_vvariant_build("allgatherv",
                                                           inner),
             sizes=QUICK_SIZES if cfg.quick else (1024, 65536, 262144),
             inner=inner, unit="us", nbytes=lambda s: s * 3,
             sweepable=True),
        Case(name="coll_alltoallv", build=_vvariant_build("alltoallv",
                                                          inner),
             sizes=QUICK_SIZES if cfg.quick else (1024, 65536, 262144),
             inner=inner, unit="us", nbytes=lambda s: s * 3,
             sweepable=True),
    ]
    return cases


def _plan_reuse_rows(cfg: BenchConfig) -> tuple[list[dict], bool]:
    """Trace-time + plan-cache measurement backing the ``plan_reuse``
    invariant: the second trace of the plan program must serve its
    ``allreduce_init`` from the cache (no new misses, new hits)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro.core as jmpi
    from repro.core import compat

    chain = 8 if cfg.quick else PLAN_CHAIN
    size = 65536
    x = jnp.ones((size,), jnp.float32)

    mesh = compat.make_mesh((len(jax.devices()),), ("ranks",))
    n = mesh.devices.size

    def adhoc_fn():
        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            acc = x
            for _ in range(chain):
                _, acc = jmpi.allreduce(acc)
                acc = acc / n
            return acc
        return f

    def plan_fn():
        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            comm = jmpi.world()
            plan = comm.allreduce_init(
                jax.ShapeDtypeStruct(x.shape, x.dtype))
            acc = x
            for _ in range(chain):
                acc = jmpi.wait(plan.start(acc))[1] / n
            return acc
        return f

    def lower_ms(build):
        t0 = timeit.default_timer()
        build().lower(x)
        return (timeit.default_timer() - t0) * 1e3

    jmpi.plan_cache_clear()
    adhoc_t1, adhoc_t2 = lower_ms(adhoc_fn), lower_ms(adhoc_fn)
    s0 = jmpi.plan_cache_stats()
    plan_t1 = lower_ms(plan_fn)
    s1 = jmpi.plan_cache_stats()
    plan_t2 = lower_ms(plan_fn)           # second trace: *_init cache hit
    s2 = jmpi.plan_cache_stats()

    reuse_ok = s2["misses"] == s1["misses"] and s2["hits"] > s1["hits"]
    rows = [
        free_row("persistent_adhoc_trace_ms", adhoc_t1, unit="ms",
                 size=size, derived={"second_ms": adhoc_t2,
                                     "chain": float(chain)}),
        free_row("persistent_plan_trace_ms", plan_t1, unit="ms",
                 size=size, derived={"second_ms": plan_t2,
                                     "chain": float(chain)}),
        free_row("persistent_plan_cache_hits", s2["hits"], unit="count",
                 size=size,
                 derived={"misses": float(s2["misses"]),
                          "first_trace_misses":
                              float(s1["misses"] - s0["misses"]),
                          "second_trace_hits":
                              float(s2["hits"] - s1["hits"])}),
    ]
    return rows, reuse_ok


def _policy_sweep_rows(cfg: BenchConfig) -> tuple[list[dict], bool]:
    """Mini algorithm sweep → derived policy table (``policy_derived``)."""
    from repro.core import registry
    from repro.launch import collective_tuner

    mesh = collective_tuner.tune_mesh()
    sizes = (4096,) if cfg.quick else (1024, 65536)
    records = collective_tuner.sweep(
        mesh, sizes=sizes, ops=("allreduce",),
        inner=5 if cfg.quick else 20)
    rows = [
        free_row(f"sweep_allreduce_{r['algorithm']}", r["us_per_call"],
                 unit="us", size=r["numel"])
        for r in records
    ]
    table = collective_tuner.build_policy(records)
    derived_ok = isinstance(table, registry.PolicyTable) and \
        bool(table.describe().strip())
    return rows, derived_ok


def extras(cfg: BenchConfig, rows: list[dict]
           ) -> tuple[list[dict], dict]:
    """Post-case hook: plan-cache reuse + policy derivation invariants."""
    extra_rows: list[dict] = []
    invariants: dict = {}
    if cfg.wants("persistent"):
        reuse_rows, reuse_ok = _plan_reuse_rows(cfg)
        extra_rows += reuse_rows
        invariants["plan_reuse"] = reuse_ok
    if cfg.wants("sweep"):
        sweep_rows, derived_ok = _policy_sweep_rows(cfg)
        extra_rows += sweep_rows
        invariants["policy_derived"] = derived_ok
    return extra_rows, invariants
