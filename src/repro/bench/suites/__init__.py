"""Registered benchmark suites (name → module, device count).

This table is intentionally import-light: suite modules import jax and the
model/PDE stacks, so they are only imported inside the child process that
runs them (`repro.bench.cli` spawns one child per suite with
``--xla_force_host_platform_device_count`` pinned to ``n_devices``).

A suite module provides::

    def build(cfg: BenchConfig) -> list[Case]          # required
    def extras(cfg, rows) -> (extra_rows, invariants)  # optional

``extras`` runs after every case, sees the measured rows, and returns
free-form reported rows (speedup ratios, cache counters) plus the
machine-checked boolean ``invariants`` that ``repro.bench.compare --smoke``
gates on.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SuiteSpec:
    """Registry entry for one suite.

    Attributes:
        name: CLI name (``--suite name``) and artifact key
            (``BENCH_<name>.json``).
        module: import path of the suite module (child-process only).
        n_devices: emulated device count the suite runs under.
        description: one-line summary for ``--list``.
    """

    name: str
    module: str
    n_devices: int
    description: str


_ALL = [
    SuiteSpec("p2p", "repro.bench.suites.p2p", 2,
              "OMB-style point-to-point latency + windowed bandwidth sweep "
              "(paper Listing 5 pattern, 2 ranks)"),
    SuiteSpec("collectives", "repro.bench.suites.collectives", 8,
              "collective microbenchmarks: blocking, nonblocking, "
              "persistent plans, neighborhood (8 ranks)"),
    SuiteSpec("halo", "repro.bench.suites.halo", 8,
              "Cahn-Hilliard strong scaling (paper Fig. 2) + halo-exchange "
              "lowering sweep"),
    SuiteSpec("mpdata", "repro.bench.suites.mpdata", 8,
              "MPDATA decomposition layouts (paper Fig. 3)"),
    SuiteSpec("pi", "repro.bench.suites.pi", 4,
              "pi benchmark: JIT speedup + JIT-resident vs round-trip "
              "communication (paper Listings 1-4 / Fig. 1)"),
    SuiteSpec("trainer", "repro.bench.suites.trainer", 8,
              "trainer comm backends: jmpi / int8-compressed / round-trip "
              "/ hostbridge (ms per step)"),
    SuiteSpec("kernels", "repro.bench.suites.kernels", 1,
              "kernel-structure twins: blockwise attention, chunked SSD "
              "(single device)"),
    SuiteSpec("serve", "repro.bench.suites.serve", 1,
              "serving engines: continuous batching + paged KV cache vs "
              "padded fixed batch (tokens/s, p50/p99 latency)"),
]

SUITES: dict[str, SuiteSpec] = {s.name: s for s in _ALL}


def resolve(names: str | None) -> list[SuiteSpec]:
    """Resolve a CLI ``--suite`` value to specs.

    Args:
        names: comma-separated suite names, ``"all"``, or None (= all).
    Returns:
        The matching specs in registry order.
    Raises:
        SystemExit: naming an unknown suite (message lists known ones).
    """
    if names in (None, "", "all"):
        return list(_ALL)
    specs = []
    for name in names.split(","):
        name = name.strip()
        if name not in SUITES:
            raise SystemExit(
                f"unknown suite {name!r}; known: {', '.join(SUITES)}")
        specs.append(SUITES[name])
    return specs
