"""Trainer comm-backend suite (the paper's claim at trainer scale, 8 DP
ranks).

Same tiny LM, same data:

* ``trainer_jmpi`` — whole train step (fwd/bwd + in-program gradient
  allreduce + optimizer) in ONE compiled block;
* ``trainer_jmpi_int8`` — ditto with the compressed gradient allreduce;
* ``trainer_roundtrip`` — the SAME in-program psum reduce, but the step
  split into two dispatches with a host sync between them (mechanism held
  fixed → isolates the leave-the-compiled-block cost);
* ``trainer_hostbridge`` — per-rank grads to host, numpy reduction,
  re-upload (the full mpi4py pattern).

Rows are ms/step (``case size`` = sequence length); ``extras`` emits the
speedup-vs-roundtrip ratios.
"""

from __future__ import annotations

from repro.bench.core import BenchConfig, Case, free_row


def _seq(cfg: BenchConfig) -> int:
    return 32 if cfg.quick else 64


def _setup(cfg: BenchConfig, seq: int):
    import jax
    from repro.core import compat
    from repro.configs import get_tiny
    from repro.launch.specs import synth_batch

    model_cfg = get_tiny("yi-6b")
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    n = mesh.devices.size
    batch = synth_batch(model_cfg, batch=(4 if cfg.quick else 8) * n,
                        seq=seq, kind="train")
    return model_cfg, mesh, batch


def _jmpi_build(cfg: BenchConfig, bits: int):
    def build(seq: int):
        import jax
        import repro.core as jmpi
        from repro.configs.base import RunConfig
        from repro.models import lm as lm_lib
        from repro.train import optim
        from repro.train.trainer import build_jmpi_train_step

        model_cfg, mesh, batch = _setup(cfg, seq)
        rc = RunConfig(learning_rate=1e-3, grad_compression_bits=bits)
        params = lm_lib.init_params(model_cfg, jax.random.PRNGKey(0))
        opt = optim.init(params, rc)
        comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
        step = build_jmpi_train_step(model_cfg, rc, mesh, None)

        def thunk():
            _p, _o, _c, loss = step(params, opt, comp, batch)
            loss.block_until_ready()

        return thunk

    return build


def _split_builds(cfg: BenchConfig):
    """Build the roundtrip and hostbridge thunk factories (they share the
    grad/apply jit fragments)."""

    def make(kind: str):
        def build(seq: int):
            import jax
            import jax.numpy as jnp
            import numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core import compat
            from repro.configs.base import RunConfig
            from repro.models import lm as lm_lib
            from repro.train import optim

            model_cfg, mesh, batch = _setup(cfg, seq)
            rc = RunConfig(learning_rate=1e-3)
            params = lm_lib.init_params(model_cfg, jax.random.PRNGKey(0))
            opt = optim.init(params, rc)
            apply_fn = jax.jit(lambda p, g, o: optim.update(p, g, o, rc))

            if kind == "roundtrip":
                grad_fn = jax.jit(compat.shard_map(
                    lambda p, b: jax.tree.map(
                        lambda g: jax.lax.pmean(g, "data"),
                        jax.grad(lambda pp: lm_lib.train_loss(
                            pp, model_cfg, b)[0])(p)),
                    mesh=mesh, in_specs=(P(), P("data")), out_specs=P(),
                    check_vma=False))

                def thunk():
                    g = grad_fn(params, batch)
                    jax.block_until_ready(g)   # leave the compiled block
                    out = apply_fn(params, g, opt)
                    jax.block_until_ready(out)
            else:
                grad_fn = jax.jit(compat.shard_map(
                    lambda p, b: jax.tree.map(
                        lambda g: g[None],
                        jax.grad(lambda pp: lm_lib.train_loss(
                            pp, model_cfg, b)[0])(p)),
                    mesh=mesh, in_specs=(P(), P("data")),
                    out_specs=P("data"), check_vma=False))

                def thunk():
                    gstack = grad_fn(params, batch)
                    jax.block_until_ready(gstack)
                    gmean = jax.tree.map(
                        lambda g: jnp.asarray(np.asarray(g).mean(0)),
                        gstack)
                    out = apply_fn(params, gmean, opt)
                    jax.block_until_ready(out)

            return thunk

        return build

    return make("roundtrip"), make("hostbridge")


def build(cfg: BenchConfig) -> list[Case]:
    """Build the trainer-backend cases for ``cfg``."""
    seq = _seq(cfg)
    roundtrip, hostbridge = _split_builds(cfg)
    return [
        Case(name="trainer_jmpi", build=_jmpi_build(cfg, bits=0),
             sizes=(seq,), unit="ms"),
        Case(name="trainer_jmpi_int8", build=_jmpi_build(cfg, bits=8),
             sizes=(seq,), unit="ms"),
        Case(name="trainer_roundtrip", build=roundtrip, sizes=(seq,),
             unit="ms"),
        Case(name="trainer_hostbridge", build=hostbridge, sizes=(seq,),
             unit="ms"),
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Speedup-vs-roundtrip ratio rows."""
    seq = _seq(cfg)
    by_name = {r["name"]: r["value"] for r in rows if r["size"] == seq}
    extra: list[dict] = []
    base = by_name.get("trainer_roundtrip")
    if base:
        for name in ("trainer_jmpi", "trainer_jmpi_int8",
                     "trainer_hostbridge"):
            if by_name.get(name):
                extra.append(free_row(f"{name}_speedup_vs_roundtrip",
                                      base / by_name[name], size=seq))
    return extra, {}
