"""Trainer comm-backend suite (the paper's claim at trainer scale, 8 DP
ranks).

Same tiny LM, same data:

* ``trainer_jmpi`` — whole train step (fwd/bwd + in-program gradient
  allreduce + optimizer) in ONE compiled block;
* ``trainer_jmpi_int8`` — ditto with the compressed gradient allreduce;
* ``trainer_roundtrip`` — the SAME in-program psum reduce, but the step
  split into two dispatches with a host sync between them (mechanism held
  fixed → isolates the leave-the-compiled-block cost);
* ``trainer_hostbridge`` — per-rank grads to host, numpy reduction,
  re-upload (the full mpi4py pattern).

Compressed/overlapped gradient sync (ISSUE 8) — the bucketed
``distributed.overlap.bucketed_grad_sync`` path measured over the REAL
wire (one persistent 8-rank socket job driven like the p2p suite's
multiproc rows, ``_bench_worker``'s ``gradsync`` op), because that is
where the compressed formats' byte win is literal — on the emulated mesh
the int8 two-phase schedule only adds work:

* ``trainer_sync_fp32``          — serial fp32 bucketed sync (baseline);
* ``trainer_sync_int8``          — serial ``int8_ef`` bucketed sync;
* ``trainer_compressed_overlap`` — issue-all-then-waitall ``int8_ef``;
* ``trainer_wire_bytes``         — measured int8/fp32 transmitted payload
  ratio from the endpoint spy (gate-free row; ~0.25), plus the topk twin.

Invariants (``compare --smoke`` gates these): ``compressed_not_slower_
than_fp32`` (the overlapped compressed sync must beat the fp32 serial
baseline — the PR's headline step-time claim) and ``overlap_not_slower_
than_serial`` (overlap may not cost more than 1.35× serial — on the
eager wire backend both orders do identical work, so this bounds noise).
Both are median claims, so they are only emitted when every sync row
carries >= 3 samples (the CI gate's repeats=5 qualifies; a repeats=1
smoke run records the timing rows without gating them).

Rows are ms/step (``case size`` = sequence length for the train-step
rows, gradient float count for the sync rows); ``extras`` emits the
speedup-vs-roundtrip ratios and the wire-byte rows.
"""

from __future__ import annotations

import json

from repro.bench.core import BenchConfig, Case, free_row

_SYNC_NPROCS = 8
_SYNC_BUCKETS = 4
_SYNC_INNER = 2


def _seq(cfg: BenchConfig) -> int:
    return 32 if cfg.quick else 64


def _setup(cfg: BenchConfig, seq: int):
    import jax
    from repro.core import compat
    from repro.configs import get_tiny
    from repro.launch.specs import synth_batch

    model_cfg = get_tiny("yi-6b")
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    n = mesh.devices.size
    batch = synth_batch(model_cfg, batch=(4 if cfg.quick else 8) * n,
                        seq=seq, kind="train")
    return model_cfg, mesh, batch


def _jmpi_build(cfg: BenchConfig, bits: int):
    def build(seq: int):
        import jax
        import repro.core as jmpi
        from repro.configs.base import RunConfig
        from repro.models import lm as lm_lib
        from repro.train import optim
        from repro.train.trainer import build_jmpi_train_step

        model_cfg, mesh, batch = _setup(cfg, seq)
        rc = RunConfig(learning_rate=1e-3, grad_compression_bits=bits)
        params = lm_lib.init_params(model_cfg, jax.random.PRNGKey(0))
        opt = optim.init(params, rc)
        comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
        step = build_jmpi_train_step(model_cfg, rc, mesh, None)

        def thunk():
            _p, _o, _c, loss = step(params, opt, comp, batch)
            loss.block_until_ready()

        return thunk

    return build


def _split_builds(cfg: BenchConfig):
    """Build the roundtrip and hostbridge thunk factories (they share the
    grad/apply jit fragments)."""

    def make(kind: str):
        def build(seq: int):
            import jax
            import jax.numpy as jnp
            import numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core import compat
            from repro.configs.base import RunConfig
            from repro.models import lm as lm_lib
            from repro.train import optim

            model_cfg, mesh, batch = _setup(cfg, seq)
            rc = RunConfig(learning_rate=1e-3)
            params = lm_lib.init_params(model_cfg, jax.random.PRNGKey(0))
            opt = optim.init(params, rc)
            apply_fn = jax.jit(lambda p, g, o: optim.update(p, g, o, rc))

            if kind == "roundtrip":
                grad_fn = jax.jit(compat.shard_map(
                    lambda p, b: jax.tree.map(
                        lambda g: jax.lax.pmean(g, "data"),
                        jax.grad(lambda pp: lm_lib.train_loss(
                            pp, model_cfg, b)[0])(p)),
                    mesh=mesh, in_specs=(P(), P("data")), out_specs=P(),
                    check_vma=False))

                def thunk():
                    g = grad_fn(params, batch)
                    jax.block_until_ready(g)   # leave the compiled block
                    out = apply_fn(params, g, opt)
                    jax.block_until_ready(out)
            else:
                grad_fn = jax.jit(compat.shard_map(
                    lambda p, b: jax.tree.map(
                        lambda g: g[None],
                        jax.grad(lambda pp: lm_lib.train_loss(
                            pp, model_cfg, b)[0])(p)),
                    mesh=mesh, in_specs=(P(), P("data")),
                    out_specs=P("data"), check_vma=False))

                def thunk():
                    gstack = grad_fn(params, batch)
                    jax.block_until_ready(gstack)
                    gmean = jax.tree.map(
                        lambda g: jnp.asarray(np.asarray(g).mean(0)),
                        gstack)
                    out = apply_fn(params, gmean, opt)
                    jax.block_until_ready(out)

            return thunk

        return build

    return make("roundtrip"), make("hostbridge")


def _sync_total(cfg: BenchConfig) -> int:
    """Per-rank gradient float count for the wire-sync rows."""
    return (1 << 19) if cfg.quick else (1 << 21)


_SYNC_JOB = None


def _sync_job():
    """The lazily-started persistent 8-rank socket job shared by every
    sync row (and the wire-byte measurement); restarted if a prior cell's
    failure killed it, reaped by the launcher's atexit hook."""
    global _SYNC_JOB
    if _SYNC_JOB is None or _SYNC_JOB.procs[0].poll() is not None:
        from repro.transport import launch
        _SYNC_JOB = launch(_SYNC_NPROCS,
                           "repro.transport.testing:_bench_worker",
                           transport="sock", interactive=True, timeout=900)
    return _SYNC_JOB


def _sync_cmd(cmd: dict) -> dict:
    job = _sync_job()
    job.command(cmd)
    reply = job.read_line()
    if not reply.startswith("DONE "):
        raise RuntimeError(f"gradsync worker replied {reply!r}")
    return json.loads(reply[len("DONE "):])


def _gradsync_build(algorithm: str, overlap: bool):
    def build(total: int):
        _sync_job()  # spawn + rendezvous outside the clock
        cmd = {"op": "gradsync", "total": total, "algorithm": algorithm,
               "buckets": _SYNC_BUCKETS, "overlap": overlap,
               "inner": _SYNC_INNER}

        def thunk():
            _sync_cmd(cmd)

        return thunk

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """Build the trainer-backend cases for ``cfg``."""
    seq = _seq(cfg)
    total = _sync_total(cfg)
    roundtrip, hostbridge = _split_builds(cfg)
    return [
        Case(name="trainer_jmpi", build=_jmpi_build(cfg, bits=0),
             sizes=(seq,), unit="ms"),
        Case(name="trainer_jmpi_int8", build=_jmpi_build(cfg, bits=8),
             sizes=(seq,), unit="ms"),
        Case(name="trainer_roundtrip", build=roundtrip, sizes=(seq,),
             unit="ms"),
        Case(name="trainer_hostbridge", build=hostbridge, sizes=(seq,),
             unit="ms"),
        Case(name="trainer_sync_fp32", build=_gradsync_build("", False),
             sizes=(total,), inner=_SYNC_INNER, unit="ms",
             nbytes=lambda t: t * 4),
        Case(name="trainer_sync_int8", build=_gradsync_build("int8_ef",
                                                             False),
             sizes=(total,), inner=_SYNC_INNER, unit="ms",
             nbytes=lambda t: t * 4),
        Case(name="trainer_compressed_overlap",
             build=_gradsync_build("int8_ef", True),
             sizes=(total,), inner=_SYNC_INNER, unit="ms",
             nbytes=lambda t: t * 4),
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Speedup-vs-roundtrip ratios, measured wire-byte rows, and the
    compressed-sync invariants."""
    seq = _seq(cfg)
    total = _sync_total(cfg)
    by_name = {r["name"]: r["value"] for r in rows if r["size"] == seq}
    extra: list[dict] = []
    base = by_name.get("trainer_roundtrip")
    if base:
        for name in ("trainer_jmpi", "trainer_jmpi_int8",
                     "trainer_hostbridge"):
            if by_name.get(name):
                extra.append(free_row(f"{name}_speedup_vs_roundtrip",
                                      base / by_name[name], size=seq))

    sync_rows = {r["name"]: r for r in rows if r["size"] == total}
    sync = {k: r["value"] for k, r in sync_rows.items()}
    fp32 = sync.get("trainer_sync_fp32")
    int8 = sync.get("trainer_sync_int8")
    over = sync.get("trainer_compressed_overlap")
    # The sync invariants are claims about steady-state MEDIANS over a
    # noisy eager wire (single samples at this size swing ±50% under
    # load) — only gate them when every row has enough samples for a
    # meaningful median.  The CI perf-gate runs repeats=5; the in-tree
    # repeats=1 smoke run only validates the artifact.
    stable = all(
        (r.get("stats") or {}).get("n", 0) >= 3
        for r in sync_rows.values())
    invariants: dict[str, bool] = {}
    if fp32 and over:
        if stable:
            invariants["compressed_not_slower_than_fp32"] = over <= fp32
        extra.append(free_row("trainer_compressed_speedup_vs_fp32",
                              fp32 / over, size=total))
    if int8 and over and stable:
        invariants["overlap_not_slower_than_serial"] = over <= 1.35 * int8
    try:
        wb = _sync_cmd({"op": "wire_bytes", "total": total})
        extra.append(free_row("trainer_wire_bytes",
                              wb["int8"] / wb["fp32"], size=total))
        extra.append(free_row("trainer_wire_bytes_topk",
                              wb["topk"] / wb["fp32"], size=total))
    except Exception:
        pass  # wire-byte spy is reporting-only; timing rows already gated
    return extra, invariants
