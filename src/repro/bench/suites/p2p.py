"""OMB-style point-to-point suite (2 ranks): latency + windowed bandwidth.

Reproduces the paper's per-size send/recv timing-loop format (its Listing-5
exchange pattern, the OMB-Py ``osu_latency``/``osu_bw`` pair) on the
JIT-resident transport:

* ``p2p_latency`` — a tagged two-rank exchange (``sendrecv`` with pairs
  ``0↔1``) chained ``INNER`` times inside ONE compiled program; the row
  value is µs per exchange (both directions in flight, the SPMD analogue
  of a ping-pong round).
* ``p2p_bandwidth`` — OMB window pattern: ``WINDOW`` nonblocking exchanges
  issued back-to-back, completed with one ``waitall``, per inner step;
  derived column reports the effective per-direction GB/s.
* ``p2p_noncontig_vector`` / ``p2p_noncontig_subarray`` — the paper's
  §2.3 non-contiguous-view comparison: the same exchange with the payload
  described by a derived datatype (strided columns / interior block of a
  halo-padded tile), packed on send and scattered on receive through
  ``recv_into`` — against the contiguous ``p2p_latency`` row these
  measure the pack/unpack prologue XLA fuses into the transfer.
* ``p2p_multiproc_latency`` / ``p2p_multiproc_bw`` — the same ping-pong
  and window patterns executed by TWO REAL PROCESSES over the socket
  transport (the multiproc backend's ``direct`` lowerings), driven
  through one persistent interactive worker job shared by every cell.
  Against the emulated rows these measure what the paper's §Performance
  comparison measures: wire + serialization cost vs. compiled
  intra-process movement.
* ``p2p_multiproc_persistent_latency`` / ``p2p_multiproc_persistent_bw``
  — the same two patterns through cached ``sendrecv_init`` plans on the
  SHM transport: channel negotiation happens once per size outside the
  clock, steady state runs the zero-copy persistent-channel fast path
  (no header parse, no meta, no allocation).  The eager-vs-persistent
  contrast is the repo's analogue of the paper's eager-pickle vs
  compiled-transfer gap; ``extras`` gates it with the
  ``persistent_faster_than_eager`` invariant.

Sizes are float32 element counts; ``bytes`` records the per-message
payload.  All cases honor a CLI ``--sizes`` override (the noncontig
cases skip non-square sizes — their tiles are ``side × side``).
"""

from __future__ import annotations

import math

from repro.bench.core import BenchConfig, Case

FULL_SIZES = (256, 4096, 65536, 262144, 1048576)
QUICK_SIZES = (1024, 65536)
WINDOW = 8


def _inner(cfg: BenchConfig) -> int:
    return 10 if cfg.quick else 40


def _mesh():
    import jax
    from repro.core import compat
    return compat.make_mesh((min(2, len(jax.devices())),), ("ranks",))


def _latency_build(inner: int):
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh()

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            def body(i, acc):
                _, y = jmpi.sendrecv(acc, pairs=[(0, 1), (1, 0)], tag=5)
                return y

            return jax.lax.fori_loop(0, inner, body, x)

        x = jnp.ones((size,), jnp.float32)
        return lambda: f(x).block_until_ready()

    return build


def _bandwidth_build(inner: int, window: int):
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh()

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            def body(i, acc):
                reqs = [jmpi.isendrecv(acc, pairs=[(0, 1), (1, 0)], tag=j)
                        for j in range(window)]
                _, outs = jmpi.waitall(reqs)
                return outs[-1]

            return jax.lax.fori_loop(0, inner, body, x)

        x = jnp.ones((size,), jnp.float32)
        return lambda: f(x).block_until_ready()

    return build


def _noncontig_build(kind: str, inner: int):
    def build(size: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh()
        side = math.isqrt(size)

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            def body(i, buf):
                if kind == "vector":
                    # every second column of a (side, 2·side) buffer
                    view = jmpi.View(buf, (slice(None),
                                           slice(0, 2 * side, 2)))
                else:
                    # interior block of a halo-padded (side+2)² tile
                    view = jmpi.View(buf, (slice(1, side + 1),
                                           slice(1, side + 1)))
                req = jmpi.isendrecv(view, pairs=[(0, 1), (1, 0)], tag=5,
                                     recv_into=view)
                _, out = jmpi.wait(req)
                return out

            return jax.lax.fori_loop(0, inner, body, x)

        shape = ((side, 2 * side) if kind == "vector"
                 else (side + 2, side + 2))
        x = jnp.ones(shape, jnp.float32)
        return lambda: f(x).block_until_ready()

    return build


_MP_JOBS: dict = {}


def _mp_job(transport: str = "sock"):
    """The lazily-started persistent 2-rank bench job for ``transport``.

    Started once per (suite process, transport) and reused by every
    multiproc cell — the launcher's atexit hook reaps it.  Restarted if a
    previous cell's failure killed it.
    """
    job = _MP_JOBS.get(transport)
    if job is None or job.procs[0].poll() is not None:
        from repro.transport import launch
        job = launch(2, "repro.transport.testing:_bench_worker",
                     transport=transport, interactive=True, timeout=600)
        _MP_JOBS[transport] = job
    return job


def _multiproc_build(op: str, inner: int, window: int = WINDOW,
                     transport: str = "sock"):
    def build(size: int):
        # spawn + rendezvous happen here, outside the clock
        job = _mp_job(transport)
        cmd = {"op": op, "size": size * 4, "inner": inner}
        if op.startswith("window"):
            cmd["window"] = window

        def thunk():
            job.command(cmd)
            reply = job.read_line()
            if not reply.startswith("DONE "):
                raise RuntimeError(f"bench worker replied {reply!r}")

        return thunk

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """Build the p2p cases for ``cfg`` (quick mode shrinks grid + inner)."""
    sizes = QUICK_SIZES if cfg.quick else FULL_SIZES
    inner = _inner(cfg)
    nbytes = lambda size: size * 4  # noqa: E731 - float32 payload
    square = lambda size: math.isqrt(size) ** 2 == size  # noqa: E731

    def bw_derived(size: int, sec_per_call: float) -> dict:
        return {"GBps_per_dir": WINDOW * size * 4 / sec_per_call / 1e9,
                "window": float(WINDOW)}

    def lat_derived(size: int, sec_per_call: float) -> dict:
        return {"msgs_per_s": 2.0 / sec_per_call}

    return [
        Case(name="p2p_latency", build=_latency_build(inner),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=lat_derived, sweepable=True),
        Case(name="p2p_bandwidth", build=_bandwidth_build(inner, WINDOW),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=bw_derived, sweepable=True),
        Case(name="p2p_noncontig_vector",
             build=_noncontig_build("vector", inner),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=lat_derived, sweepable=True, size_ok=square),
        Case(name="p2p_noncontig_subarray",
             build=_noncontig_build("subarray", inner),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=lat_derived, sweepable=True, size_ok=square),
        Case(name="p2p_multiproc_latency",
             build=_multiproc_build("pingpong", inner),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=lat_derived, sweepable=True),
        Case(name="p2p_multiproc_bw",
             build=_multiproc_build("window", inner, WINDOW),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=bw_derived, sweepable=True),
        Case(name="p2p_multiproc_persistent_latency",
             build=_multiproc_build("pingpong_persistent", inner,
                                    transport="shm"),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=lat_derived, sweepable=True),
        Case(name="p2p_multiproc_persistent_bw",
             build=_multiproc_build("window_persistent", inner, WINDOW,
                                    transport="shm"),
             sizes=sizes, inner=inner, unit="us", nbytes=nbytes,
             derived=bw_derived, sweepable=True),
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Eager-vs-persistent contrast rows and the fast-path invariant.

    ``persistent_faster_than_eager`` claims the persistent-channel plan
    path beats the eager pickle-framed path by ≥5× at the smallest
    measured size (4 KiB in the quick grid) — the repo's counterpart to
    the paper's §Performance eager-vs-compiled gap.  Like the trainer
    invariants, it is a claim about steady-state MEDIANS and is only
    emitted when every involved row carries ≥3 samples (the CI perf gate
    runs repeats=5; repeats=1 smoke runs validate the artifact only).
    """
    from repro.bench.core import free_row

    lat = {(r["name"], r["size"]): r for r in rows
           if r["name"] in ("p2p_multiproc_latency",
                            "p2p_multiproc_persistent_latency")}
    shared = sorted(s for (n, s) in lat
                    if n == "p2p_multiproc_latency"
                    and ("p2p_multiproc_persistent_latency", s) in lat)
    extra: list[dict] = []
    invariants: dict[str, bool] = {}
    if shared:
        size = shared[0]
        eager = lat[("p2p_multiproc_latency", size)]
        pers = lat[("p2p_multiproc_persistent_latency", size)]
        if pers["value"] > 0:
            extra.append(free_row("p2p_persistent_speedup_vs_eager",
                                  eager["value"] / pers["value"],
                                  size=size))
        stable = all((r.get("stats") or {}).get("n", 0) >= 3
                     for r in (eager, pers))
        if stable:
            invariants["persistent_faster_than_eager"] = (
                pers["value"] * 5.0 <= eager["value"])
    # Honest same-transport contrast: one eager ping-pong on the SHM job
    # (reporting-only — the gated eager row stays on sock, the backend's
    # portable default).
    try:
        size = shared[0] if shared else (cfg.sizes or QUICK_SIZES)[0]
        inner = _inner(cfg)
        thunk = _multiproc_build("pingpong", inner,
                                 transport="shm")(size)
        thunk()  # first call pays barrier sync noise; time the second
        import time as _time
        t0 = _time.perf_counter()
        thunk()
        per_call_us = (_time.perf_counter() - t0) / inner * 1e6
        extra.append(free_row("p2p_multiproc_eager_shm_latency",
                              per_call_us, unit="us", size=size))
    except Exception:
        pass  # contrast row is reporting-only; gated rows already ran
    return extra, invariants
