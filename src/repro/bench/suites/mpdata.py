"""MPDATA decomposition-layout suite (paper Fig. 3, 8 ranks).

The same 2-D advection problem decomposed along dim 0 (8×1), dim 1 (1×8)
or both (2×4) — PyMPDATA-MPI exposes exactly this choice; the per-layout
µs/step rows reproduce the paper's layout study.  ``case size`` = grid
points per side.

``extras`` re-runs the first layout for 5 steps against the single-device
``reference_step`` oracle → the ``mpdata_oracle`` invariant (layouts must
agree with the un-decomposed solver, not just be fast).
"""

from __future__ import annotations

from repro.bench.core import BenchConfig, Case, free_row


def _grid_steps(cfg: BenchConfig) -> tuple[int, int]:
    return (64, 10) if cfg.quick else (256, 50)


def _psi0(grid: int):
    import jax.numpy as jnp
    import numpy as np

    x = np.arange(grid)
    cx, cy, w = 0.375 * grid, 0.5 * grid, grid * grid / 128.0
    return jnp.asarray(
        np.exp(-((x - cx) ** 2)[:, None] / w - ((x - cy) ** 2)[None, :] / w)
        + 0.01, jnp.float32)


def _layouts():
    import jax
    n = len(jax.devices())
    layouts = [(n, 1), (1, n)]
    if n >= 4:
        layouts.append((2, n // 2))
    return layouts


def _layout_build(rows: int, cols: int, steps: int):
    def build(grid: int):
        from repro.core import compat
        from repro.pde import mpdata

        mesh = compat.make_mesh((rows, cols), ("px", "py"))
        run = mpdata.make_solver(mesh, inner_steps=steps)
        psi0 = _psi0(grid)
        return lambda: run(psi0).block_until_ready()

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """One case per decomposition layout (names are device-count free so
    baseline keys stay stable: run.py always drives this at 8 ranks)."""
    grid, steps = _grid_steps(cfg)
    return [
        Case(name=f"mpdata_{rows}x{cols}",
             build=_layout_build(rows, cols, steps),
             sizes=(grid,), inner=steps, unit="us")
        for rows, cols in _layouts()
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Oracle agreement: 5 decomposed steps vs ``reference_step``."""
    import numpy as np
    from repro.core import compat
    from repro.pde import mpdata

    grid, _ = _grid_steps(cfg)
    psi0 = _psi0(grid)
    want = psi0
    for _ in range(5):
        want = mpdata.reference_step(want)

    layouts = _layouts() if not cfg.quick else _layouts()[:1]
    ok = True
    worst = 0.0
    for rows_, cols_ in layouts:
        mesh = compat.make_mesh((rows_, cols_), ("px", "py"))
        got = mpdata.make_solver(mesh, inner_steps=5)(psi0)
        err = float(np.abs(np.asarray(got) - np.asarray(want)).max())
        worst = max(worst, err)
        ok = ok and err < 1e-4
    return ([free_row("mpdata_oracle_err", worst, unit="x", size=grid)],
            {"mpdata_oracle": ok})
