"""π suite (paper Listings 1–4 / Fig. 1, 4 ranks).

* ``pi_python`` / ``pi_jit`` — Listing 1: the compute kernel with and
  without JIT (the paper's ~100× speedup headline).
* ``pi_jmpi`` — Listing 3: the whole N_TIMES loop, compute *and*
  allreduce, in ONE compiled program.
* ``pi_roundtrip`` — the same psum allreduce but one jit dispatch per
  iteration with a host sync in between: the paper's
  leave-the-compiled-block-every-call pattern with the communication
  mechanism held fixed, so roundtrip/jmpi isolates exactly the Fig. 1
  overhead.
* ``pi_hostbridge`` — Listing 2: per-iteration dispatch + host numpy
  reduction (the mpi4py failure mode; different transport, see the
  emulated-transport caveat in docs/BENCHMARKS.md).

``case size`` = the communication-frequency divisor ``x``
(``n_intervals = N_TIMES / x`` — higher x = more communication-bound).
``extras`` emits the Fig. 1 speedup ratios and the π-accuracy invariant.
"""

from __future__ import annotations

import math

from repro.bench.core import BenchConfig, Case, free_row

MAX_INTERVALS = 100000
RTOL = 1e-3

_ACCURACY: dict[str, bool] = {}


def _n_times(cfg: BenchConfig) -> int:
    return 40 if cfg.quick else 200


def _factors(cfg: BenchConfig) -> tuple[int, ...]:
    return (1, 4) if cfg.quick else (1, 4, 16)


def _mesh():
    import jax
    from repro.core import compat
    return compat.make_mesh((len(jax.devices()),), ("ranks",))


def _pi_part_python(n_intervals: int, rank: int = 0, size: int = 1) -> float:
    h = 1.0 / n_intervals
    partial_sum = 0.0
    for i in range(rank + 1, n_intervals, size):
        x = h * (i - 0.5)
        partial_sum += 4.0 / (1.0 + x * x)
    return h * partial_sum


def _python_build(n_intervals: int):
    def build(size: int):
        def thunk():
            pi = _pi_part_python(n_intervals)
            assert abs(pi - math.pi) < 1e-2
            return pi

        return thunk

    return build


def _jit_build(n_intervals: int):
    def build(size: int):
        import jax
        import jax.numpy as jnp

        @jax.jit
        def get_pi_part(n):
            idx = jnp.arange(1, MAX_INTERVALS)
            h = 1.0 / n
            x = h * (idx - 0.5)
            vals = jnp.where(idx < n, 4.0 / (1.0 + x * x), 0.0)
            return h * jnp.sum(vals)

        narr = jnp.float32(n_intervals)

        def thunk():
            out = get_pi_part(narr)
            out.block_until_ready()
            return out

        out = thunk()
        assert abs(float(out) - math.pi) < 1e-2
        _ACCURACY["pi_jit"] = True
        return thunk

    return build


def _jmpi_build(n_times: int):
    def build(x_factor: int):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh()
        n_intervals = max(64, n_times // x_factor)

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def pi_loop(dummy):
            rank = jmpi.rank()
            size = jmpi.size()
            h = 1.0 / n_intervals
            idx = jnp.arange(0, n_intervals // size + 1)

            def one(i, acc):
                gidx = rank + 1 + idx * size
                xs = h * (gidx - 0.5)
                part = h * jnp.sum(jnp.where(gidx < n_intervals + 1,
                                             4.0 / (1.0 + xs * xs), 0.0))
                _, pi = jmpi.allreduce(part + 0.0 * acc)
                return pi

            return jax.lax.fori_loop(0, n_times, one, 0.0 * dummy)

        z = jnp.float32(0.0)
        pi = float(pi_loop(z))
        assert abs(pi - math.pi) / math.pi < RTOL, pi
        _ACCURACY[f"pi_jmpi_x{x_factor}"] = True
        return lambda: pi_loop(z).block_until_ready()

    return build


def _roundtrip_build(n_times: int):
    def build(x_factor: int):
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.core as jmpi

        mesh = _mesh()
        n_intervals = max(64, n_times // x_factor)

        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def one(acc):
            rank = jmpi.rank()
            size = jmpi.size()
            h = 1.0 / n_intervals
            idx = jnp.arange(0, n_intervals // size + 1)
            gidx = rank + 1 + idx * size
            xs = h * (gidx - 0.5)
            part = h * jnp.sum(jnp.where(gidx < n_intervals + 1,
                                         4.0 / (1.0 + xs * xs), 0.0))
            _, pi = jmpi.allreduce(part + 0.0 * acc)
            return pi

        def thunk():
            pi = jnp.float32(0.0)
            for _ in range(n_times):
                pi = one(pi * 0.0)
                pi.block_until_ready()        # the host round-trip
            return float(pi)

        pi = thunk()
        assert abs(pi - math.pi) / math.pi < RTOL, pi
        _ACCURACY[f"pi_roundtrip_x{x_factor}"] = True
        return thunk

    return build


def _hostbridge_build(n_times: int):
    def build(x_factor: int):
        import jax
        import jax.numpy as jnp
        import numpy as np

        mesh = _mesh()
        n_dev = mesh.devices.size
        n_intervals = max(64, n_times // x_factor)

        @jax.jit
        def part_all_ranks(dummy):
            ranks = jnp.arange(n_dev)
            h = 1.0 / n_intervals
            idx = jnp.arange(0, n_intervals // n_dev + 1)
            gidx = ranks[:, None] + 1 + idx[None, :] * n_dev
            xs = h * (gidx - 0.5)
            parts = h * jnp.sum(jnp.where(gidx < n_intervals + 1,
                                          4.0 / (1.0 + xs * xs), 0.0),
                                axis=1)
            return parts + 0.0 * dummy

        def thunk():
            pi = 0.0
            for _ in range(n_times):
                parts = part_all_ranks(jnp.float32(pi * 0.0))
                parts.block_until_ready()        # leave the compiled block
                pi = float(np.sum(np.asarray(parts)))
            return pi

        pi = thunk()
        assert abs(pi - math.pi) / math.pi < RTOL, pi
        _ACCURACY[f"pi_hostbridge_x{x_factor}"] = True
        return thunk

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """Build the π cases for ``cfg``."""
    _ACCURACY.clear()
    n_times = _n_times(cfg)
    n_intervals = 20000 if cfg.quick else MAX_INTERVALS
    factors = _factors(cfg)
    return [
        Case(name="pi_python", build=_python_build(n_intervals),
             sizes=(n_intervals,), unit="ms"),
        Case(name="pi_jit", build=_jit_build(n_intervals),
             sizes=(n_intervals,), unit="us"),
        Case(name="pi_jmpi", build=_jmpi_build(n_times), sizes=factors,
             unit="ms"),
        Case(name="pi_roundtrip", build=_roundtrip_build(n_times),
             sizes=factors, unit="ms"),
        Case(name="pi_hostbridge", build=_hostbridge_build(n_times),
             sizes=factors, unit="ms"),
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Fig. 1 speedup ratios + the π-accuracy invariant."""
    from repro.bench.schema import TIME_UNITS

    def us(name: str, size: int) -> float | None:
        for r in rows:
            if r["name"] == name and r["size"] == size:
                return r["value"] * TIME_UNITS[r["unit"]]
        return None

    extra: list[dict] = []
    n_intervals = 20000 if cfg.quick else MAX_INTERVALS
    t_py, t_jit = us("pi_python", n_intervals), us("pi_jit", n_intervals)
    if t_py and t_jit:
        extra.append(free_row("pi_jit_speedup", t_py / t_jit,
                              size=n_intervals))
    for x in _factors(cfg):
        t_jmpi, t_rt = us("pi_jmpi", x), us("pi_roundtrip", x)
        t_host = us("pi_hostbridge", x)
        if t_jmpi and t_rt:
            extra.append(free_row("pi_jitresident_speedup", t_rt / t_jmpi,
                                  size=x))
        if t_jmpi and t_host:
            extra.append(free_row("pi_vs_hostbridge_speedup",
                                  t_host / t_jmpi, size=x))
    return extra, {"pi_accurate": all(_ACCURACY.values())
                   and bool(_ACCURACY)}
