"""Kernel-structure suite (single device).

Interpret-mode Pallas timings are meaningless (Python loop per grid step),
so this measures the XLA-native *twins* sharing the kernels' algorithmic
structure against their naive counterparts — the blockwise-vs-naive
attention memory/latency trade and the chunked-vs-sequential SSD scan.
``case size`` = sequence length.
"""

from __future__ import annotations

from repro.bench.core import BenchConfig, Case, free_row

ATTN_BLOCK = 512


def _seqs(cfg: BenchConfig) -> tuple[int, ...]:
    return (512,) if cfg.quick else (2048,)


def _attn_build(blockwise: bool):
    def build(s: int):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.models.attention import _sdpa, blockwise_sdpa, causal_mask

        b, h, kh, d = 1, 4, 2, 64
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.bfloat16)
        block = min(ATTN_BLOCK, s)
        if blockwise:
            f = jax.jit(lambda q, k, v: blockwise_sdpa(
                q, k, v, kh, q_block=block, kv_block=block))
        else:
            f = jax.jit(lambda q, k, v: _sdpa(
                q, k, v, causal_mask(s)[None, None, None], kh))
        return lambda: f(q, k, v).block_until_ready()

    return build


def _ssd_build(chunked: bool):
    def build(s: int):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.models.ssm import ssd_chunked
        from repro.kernels.mamba2_ssd.ref import ssd_scan_ref

        b, H, P, N = 1, 8, 32, 64
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((b, s, H, P)) * 0.5, jnp.float32)
        dt = jnp.abs(jnp.asarray(rng.standard_normal((b, s, H)) * 0.3,
                                 jnp.float32)) + 0.01
        B = jnp.asarray(rng.standard_normal((b, s, N)) * 0.5, jnp.float32)
        C = jnp.asarray(rng.standard_normal((b, s, N)) * 0.5, jnp.float32)
        A = -jnp.abs(jnp.asarray(rng.uniform(0.5, 2.0, H), jnp.float32))
        D = jnp.zeros((H,), jnp.float32)
        if chunked:
            f = jax.jit(lambda: ssd_chunked(x, dt, A, B, C, chunk=64)[0])
        else:
            f = jax.jit(lambda: ssd_scan_ref(
                jnp.moveaxis(x, 2, 1), jnp.moveaxis(dt, 2, 1),
                B, C, A, D)[0])
        return lambda: f().block_until_ready()

    return build


def build(cfg: BenchConfig) -> list[Case]:
    """Build the kernel-twin cases for ``cfg``."""
    seqs = _seqs(cfg)
    return [
        Case(name="attn_naive", build=_attn_build(blockwise=False),
             sizes=seqs, unit="us"),
        Case(name="attn_blockwise", build=_attn_build(blockwise=True),
             sizes=seqs, unit="us"),
        Case(name="ssd_sequential", build=_ssd_build(chunked=False),
             sizes=seqs, unit="us"),
        Case(name="ssd_chunked", build=_ssd_build(chunked=True),
             sizes=seqs, unit="us"),
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Structure-win ratio rows (naive/blockwise, sequential/chunked)."""
    extra: list[dict] = []
    for s in _seqs(cfg):
        vals = {r["name"]: r["value"] for r in rows if r["size"] == s}
        if vals.get("attn_blockwise") and vals.get("attn_naive"):
            extra.append(free_row("attn_blockwise_speedup",
                                  vals["attn_naive"] /
                                  vals["attn_blockwise"], size=s))
        if vals.get("ssd_chunked") and vals.get("ssd_sequential"):
            extra.append(free_row("ssd_chunked_speedup",
                                  vals["ssd_sequential"] /
                                  vals["ssd_chunked"], size=s))
    return extra, {}
