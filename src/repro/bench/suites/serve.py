"""Serving-engine suite: continuous batching vs the padded fixed batch.

One mixed-length workload (short+long prompts, per-request generation
budgets, staggered arrivals — the shape real serving traffic has), two
engines:

* ``serve_continuous`` — :class:`repro.serve.engine.ContinuousEngine`:
  paged KV cache, chunked prefill interleaved with decode, slot recycling;
* ``serve_padded`` — :class:`repro.serve.engine.Engine`: requests padded
  into fixed batches, decoded in lockstep to the longest budget, batch
  restart between rounds.

Rows are ms per whole workload at ``size`` = offered requests (the
tokens/s-vs-offered-load curve lives in each row's ``tok_per_s`` derived
value); ``extras`` reports the continuous/padded speedup, p50/p99 request
latencies from an instrumented pass, and the machine-checked invariants:
the continuous engine must beat the padded one on aggregate tokens/s, and
the paged cache must be bitwise-equal to the dense reference.
"""

from __future__ import annotations

from repro.bench.core import BenchConfig, Case, free_row

MAX_PROMPT = 24
MAX_NEW = 32
MAX_SLOTS = 8


def _sizes(cfg: BenchConfig) -> tuple[int, ...]:
    return (8, 24) if cfg.quick else (16, 48)


def _workload(n: int):
    """n mixed requests: (prompt, max_new, arrival) with short/long prompts
    interleaved, bimodal generation budgets (mostly short answers, a long
    tail of long ones — the head-of-line-blocking shape fixed batching is
    worst at), and four arrivals per engine step."""
    import numpy as np

    rng = np.random.default_rng(1234 + n)
    reqs = []
    for i in range(n):
        s = int(rng.integers(4, 9)) if i % 2 == 0 \
            else int(rng.integers(16, MAX_PROMPT + 1))
        mnt = int(rng.integers(MAX_NEW - 4, MAX_NEW + 1)) \
            if rng.random() < 0.25 else int(rng.integers(2, 7))
        prompt = rng.integers(0, 256, (s,), dtype=np.int32)
        reqs.append((prompt, mnt, i // 4))
    return reqs


def _useful_tokens(n: int) -> int:
    return sum(mnt for _, mnt, _ in _workload(n))


def _tiny():
    import jax
    from repro.configs import get_tiny
    from repro.models import lm as lm_lib

    cfg = get_tiny("yi-6b")
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _continuous_engine(model_cfg, params):
    from repro.serve.engine import ContinuousEngine, ServeConfig

    sc = ServeConfig(max_prompt=MAX_PROMPT, max_new_tokens=MAX_NEW,
                     eos_id=-1, block_size=8, n_blocks=56,
                     max_slots=MAX_SLOTS, prefill_chunk=12,
                     prefill_batch=4)
    return ContinuousEngine(model_cfg, params, sc)


def _run_continuous(eng, reqs):
    eng.reset()
    for prompt, mnt, arrival in reqs:
        eng.submit(prompt, mnt, arrival=arrival)
    return eng.run()


def _run_padded(eng, reqs):
    """Fixed-batch rounds in arrival order: prompts padded to MAX_PROMPT,
    every round decoded to the engine-wide MAX_NEW budget."""
    import numpy as np

    outs = []
    for lo in range(0, len(reqs), MAX_SLOTS):
        batch = reqs[lo:lo + MAX_SLOTS]
        prompts = np.zeros((len(batch), MAX_PROMPT), np.int32)
        for i, (prompt, _, _) in enumerate(batch):
            prompts[i, :len(prompt)] = prompt
        outs.append(eng.generate(prompts))
    return outs


def build(cfg: BenchConfig) -> list[Case]:
    """Build the serving cases for ``cfg``."""
    sizes = _sizes(cfg)

    def derived(n: int, sec: float) -> dict:
        return {"tok_per_s": _useful_tokens(n) / sec if sec > 0 else 0.0,
                "useful_tokens": float(_useful_tokens(n))}

    def build_continuous(n: int):
        model_cfg, params = _tiny()
        eng = _continuous_engine(model_cfg, params)
        reqs = _workload(n)

        def thunk():
            _run_continuous(eng, reqs)

        return thunk

    def build_padded(n: int):
        from repro.serve.engine import Engine, ServeConfig

        model_cfg, params = _tiny()
        eng = Engine(model_cfg, params,
                     ServeConfig(max_prompt=MAX_PROMPT,
                                 max_new_tokens=MAX_NEW, eos_id=-1))
        reqs = _workload(n)

        def thunk():
            _run_padded(eng, reqs)

        return thunk

    return [
        Case(name="serve_continuous", build=build_continuous, sizes=sizes,
             unit="ms", derived=derived, sweepable=True),
        Case(name="serve_padded", build=build_padded, sizes=sizes,
             unit="ms", derived=derived, sweepable=True),
    ]


def extras(cfg: BenchConfig, rows: list[dict]) -> tuple[list[dict], dict]:
    """Speedup + latency percentiles + correctness invariants."""
    import numpy as np

    extra: list[dict] = []
    invariants: dict = {}

    head = max(_sizes(cfg))
    by = {(r["name"], r["size"]): r["value"] for r in rows}
    cont = by.get(("serve_continuous", head))
    padd = by.get(("serve_padded", head))
    if cont and padd:
        extra.append(free_row("serve_continuous_speedup_vs_padded",
                              padd / cont, size=head))
        invariants["continuous_faster_than_padded"] = padd / cont > 1.0

    model_cfg, params = _tiny()
    eng = _continuous_engine(model_cfg, params)

    # p50/p99 request latency from a warm instrumented pass at the head
    # load (first pass compiles the step functions; ``reset`` inside the
    # second pass clears its latency samples)
    _run_continuous(eng, _workload(head))
    _run_continuous(eng, _workload(head))
    lats_ms = np.sort(np.array(list(eng.latency.values()))) * 1e3
    if len(lats_ms):
        extra.append(free_row("serve_latency_p50", float(
            np.percentile(lats_ms, 50)), unit="ms", size=head))
        extra.append(free_row("serve_latency_p99", float(
            np.percentile(lats_ms, 99)), unit="ms", size=head))

    # paged-vs-dense bitwise oracle: per-sequence K/V extracted through the
    # block-table datatype view must equal the dense linear cache, and the
    # continuous tokens must equal the one-request-at-a-time reference.
    import jax
    import jax.numpy as jnp
    from repro.models import lm as lm_lib

    rng = np.random.default_rng(7)
    prompt = rng.integers(0, 256, (9,), dtype=np.int32)
    mnt = 5
    n_kv = len(prompt) + mnt - 1
    snap = {}
    orig_free = eng.cache.free_slot

    def spy(slot):
        snap.update(eng.cache.extract(slot, n_kv))
        orig_free(slot)

    eng.reset()
    eng.cache.free_slot = spy
    rid = eng.submit(prompt, mnt)
    res = eng.run()
    eng.cache.free_slot = orig_free

    pre = jax.jit(lambda p, b: lm_lib.prefill(p, model_cfg, b, 32))
    dec = jax.jit(lambda p, b, c, t: lm_lib.decode_step(p, model_cfg, b,
                                                        c, t))
    logits, caches = pre(params, {"tokens": jnp.asarray(prompt[None, :])})
    toks = [int(np.asarray(logits)[0, 0, :model_cfg.vocab_size].argmax())]
    for i in range(mnt - 1):
        logits, caches = dec(params, {"tokens": jnp.asarray([[toks[-1]]])},
                             caches, len(prompt) + i)
        toks.append(int(np.asarray(logits)[0, 0,
                                           :model_cfg.vocab_size].argmax()))
    dense_k = np.asarray(caches["main"]["k"])[:, 0, :n_kv]
    dense_v = np.asarray(caches["main"]["v"])[:, 0, :n_kv]
    invariants["paged_equals_dense"] = bool(
        np.array_equal(dense_k, snap.get("k"))
        and np.array_equal(dense_v, snap.get("v")))
    invariants["continuous_matches_sequential"] = toks == list(res[rid])
    return extra, invariants
