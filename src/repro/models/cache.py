"""Decode-time caches: GQA KV (full or SWA ring), MLA latent, SSM/xLSTM state.

All caches are plain pytrees (dicts) so they pass through jit boundaries,
``input_specs`` can describe them as ShapeDtypeStructs for the dry-run, and
sharding rules apply per leaf.  Slot bookkeeping uses an explicit
``slot_pos`` array ((S_cache,) int32, -1 = empty slot) so sliding-window ring
buffers and linear caches share one masking rule:
valid  =  slot_pos >= 0  &  slot_pos <= t  &  (window is None or t - slot_pos < window).
"""

from __future__ import annotations

import jax.numpy as jnp


def init_kv_cache(cfg, batch, max_len, dtype=None):
    """Full-length (or SWA ring) KV cache for one attention layer stack.

    Returned arrays carry a leading layer dim so the layer scan can
    scan over the cache in lockstep with the stacked params.
    """
    dt = dtype or cfg.act_dtype
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    s = max_len if cfg.window is None else min(cfg.window, max_len)
    return {
        "k": jnp.zeros((cfg.n_layers, batch, s, kh, hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, s, kh, hd), dt),
        "slot_pos": jnp.full((cfg.n_layers, s), -1, jnp.int32),
    }


def init_mla_cache(cfg, batch, max_len, n_layers=None, dtype=None):
    dt = dtype or cfg.act_dtype
    nl = n_layers if n_layers is not None else cfg.n_layers
    return {
        "ckv": jnp.zeros((nl, batch, max_len, cfg.kv_lora_rank), dt),
        "krope": jnp.zeros((nl, batch, max_len, cfg.qk_rope_dim), dt),
        "slot_pos": jnp.full((nl, max_len), -1, jnp.int32),
    }


def init_ssm_state(cfg, batch, n_layers=None, dtype=None):
    dt = dtype or cfg.act_dtype
    nl = n_layers if n_layers is not None else cfg.n_layers
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_ch = d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((nl, batch, cfg.ssm_conv - 1, conv_ch), dt),
        "ssd": jnp.zeros((nl, batch, nheads, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
    }


def init_mlstm_state(cfg, batch, n_layers, dtype=None):
    d_inner = int(cfg.d_model * cfg.xlstm_proj_factor)
    h = cfg.n_heads
    dh = d_inner // h
    return {
        "C": jnp.zeros((n_layers, batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((n_layers, batch, h, dh), jnp.float32),
        "m": jnp.full((n_layers, batch, h), -30.0, jnp.float32),
    }


def init_slstm_state(cfg, batch, n_layers, dtype=None):
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jnp.zeros((n_layers, batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((n_layers, batch, h, dh), -30.0, jnp.float32)}


def init_paged_kv_cache(cfg, n_blocks, block_size, dtype=None):
    """Paged KV pool for one attention layer stack (continuous batching).

    Unlike :func:`init_kv_cache` there is no batch dim: the pool is
    ``n_blocks * block_size`` flat token rows shared by every in-flight
    sequence, carved into fixed-size blocks that a host-side allocator
    (``serve/paged_cache.py``) hands out via per-sequence block tables.
    Block 0 is reserved as the scratch block — writes from idle decode
    slots and prefill padding land there and are never attended.  There is
    no ``slot_pos`` array: validity is positional (gathered row ``j``
    holds position ``j``), so :func:`paged_valid_mask` masks per sequence.
    """
    dt = dtype or cfg.act_dtype
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    p = int(n_blocks) * int(block_size)
    return {
        "k": jnp.zeros((cfg.n_layers, p, kh, hd), dt),
        "v": jnp.zeros((cfg.n_layers, p, kh, hd), dt),
    }


def paged_valid_mask(pos, s_max, window=None):
    """(..., s_max) mask for paged attention rows gathered in position order.

    ``pos`` is int32 of any shape — the query position per row ((B,) slots
    in decode, (B, C) chunk rows in batched chunked prefill); ``pos < 0``
    marks an idle/pad row and masks everything.  Gathered key row ``j``
    holds position ``j``, so the rule is the linear-cache one of
    :func:`valid_mask` with ``slot_pos = arange``: ``j <= pos`` and (SWA)
    ``pos - j < window``.
    """
    j = jnp.arange(s_max, dtype=jnp.int32)
    p = pos.astype(jnp.int32)[..., None]
    m = (j <= p) & (p >= 0)
    if window is not None:
        m &= (p - j) < window
    return m


def slot_write_index(slot_pos_row, t, window):
    """Where position t lands: t (linear cache) or t % window (ring)."""
    del slot_pos_row
    s = t if window is None else t % window
    return s


def valid_mask(slot_pos, t, window):
    """(S_cache,) bool — which slots a query at position t may attend to."""
    m = (slot_pos >= 0) & (slot_pos <= t)
    if window is not None:
        m &= (t - slot_pos) < window
    return m
