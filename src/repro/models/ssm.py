"""Mamba2 (state-space duality) block: chunked-parallel train/prefill path
(matmul-heavy, MXU-friendly — the formulation the Pallas kernel accelerates)
plus the O(1)-state single-step decode path.

Shapes follow the Mamba2 paper: d_inner = expand·d_model, heads = d_inner /
headdim, scalar decay per head (A), shared B/C of size ssm_state per group
(n_groups=1 here, zamba2's choice), short causal conv over (x,B,C).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import dense_init


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_ch = d_inner + 2 * cfg.ssm_state
    return d_inner, nheads, conv_ch


def init_mamba2(key, cfg):
    d = cfg.d_model
    d_inner, nheads, conv_ch = dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * cfg.ssm_state + nheads),
                           in_axis_size=d),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nheads,), 0.01))).astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, d), in_axis_size=d_inner),
    }


def _split_proj(cfg, proj):
    d_inner, nheads, _ = dims(cfg)
    n = cfg.ssm_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -nheads:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, kernel k. xbc: (B,S,C); state: (B,k-1,C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)           # (B, S+k-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out + b.astype(xbc.dtype)), new_state


def ssd_chunked(xh, dt, A, B, C, chunk=64, h0=None):
    """Chunked-parallel SSD scan.

    xh: (b, s, H, P) inputs; dt: (b, s, H) positive step sizes;
    A: (H,) negative decay rates; B, C: (b, s, N).
    Returns (y (b,s,H,P), h_final (b,H,P,N)). fp32 state math.

    Within a chunk the recurrence h_t = e^{A·dt_t} h_{t-1} + dt_t·B_t⊗x_t is
    unrolled into two matmuls against decay-weighted masks (the "dual" /
    attention-like form); across chunks a short scan carries the state.
    """
    b, s, H, P = xh.shape
    N = B.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    xc = xh.reshape(b, nc, L, H, P).astype(jnp.float32)
    dtc = dt.reshape(b, nc, L, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, L, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, L, N).astype(jnp.float32)

    dA = dtc * A  # (b,nc,L,H) negative
    seg = jnp.cumsum(dA, axis=2)                       # Σ_{u<=t} dA_u
    # intra-chunk "attention": M[t,u] = e^{seg_t - seg_u} for u<=t
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # (b,nc,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    # G[t,u] = C_t·B_u  (shared across heads; n_groups=1)
    G = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)                # (b,nc,L,L)
    W = G[..., None] * M                                     # (b,nc,L,L,H)
    y_intra = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", W, dtc, xc)

    # chunk-final states: h_c = Σ_u e^{seg_L - seg_u} dt_u B_u ⊗ x_u
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)          # (b,nc,L,H)
    hc = jnp.einsum("bclh,bclh,bcln,bclhp->bchpn",
                    decay_to_end, dtc, Bc, xc)               # per-chunk state
    chunk_decay = jnp.exp(seg[:, :, -1, :])                  # (b,nc,H)

    # inter-chunk scan (nc steps)
    def scan_fn(h, inp):
        hci, dci = inp                                       # (b,H,P,N),(b,H)
        h_new = h * dci[:, :, None, None] + hci
        return h_new, h
    h_init = jnp.zeros((b, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    hcs = jnp.moveaxis(hc, 1, 0)                             # (nc,b,H,P,N)
    dcs = jnp.moveaxis(chunk_decay, 1, 0)                    # (nc,b,H)
    h_final, h_prevs = jax.lax.scan(scan_fn, h_init, (hcs, dcs))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (b,nc,H,P,N)

    # contribution of carried-in state to each position
    decay_from_start = jnp.exp(seg)                          # (b,nc,L,H)
    y_inter = jnp.einsum("bclh,bcln,bchpn->bclhp",
                         decay_from_start, Cc, h_prevs)
    y = (y_intra + y_inter).reshape(b, nc * L, H, P)
    if pad:
        y = y[:, :s]
    return y.astype(xh.dtype), h_final


def ssd_decode_step(h, xh, dt, A, B, C):
    """Single-token state update. h: (b,H,P,N); xh: (b,H,P); dt: (b,H);
    B,C: (b,N). Returns (y (b,H,P), h')."""
    dA = jnp.exp(dt * A)                                     # (b,H)
    h32 = h.astype(jnp.float32)
    upd = (dt[:, :, None] * xh.astype(jnp.float32))[..., None] \
        * B.astype(jnp.float32)[:, None, None, :]            # (b,H,P,N)
    h_new = h32 * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C.astype(jnp.float32))
    return y.astype(xh.dtype), h_new


def _gated_norm(scale, y, z, eps=1e-5):
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def mamba2_forward(params, cfg, x, state=None, chunk=64):
    """Train/prefill. x: (B,S,d). Returns (y, new_state or None)."""
    d_inner, nheads, _ = dims(cfg)
    n = cfg.ssm_state
    dt_ = x.dtype
    proj = x @ params["w_in"].astype(dt_)
    z, xbc, dtp = _split_proj(cfg, proj)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xh = xbc[..., :d_inner]
    B = xbc[..., d_inner:d_inner + n]
    C = xbc[..., d_inner + n:]
    xh = shard(xh.reshape(*xh.shape[:2], nheads, cfg.ssm_headdim),
               "batch", None, "heads", None)
    dtv = jax.nn.softplus(dtp.astype(jnp.float32)
                          + params["dt_bias"])               # (B,S,H)
    dtv = shard(dtv, "batch", None, "heads")  # heads→model keeps the (L,L,H)
    A = -jnp.exp(params["A_log"])             # intra-chunk masks sharded (H,)
    h0 = None if state is None else state["ssd"]
    y, h_final = ssd_chunked(xh, dtv, A, B, C, chunk=chunk, h0=h0)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(*y.shape[:2], d_inner)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    out = y @ params["w_out"].astype(dt_)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "ssd": h_final}
    return shard(out, "batch", None, "embed"), new_state


def mamba2_decode(params, cfg, x, state):
    """One token. x: (B,1,d); state: {conv (B,k-1,C), ssd (B,H,P,N)}."""
    d_inner, nheads, _ = dims(cfg)
    n = cfg.ssm_state
    dt_ = x.dtype
    proj = x @ params["w_in"].astype(dt_)                    # (B,1,·)
    z, xbc, dtp = _split_proj(cfg, proj)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 state["conv"])
    xh = xbc[:, 0, :d_inner].reshape(-1, nheads, cfg.ssm_headdim)
    B = xbc[:, 0, d_inner:d_inner + n]
    C = xbc[:, 0, d_inner + n:]
    dtv = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h_new = ssd_decode_step(state["ssd"], xh, dtv, A, B, C)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(x.shape[0], 1, d_inner)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    out = y @ params["w_out"].astype(dt_)
    return out, {"conv": new_conv.astype(state["conv"].dtype), "ssd": h_new}
