"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory with recurrent weights, inherently sequential).

mLSTM is gated linear attention with per-head scalar forget/input gates and a
max-state stabilizer m; we implement the chunkwise-parallel form (matmuls
within chunks, short scan across chunks) for train/prefill — the same
structure the Mamba2 SSD path uses — and the O(1) recurrent step for decode.

sLSTM has hidden-to-gate recurrent weights (block-diagonal per head), so the
time loop is a true ``lax.scan`` (documented as serial in the roofline notes;
xLSTM places sLSTM in 1 of 8 blocks so the cost is bounded).

Block wiring follows the xLSTM paper's pre-LN residual blocks: mLSTM block
up-projects ×2, runs the cell, gates, down-projects; sLSTM block runs the
cell at model width, then a gated (4/3×) MLP.  d_ff=0 in the assigned config
means exactly this: no separate FFN outside the blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import dense_init, init_mlp, mlp, rmsnorm, init_rmsnorm


# ===================================================================== #
# mLSTM
# ===================================================================== #

def mlstm_dims(cfg):
    d_inner = int(cfg.d_model * cfg.xlstm_proj_factor)
    h = cfg.n_heads
    dh = d_inner // h
    return d_inner, h, dh


def init_mlstm_block(key, cfg):
    d = cfg.d_model
    d_inner, h, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": init_rmsnorm(d),
        "w_up": dense_init(ks[0], (d, 2 * d_inner), in_axis_size=d),  # x | gate
        # q/k/v are block-diagonal per head (xLSTM paper's BlockLinear):
        # (H, dh, dh) instead of (d_inner, d_inner) — 1/H the parameters.
        "wq": dense_init(ks[1], (h, dh, dh), in_axis_size=dh),
        "wk": dense_init(ks[2], (h, dh, dh), in_axis_size=dh),
        "wv": dense_init(ks[3], (h, dh, dh), in_axis_size=dh),
        "w_if": dense_init(ks[4], (d_inner, 2 * h), in_axis_size=d_inner),
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.full((h,), 3.0)]
                                ).astype(jnp.float32),
        "out_norm": jnp.ones((h, dh), jnp.float32),
        "w_down": dense_init(ks[5], (d_inner, d), in_axis_size=d_inner),
    }


def _mlstm_gates(params, xin):
    """log input gate (i), log forget gate (f) per head: (B,S,H) each."""
    gif = xin @ params["w_if"].astype(xin.dtype) + params["b_if"].astype(xin.dtype)
    h = gif.shape[-1] // 2
    log_i = gif[..., :h].astype(jnp.float32)            # exp gating: log i = raw
    log_f = jax.nn.log_sigmoid(gif[..., h:].astype(jnp.float32))
    return log_i, log_f


def mlstm_chunked(q, k, v, log_i, log_f, chunk=64, state=None):
    """Chunkwise-parallel mLSTM with stabilizer.

    q,k,v: (B,S,H,D); log_i/log_f: (B,S,H).
    state: optional dict(C (B,H,D,D), n (B,H,D), m (B,H)).
    Returns (y (B,S,H,D), new_state).
    """
    b, s, H, D = q.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    qc = q.reshape(b, nc, L, H, D).astype(jnp.float32) / jnp.sqrt(D)
    kc = k.reshape(b, nc, L, H, D).astype(jnp.float32)
    vc = v.reshape(b, nc, L, H, D).astype(jnp.float32)
    lic = log_i.reshape(b, nc, L, H)
    lfc = log_f.reshape(b, nc, L, H)

    F = jnp.cumsum(lfc, axis=2)                        # Σ log f within chunk
    # intra-chunk log weights: W[t,u] = F_t − F_u + i_u  (u ≤ t)
    logw = F[:, :, :, None, :] - F[:, :, None, :, :] + lic[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    logw = jnp.where(causal[None, None, :, :, None], logw, -jnp.inf)
    # carried-state contribution at t has log weight F_t (+ m_prev inside state)

    def scan_chunk(carry, inp):
        C_prev, n_prev, m_prev = carry                 # (b,H,D,D),(b,H,D),(b,H)
        qci, kci, vci, lici, Fi, logwi = inp
        # stabilizer per query position t: max over intra weights & carried
        m_intra = jnp.max(logwi, axis=2)               # (b,L,H) max over u
        m_t = jnp.maximum(Fi + m_prev[:, None, :], m_intra)   # (b,L,H)
        # intra-chunk attention (stabilized)
        w_int = jnp.exp(logwi - m_t[:, :, None, :])    # (b,L,L,H)
        scores = jnp.einsum("blhd,buhd->bluh", qci, kci)
        y_num = jnp.einsum("bluh,buhd->blhd", scores * w_int, vci)
        den_int = jnp.sum(scores * w_int, axis=2)      # Σ_u w·(q_t·k_u): (b,L,H)
        # carried-state contribution, weight exp(F_t + m_prev − m_t)
        w_car = jnp.exp(Fi + m_prev[:, None, :] - m_t)  # (b,L,H)
        y_num = y_num + w_car[..., None] * jnp.einsum("blhd,bhde->blhe",
                                                      qci, C_prev)
        den_car = w_car * jnp.einsum("blhd,bhd->blh", qci, n_prev)
        den = jnp.maximum(jnp.abs(den_int + den_car), jnp.exp(-m_t))
        y = y_num / den[..., None]

        # chunk-end state update (stabilized at m_state_new)
        F_L = Fi[:, -1, :]                             # (b,H)
        k_logw = F_L[:, None, :] - Fi + lici           # (b,L,H)
        m_state_new = jnp.maximum(F_L + m_prev, jnp.max(k_logw, axis=1))
        w_k = jnp.exp(k_logw - m_state_new[:, None, :])
        decay = jnp.exp(F_L + m_prev - m_state_new)
        C_new = C_prev * decay[:, :, None, None] \
            + jnp.einsum("blh,blhd,blhe->bhde", w_k, kci, vci)
        n_new = n_prev * decay[:, :, None] \
            + jnp.einsum("blh,blhd->bhd", w_k, kci)
        return (C_new, n_new, m_state_new), y

    if state is None:
        C0 = jnp.zeros((b, H, D, D), jnp.float32)
        n0 = jnp.zeros((b, H, D), jnp.float32)
        m0 = jnp.full((b, H), -30.0, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lic, 1, 0),
          jnp.moveaxis(F, 1, 0), jnp.moveaxis(logw, 1, 0))
    (Cf, nf, mf), ys = jax.lax.scan(scan_chunk, (C0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * L, H, D)
    if pad:
        y = y[:, :s]
    return y.astype(q.dtype), {"C": Cf, "n": nf, "m": mf}


def mlstm_decode_step(state, q, k, v, log_i, log_f):
    """q,k,v: (B,H,D); log_i/log_f: (B,H). Returns (y, new_state)."""
    C, n, m = (state["C"], state["n"], state["m"])
    q = q.astype(jnp.float32) / jnp.sqrt(q.shape[-1])
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, log_i)
    C_new = C * jnp.exp(log_f + m - m_new)[..., None, None] \
        + jnp.exp(log_i - m_new)[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = n * jnp.exp(log_f + m - m_new)[..., None] \
        + jnp.exp(log_i - m_new)[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    y = num / den[..., None]
    return y.astype(q.dtype), {"C": C_new, "n": n_new, "m": m_new}


def mlstm_block(params, cfg, x, state=None, decode=False):
    """Pre-LN residual mLSTM block. x: (B,S,d)."""
    d_inner, H, D = mlstm_dims(cfg)
    dt = x.dtype
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    up = xin @ params["w_up"].astype(dt)
    xi, gate = up[..., :d_inner], up[..., d_inner:]
    xi = shard(xi, "batch", None, "inner")
    xh = xi.reshape(*xi.shape[:2], H, D)               # (B,S,H,dh)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"].astype(dt))
    k = jnp.einsum("bshd,hde->bshe", xh, params["wk"].astype(dt))
    v = jnp.einsum("bshd,hde->bshe", xh, params["wv"].astype(dt))
    log_i, log_f = _mlstm_gates(params, xi)
    if decode:
        y, new_state = mlstm_decode_step(state, q[:, 0], k[:, 0], v[:, 0],
                                         log_i[:, 0], log_f[:, 0])
        y = y[:, None]
    else:
        y, new_state = mlstm_chunked(q, k, v, log_i, log_f, state=state)
    # per-head norm then merge
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * params["out_norm"]).astype(dt)
    y = y.reshape(*y.shape[:2], d_inner)
    y = y * jax.nn.silu(gate)
    out = y @ params["w_down"].astype(dt)
    return x + shard(out, "batch", None, "embed"), new_state


# ===================================================================== #
# sLSTM
# ===================================================================== #

def slstm_dims(cfg):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return h, dh


def init_slstm_block(key, cfg):
    d = cfg.d_model
    h, dh = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    ff = int(d * 4 / 3)
    return {
        "norm": init_rmsnorm(d),
        # input weights for gates i,f,z,o: (d, 4, H, Dh)
        "w_x": dense_init(ks[0], (d, 4, h, dh), in_axis_size=d),
        # recurrent block-diagonal per head: (4, H, Dh, Dh)
        "w_r": (jax.random.normal(ks[1], (4, h, dh, dh)) / jnp.sqrt(dh)
                ).astype(jnp.float32),
        "b": jnp.zeros((4, h, dh), jnp.float32),
        "w_out": dense_init(ks[2], (d, d), in_axis_size=d),
        "mlp_norm": init_rmsnorm(d),
        "mlp": init_mlp(ks[3], d, ff, "gated_silu"),
    }


def _slstm_cell(params, carry, xt):
    """One time step. carry: (c,n,h,m) each (B,H,Dh); xt: (B,4,H,Dh)."""
    c, n, hprev, m = carry
    pre = xt.astype(jnp.float32) \
        + jnp.einsum("bhd,ghde->bghe", hprev, params["w_r"]) \
        + params["b"]
    zi, zf, zz, zo = [pre[:, i] for i in range(4)]     # (B,H,Dh)
    log_i = zi                                          # exp input gate (log)
    log_f = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(log_f + m, log_i)
    i_st = jnp.exp(log_i - m_new)
    f_st = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c_new = f_st * c + i_st * z
    n_new = f_st * n + i_st
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(params, cfg, x, state=None, decode=False):
    """x: (B,S,d). Sequential lax.scan over time (sLSTM is recurrent)."""
    h, dh = slstm_dims(cfg)
    dt = x.dtype
    b, s, d = x.shape
    xin = rmsnorm(params["norm"], x, cfg.norm_eps)
    xg = jnp.einsum("bsd,dghe->bsghe", xin, params["w_x"].astype(dt))

    if state is None:
        z = jnp.zeros((b, h, dh), jnp.float32)
        carry = (z, z, z, jnp.full((b, h, dh), -30.0, jnp.float32))
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    if decode:
        carry, ht = _slstm_cell(params, carry, xg[:, 0])
        ys = ht[:, None]
    else:
        xs = jnp.moveaxis(xg, 1, 0)                    # (S,B,4,H,Dh)
        carry, ys = jax.lax.scan(
            lambda cr, xt: _slstm_cell(params, cr, xt), carry, xs)
        ys = jnp.moveaxis(ys, 0, 1)                    # (B,S,H,Dh)
    y = ys.reshape(b, -1, d).astype(dt) @ params["w_out"].astype(dt)
    x = x + y
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    # post-MLP (4/3 gated)
    x = x + mlp(params["mlp"], cfg, rmsnorm(params["mlp_norm"], x, cfg.norm_eps))
    return x, new_state
