"""Shared layer primitives: norms, MLPs, RoPE, embeddings, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = (1.0 / fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# --------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------- #

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


# --------------------------------------------------------------------- #
# MLP (gated-SiLU llama-style, or relu^2 nemotron-style)
# --------------------------------------------------------------------- #

def init_mlp(key, d_model, d_ff, mlp_type="gated_silu"):
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[2], (d_ff, d_model), in_axis_size=d_ff)}
    if mlp_type == "gated_silu":
        p["w_in"] = dense_init(ks[0], (d_model, d_ff), in_axis_size=d_model)
        p["w_gate"] = dense_init(ks[1], (d_model, d_ff), in_axis_size=d_model)
    elif mlp_type in ("relu2", "gelu"):
        p["w_in"] = dense_init(ks[0], (d_model, d_ff), in_axis_size=d_model)
    else:
        raise ValueError(mlp_type)
    return p


def mlp(params, cfg, x):
    """x: (..., d_model) -> (..., d_model)."""
    dt = x.dtype
    w_in = params["w_in"].astype(dt)
    h = x @ w_in
    if "w_gate" in params:
        g = x @ params["w_gate"].astype(dt)
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jnp.square(jax.nn.relu(h))
    h = shard(h, *(None,) * (h.ndim - 1), "ff")
    return h @ params["w_out"].astype(dt)


# --------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------- #

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (B, S, H, D); positions: (B, S) or (S,) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * freqs                       # (B,S,d/2) or (S,d/2)
    if ang.ndim == 2:                                  # (S, d/2) -> (1,S,1,d/2)
        ang = ang[None, :, None, :]
    else:                                              # (B,S,d/2) -> (B,S,1,d/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Embedding / unembedding (vocab-sharded)
# --------------------------------------------------------------------- #

def init_embed(key, vocab, d_model):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02
                      ).astype(jnp.float32)}


def embed(params, cfg, tokens):
    table = shard(params["table"].astype(cfg.act_dtype), "vocab", "embed")
    return jnp.take(table, tokens, axis=0)


def unembed(params, cfg, x, table=None):
    """Logits over the padded vocab. ``table`` reuses tied embeddings."""
    t = table if table is not None else params["table"]
    logits = x @ t.astype(x.dtype).T
    return shard(logits, "batch", None, "vocab")
