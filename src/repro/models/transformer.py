"""Block assembly and layer stacks (scan-over-layers + remat).

Block kinds:
  dense     — GQA attn + MLP                     (qwen2, minitron, yi, danube,
                                                  internvl2 backbone, zamba2's
                                                  shared block)
  dense_x   — GQA self-attn + cross-attn + MLP   (musicgen w/ text cond)
  moe       — GQA attn + MoE FFN                 (mixtral)
  mla_dense — MLA attn + dense MLP               (deepseek first-3 layers)
  mla_moe   — MLA attn + MoE FFN                 (deepseek main stack)
  mamba     — Mamba2 mixer only                  (zamba2 backbone)

Uniform stacks hold parameters with a leading layer axis and are traversed by
``lax.scan`` (one traced layer → O(1) compile time at 61 layers) with
``jax.checkpoint`` activation rematerialization around the body.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import init_mlp, init_rmsnorm, mlp, rmsnorm

ATTN_KINDS = {"dense", "dense_x", "moe"}
MLA_KINDS = {"mla_dense", "mla_moe"}


def init_block(key, cfg, kind):
    ks = jax.random.split(key, 6)
    p = {}
    if kind in ATTN_KINDS:
        p["attn_norm"] = init_rmsnorm(cfg.d_model)
        p["attn"] = attn.init_gqa(ks[0], cfg)
    elif kind in MLA_KINDS:
        p["attn_norm"] = init_rmsnorm(cfg.d_model)
        p["attn"] = attn.init_mla(ks[0], cfg)
    if kind == "dense_x":
        p["xattn_norm"] = init_rmsnorm(cfg.d_model)
        p["xattn"] = attn.init_cross_attn(ks[1], cfg)
    if kind in ("dense", "dense_x"):
        p["mlp_norm"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type)
    elif kind == "mla_dense":
        p["mlp_norm"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.dense_ff, cfg.mlp_type)
    elif kind in ("moe", "mla_moe"):
        p["moe_norm"] = init_rmsnorm(cfg.d_model)
        p["moe"] = moe_lib.init_moe(ks[3], cfg)
    if kind == "mamba":
        p["mamba_norm"] = init_rmsnorm(cfg.d_model)
        p["mamba"] = ssm_lib.init_mamba2(ks[4], cfg)
    return p


def _mix(params, cfg, kind, x, positions, mode, t=None, cache=None, cond=None):
    """Sequence-mixer sublayer. Returns (y, new_cache)."""
    h = rmsnorm(params["attn_norm"], x, cfg.norm_eps) \
        if kind not in ("mamba",) else rmsnorm(params["mamba_norm"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        if mode == "train":
            return attn.gqa_forward(params["attn"], cfg, h, positions), None
        if mode == "prefill":
            return attn.gqa_prefill(params["attn"], cfg, h, positions, cache)
        if mode == "paged_prefill":      # t carries the paged step dict
            return attn.gqa_prefill_paged(params["attn"], cfg, h, t, cache)
        if mode == "paged_decode":
            return attn.gqa_decode_paged(params["attn"], cfg, h, t, cache)
        return attn.gqa_decode(params["attn"], cfg, h, t, cache)
    if mode in ("paged_prefill", "paged_decode"):
        raise ValueError(f"paged KV serving is GQA-only; {kind} caches "
                         f"(MLA latent / SSM state) are linear-only")
    if kind in MLA_KINDS:
        if mode == "train":
            return attn.mla_forward(params["attn"], cfg, h, positions), None
        if mode == "prefill":
            return attn.mla_prefill(params["attn"], cfg, h, positions, cache)
        return attn.mla_decode(params["attn"], cfg, h, t, cache)
    if kind == "mamba":
        if mode in ("train", "prefill"):
            st = cache if mode == "prefill" else None
            y, new_state = ssm_lib.mamba2_forward(params["mamba"], cfg, h, st)
            return y, new_state
        return ssm_lib.mamba2_decode(params["mamba"], cfg, h, cache)
    raise ValueError(kind)


def block_apply(params, cfg, kind, x, positions, mode="train", t=None,
                cache=None, cond=None):
    """One block. Returns (x, aux_loss, new_cache)."""
    y, new_cache = _mix(params, cfg, kind, x, positions, mode, t, cache, cond)
    x = x + y
    aux = jnp.float32(0.0)
    if kind == "dense_x" and cond is not None:
        h = rmsnorm(params["xattn_norm"], x, cfg.norm_eps)
        x = x + attn.cross_attn(params["xattn"], cfg, h, cond)
    if "mlp" in params:
        h = rmsnorm(params["mlp_norm"], x, cfg.norm_eps)
        x = x + mlp(params["mlp"], cfg, h)
    elif "moe" in params:
        h = rmsnorm(params["moe_norm"], x, cfg.norm_eps)
        y, aux = moe_lib.moe_ffn(params["moe"], cfg, h)
        x = x + y
    return x, aux, new_cache


def init_stack(key, cfg, kind, n_layers):
    """Stacked params with leading layer axis (for lax.scan)."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_block(k, cfg, kind))(keys)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def stack_apply(stack_params, cfg, kind, x, positions, mode="train", t=None,
                cache=None, cond=None):
    """Scan the stack. cache (if any) carries a leading layer axis.

    Returns (x, aux_total, new_cache)."""

    def body(carry, layer_in):
        xc, aux = carry
        lp, lcache = layer_in
        if cfg.carry_barrier:
            xc = jax.lax.optimization_barrier(xc)
        xc, a, new_cache = block_apply(lp, cfg, kind, xc, positions, mode,
                                       t, lcache, cond)
        return (xc, aux + a), new_cache

    body = _remat(body, cfg)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       (stack_params, cache))
    return x, aux, new_cache


def stack_apply_nocache(stack_params, cfg, kind, x, positions, cond=None):
    def body(carry, lp):
        xc, aux = carry
        if cfg.carry_barrier:
            xc = jax.lax.optimization_barrier(xc)
        xc, a, _ = block_apply(lp, cfg, kind, xc, positions, "train",
                               cond=cond)
        return (xc, aux + a), None

    body = _remat(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stack_params)
    return x, aux
