"""The language model: init / train / prefill / decode across all families.

Families (DESIGN.md §4): dense (qwen2, minitron, yi, danube), vlm (internvl2:
patch-embedding stub + dense backbone), audio (musicgen: frame-embedding stub,
optional cross-attn conditioning), moe (mixtral), mla+moe+MTP (deepseek-v3),
hybrid (zamba2: Mamba2 backbone + shared attention block), ssm (xlstm).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models import cache as cache_lib
from repro.models import transformer as tfm
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (dense_init, embed, init_embed, init_rmsnorm,
                                 rmsnorm, unembed)


# ===================================================================== #
# init
# ===================================================================== #

def _hybrid_segments(cfg):
    """zamba2: contiguous mamba runs, shared attn block after each full run."""
    k = cfg.shared_attn_every
    segs, start = [], 0
    while start < cfg.n_layers:
        end = min(start + k, cfg.n_layers)
        segs.append((start, end, end - start == k))
        start = end
    return segs


def _xlstm_segments(cfg):
    """(n_mlstm_before, has_slstm) groups: sLSTM every ``slstm_every`` blocks."""
    k = cfg.slstm_every
    if k <= 0:
        return [(cfg.n_layers, False)]
    segs = []
    remaining = cfg.n_layers
    while remaining > 0:
        if remaining >= k:
            segs.append((k - 1, True))
            remaining -= k
        else:
            segs.append((remaining, False))
            remaining = 0
    return segs


def init_params(cfg, key):
    ks = jax.random.split(key, 8)
    p = {}
    if not cfg.embeds_input:
        p["embed"] = init_embed(ks[0], cfg.padded_vocab, cfg.d_model)
    if cfg.embeds_input or not cfg.tie_embeddings:
        p["head"] = init_embed(ks[1], cfg.padded_vocab, cfg.d_model)
    p["final_norm"] = init_rmsnorm(cfg.d_model)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        kind = "dense_x" if cfg.cross_attn else "dense"
        p["main"] = tfm.init_stack(ks[2], cfg, kind, cfg.n_layers)
    elif fam == "moe" and not cfg.mla:
        p["main"] = tfm.init_stack(ks[2], cfg, "moe", cfg.n_layers)
    elif fam == "moe" and cfg.mla:  # deepseek-v3
        nd = cfg.first_k_dense
        p["dense"] = tfm.init_stack(ks[2], cfg, "mla_dense", nd)
        p["moe"] = tfm.init_stack(ks[3], cfg, "mla_moe", cfg.n_layers - nd)
        if cfg.mtp:
            p["mtp_proj"] = dense_init(ks[4], (2 * cfg.d_model, cfg.d_model),
                                       in_axis_size=2 * cfg.d_model)
            p["mtp_norm"] = init_rmsnorm(cfg.d_model)
            p["mtp_block"] = tfm.init_block(ks[5], cfg, "mla_dense")
    elif fam == "hybrid":  # zamba2
        p["mamba"] = tfm.init_stack(ks[2], cfg, "mamba", cfg.n_layers)
        p["shared"] = tfm.init_block(ks[3], cfg, "dense")
    elif fam == "ssm":  # xlstm
        segs = _xlstm_segments(cfg)
        n_m = sum(s[0] for s in segs)
        n_s = sum(1 for s in segs if s[1])
        keys_m = jax.random.split(ks[2], max(n_m, 1))
        p["mlstm"] = jax.vmap(
            lambda k: xlstm_lib.init_mlstm_block(k, cfg))(keys_m)
        if n_s:
            keys_s = jax.random.split(ks[3], n_s)
            p["slstm"] = jax.vmap(
                lambda k: xlstm_lib.init_slstm_block(k, cfg))(keys_s)
    else:
        raise ValueError(fam)
    if cfg.param_dtype != "float32":   # e.g. bf16 params (DESIGN.md §5)
        pd = jnp.dtype(cfg.param_dtype)
        p = jax.tree.map(
            lambda x: x.astype(pd) if x.dtype == jnp.float32 else x, p)
    return p


# ===================================================================== #
# caches
# ===================================================================== #

def init_cache(cfg, batch, max_len):
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        return {"main": cache_lib.init_kv_cache(cfg, batch, max_len)}
    if fam == "moe" and not cfg.mla:
        return {"main": cache_lib.init_kv_cache(cfg, batch, max_len)}
    if fam == "moe" and cfg.mla:
        nd = cfg.first_k_dense
        return {"dense": cache_lib.init_mla_cache(cfg, batch, max_len, nd),
                "moe": cache_lib.init_mla_cache(cfg, batch, max_len,
                                                cfg.n_layers - nd)}
    if fam == "hybrid":
        # shared attention block: one KV cache per application
        n_apps = sum(1 for s in _hybrid_segments(cfg) if s[2])
        kv = {k: jnp.zeros((n_apps,) + v.shape[1:], v.dtype)
              for k, v in cache_lib.init_kv_cache(cfg, batch, max_len).items()}
        return {"mamba": cache_lib.init_ssm_state(cfg, batch),
                "shared": kv}
    if fam == "ssm":
        segs = _xlstm_segments(cfg)
        n_m = sum(s[0] for s in segs)
        n_s = sum(1 for s in segs if s[1])
        c = {"mlstm": cache_lib.init_mlstm_state(cfg, batch, n_m)}
        if n_s:
            c["slstm"] = cache_lib.init_slstm_state(cfg, batch, n_s)
        return c
    raise ValueError(fam)


def init_paged_cache(cfg, n_blocks, block_size):
    """Paged KV pool pytree for the continuous-batching serving engine.

    Only GQA KV families page (dense/vlm/audio backbones and non-MLA moe):
    their cache is per-token K/V rows that a block table can scatter across
    a shared pool.  MLA latent and SSM/xLSTM state caches are linear-only —
    asking for a paged cache there raises so the engine fails at
    construction, not mid-serve.
    """
    fam = cfg.family
    if fam in ("dense", "vlm", "audio") or (fam == "moe" and not cfg.mla):
        return {"main": cache_lib.init_paged_kv_cache(cfg, n_blocks,
                                                      block_size)}
    raise NotImplementedError(
        f"paged KV serving supports GQA families; {fam} caches "
        f"(MLA latent / SSM state) are linear-only")


# ===================================================================== #
# trunk
# ===================================================================== #

def _slice_stack(stack, a, b):
    return jax.tree.map(lambda x: x[a:b], stack)


def _slice_layer(stack, i):
    return jax.tree.map(lambda x: x[i], stack)


def _set_layer(stack, i, layer):
    return jax.tree.map(lambda s, l: s.at[i].set(l), stack, layer)


def trunk(params, cfg, x, positions, mode="train", t=None, caches=None,
          cond=None):
    """Apply the model trunk. Returns (x, aux, new_caches)."""
    fam = cfg.family
    aux = jnp.float32(0.0)
    new_caches = {} if caches is not None else None
    C = caches or {}

    if fam in ("dense", "vlm", "audio", "moe") and not cfg.mla:
        kind = "dense_x" if cfg.cross_attn else (
            "moe" if fam == "moe" else "dense")
        x, aux, nc = tfm.stack_apply(params["main"], cfg, kind, x, positions,
                                     mode, t, C.get("main"), cond)
        if new_caches is not None:
            new_caches["main"] = nc

    elif fam == "moe" and cfg.mla:
        x, a1, nc1 = tfm.stack_apply(params["dense"], cfg, "mla_dense", x,
                                     positions, mode, t, C.get("dense"))
        x, a2, nc2 = tfm.stack_apply(params["moe"], cfg, "mla_moe", x,
                                     positions, mode, t, C.get("moe"))
        aux = a1 + a2
        if new_caches is not None:
            new_caches.update(dense=nc1, moe=nc2)

    elif fam == "hybrid":
        segs = _hybrid_segments(cfg)
        mamba_cache = C.get("mamba")
        new_m = mamba_cache
        new_s = C.get("shared")
        app = 0
        for (a, b, full) in segs:
            seg_params = _slice_stack(params["mamba"], a, b)
            seg_cache = None if mamba_cache is None else _slice_stack(
                mamba_cache, a, b)
            x, ax, nc = tfm.stack_apply(seg_params, cfg, "mamba", x,
                                        positions, mode, t, seg_cache)
            aux = aux + ax
            if new_caches is not None and nc is not None:
                new_m = jax.tree.map(
                    lambda s, u, a=a, b=b: s.at[a:b].set(u), new_m, nc)
            if full:
                sc = None if new_s is None else _slice_layer(C["shared"], app)
                x, ax, ncs = tfm.block_apply(params["shared"], cfg, "dense",
                                             x, positions, mode, t, sc)
                aux = aux + ax
                if new_caches is not None and ncs is not None:
                    new_s = _set_layer(new_s, app, ncs)
                app += 1
        if new_caches is not None:
            new_caches.update(mamba=new_m, shared=new_s)

    elif fam == "ssm":
        segs = _xlstm_segments(cfg)
        mi, si = 0, 0
        new_ml = C.get("mlstm")
        new_sl = C.get("slstm")
        decode = mode == "decode"
        for (n_m, has_s) in segs:
            for j in range(n_m):
                lp = _slice_layer(params["mlstm"], mi)
                st = None if new_ml is None else _slice_layer(new_ml, mi)
                x, ns = xlstm_lib.mlstm_block(lp, cfg, x, st, decode=decode)
                if new_caches is not None and ns is not None:
                    new_ml = jax.tree.map(lambda s, u, i=mi: s.at[i].set(u),
                                          new_ml, ns)
                mi += 1
            if has_s:
                lp = _slice_layer(params["slstm"], si)
                st = None if new_sl is None else _slice_layer(new_sl, si)
                x, ns = xlstm_lib.slstm_block(lp, cfg, x, st, decode=decode)
                if new_caches is not None and ns is not None:
                    new_sl = jax.tree.map(lambda s, u, i=si: s.at[i].set(u),
                                          new_sl, ns)
                si += 1
        if new_caches is not None:
            new_caches.update(mlstm=new_ml)
            if new_sl is not None:
                new_caches["slstm"] = new_sl
    else:
        raise ValueError(fam)
    return x, aux, new_caches


# ===================================================================== #
# embedding / head helpers
# ===================================================================== #

def embed_inputs(params, cfg, batch):
    """Token / frame / patch embedding composition. Returns (x, cond)."""
    cond = batch.get("cond")
    if cfg.embeds_input:                      # musicgen: EnCodec-frame stub
        x = batch["embeds"].astype(cfg.act_dtype)
    elif cfg.n_img_tokens and "image_embeds" in batch:  # internvl2 ViT stub
        tok_emb = embed(params["embed"], cfg, batch["tokens"])
        img = batch["image_embeds"].astype(cfg.act_dtype)
        x = jnp.concatenate([img, tok_emb], axis=1)     # decode steps: text-only
    else:
        x = embed(params["embed"], cfg, batch["tokens"])
    return shard(x, "batch", "seq", "embed"), cond


def head_logits(params, cfg, x):
    table = params["head"]["table"] if "head" in params \
        else params["embed"]["table"]
    return unembed(None, cfg, x, table=table)


def _xent(logits, labels, vocab_size):
    """Masked next-token CE + z-loss. labels < 0 are ignored.

    The gold logit is extracted with an iota-compare + masked reduce (not
    ``take_along_axis``): a per-token gather along the vocab-sharded axis
    would make GSPMD all-gather the full fp32 logits (~40 GiB/device at
    qwen2's vocab) while the masked reduce partitions cleanly into a psum.
    """
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0)
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    hit = jnp.arange(v, dtype=jnp.int32)[None, None, :] == safe[..., None]
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    ce = (lse - gold) * mask
    z = 1e-4 * jnp.square(lse) * mask
    n = jnp.maximum(mask.sum(), 1)
    return ce.sum() / n, z.sum() / n


# ===================================================================== #
# top-level steps
# ===================================================================== #

def train_loss(params, cfg, batch):
    """(loss, metrics). labels[t] is the target for position t."""
    x, cond = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x, aux, _ = trunk(params, cfg, x, positions, "train", cond=cond)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = head_logits(params, cfg, x)
    labels = batch["labels"]
    ce, z = _xent(logits, labels, cfg.vocab_size)
    loss = ce + z + aux

    metrics = {"ce": ce, "z_loss": z, "aux_loss": aux}
    if cfg.mtp and "mtp_block" in params:
        # DeepSeek MTP: predict t+2 from [h_t ; emb(tok_{t+1})]
        emb_next = embed(params["embed"], cfg, batch["tokens"])[:, 1:]
        h_prev = x[:, :-1]
        hcat = jnp.concatenate([h_prev, emb_next], axis=-1)
        hm = hcat @ params["mtp_proj"].astype(hcat.dtype)
        hm = rmsnorm(params["mtp_norm"], hm, cfg.norm_eps)
        hm, _, _ = tfm.block_apply(params["mtp_block"], cfg, "mla_dense", hm,
                                   positions[:-1], "train")
        mtp_logits = head_logits(params, cfg, hm)
        mtp_ce, _ = _xent(mtp_logits, labels[:, 1:], cfg.vocab_size)
        loss = loss + 0.3 * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, cfg, batch, max_len):
    """Fill caches from a prompt. Returns (last_logits, caches)."""
    x, cond = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    caches = init_cache(cfg, b, max_len)
    x, _, caches = trunk(params, cfg, x, positions, "prefill", caches=caches,
                         cond=cond)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = head_logits(params, cfg, x[:, -1:])
    return logits, caches


def decode_step(params, cfg, batch, caches, t):
    """One decode step at position ``t``. Returns (logits, new_caches)."""
    x, cond = embed_inputs(params, cfg, batch)          # (B,1,d)
    positions = jnp.full((1,), t, jnp.int32)
    x, _, caches = trunk(params, cfg, x, positions, "decode", t=t,
                         caches=caches, cond=cond)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = head_logits(params, cfg, x)
    return logits, caches


def prefill_chunk_paged(params, cfg, batch, caches, step):
    """Prefill one fixed-size chunk of up to B sequences into the paged
    pool.

    batch["tokens"] is (B, C) — one chunk per prefilling request; ``step``
    is the per-chunk bookkeeping dict (see
    ``attention.gqa_prefill_paged``).  Returns (logits (B, C, V),
    new_caches) — the caller picks each row's last *real* column when that
    prompt is fully consumed.  Chunk shape is static, so the engine pays
    one compile regardless of prompt length or how many requests share the
    dispatch.
    """
    x, cond = embed_inputs(params, cfg, batch)
    x, _, caches = trunk(params, cfg, x, step["pos"], "paged_prefill",
                         t=step, caches=caches, cond=cond)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return head_logits(params, cfg, x), caches


def decode_step_paged(params, cfg, batch, caches, step):
    """One continuous-batch decode step over every serving slot.

    batch["tokens"] is (B, 1) with B = max_slots; each slot advances at its
    own position ``step["pos"][b]`` (idle slots are masked, their writes go
    to the scratch block).  Returns (logits (B, 1, V), new_caches).
    """
    x, cond = embed_inputs(params, cfg, batch)
    x, _, caches = trunk(params, cfg, x, step["pos"], "paged_decode",
                         t=step, caches=caches, cond=cond)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return head_logits(params, cfg, x), caches


# ===================================================================== #
# analytics
# ===================================================================== #

def count_params(cfg, active_only=False) -> int:
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if active_only and cfg.n_experts:
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        per_layer_expert = cfg.n_experts * 3 * cfg.d_model * cfg.moe_ff
        active_per_layer = cfg.top_k * 3 * cfg.d_model * cfg.moe_ff
        total -= n_moe_layers * (per_layer_expert - active_per_layer)
    return total
