"""Attention: GQA (optionally sliding-window, optionally biased), cross-attn,
and MLA (DeepSeek multi-head latent attention with absorbed decode).

Layouts: activations (B, S, d_model); q (B, S, H, D); k/v (B, T, KH, D).
Softmax in fp32.  The XLA-native paths here are the dry-run/roofline
implementations; the Pallas kernels in ``repro.kernels`` implement the same
contracts for TPU execution (tests cross-check both against each other).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models import cache as cache_lib
from repro.models.layers import apply_rope, dense_init

NEG_INF = -2.0 ** 30  # large-negative in fp32, safe under bf16 casts


# ===================================================================== #
# GQA
# ===================================================================== #

def init_gqa(key, cfg):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), in_axis_size=d),
        "wk": dense_init(ks[1], (d, kh, hd), in_axis_size=d),
        "wv": dense_init(ks[2], (d, kh, hd), in_axis_size=d),
        "wo": dense_init(ks[3], (h, hd, d), in_axis_size=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kh, hd), jnp.float32)
        p["bv"] = jnp.zeros((kh, hd), jnp.float32)
    return p


def _qkv(params, cfg, x, positions, rope=True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_kv_heads):
    """Grouped scaled-dot-product attention. mask: broadcastable to
    (B, KH, G, S, T) or (B, 1, 1, S, T).  v's feature dim may differ from
    q/k's (MLA)."""
    b, s, h, d = q.shape
    dv = v.shape[-1]
    kh = n_kv_heads
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return o.reshape(b, s, h, dv)


def blockwise_sdpa(q, k, v, n_kv_heads, *, window=None, q_block=512,
                   kv_block=512, scale=None):
    """Flash-style causal attention in pure JAX (the XLA-native twin of
    kernels/flash_attention).

    Structure chosen for O(S·block) *backward* memory: an unrolled outer
    loop over query blocks — each wrapped in ``jax.checkpoint`` so its
    online-softmax state is recomputed rather than saved — with an inner
    ``lax.scan`` over exactly that block's causal∩window KV range (static
    per block ⇒ no wasted FLOPs on fully-masked blocks).  A single scan over
    (q,kv) pairs would carry the full accumulator and make autodiff save
    O(S²/block) residuals — measured at 43 GiB/device on qwen2 train_4k
    before this restructuring (EXPERIMENTS.md §Perf, iteration 0).

    q: (B,S,H,Dk); k: (B,T,KH,Dk); v: (B,T,KH,Dv). Returns (B,S,H,Dv).
    """
    b, s, h, dk = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    kh = n_kv_heads
    g = h // kh
    lq = min(q_block, s)
    lk = min(kv_block, t)
    nq, nk = -(-s // lq), -(-t // lk)
    if s % lq or t % lk:  # pad to block multiples (masked out below)
        q = jnp.pad(q, ((0, 0), (0, nq * lq - s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, nk * lk - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * lk - t), (0, 0), (0, 0)))
    sc = scale if scale is not None else 1.0 / np.sqrt(dk)

    kb = k.reshape(b, nk, lk, kh, dk)
    vb = v.reshape(b, nk, lk, kh, dv)
    diag_offset = t - s  # query i attends keys <= i + offset (prefill: t==s)

    def q_block_attend(qblk, kb, vb, qi):
        """One query block vs its static KV range. qblk: (B,Lq,KH,G,Dk)."""
        q_lo = qi * lq + diag_offset
        k_hi_block = min(nk - 1, (q_lo + lq - 1) // lk)       # causal bound
        k_lo_block = 0
        if window is not None:
            k_lo_block = max(0, (q_lo - window + 1) // lk)
        kis = jnp.arange(k_lo_block, k_hi_block + 1)

        @jax.checkpoint   # backward recomputes p: never save (Lq,Lk) probs
        def inner(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kb, ki, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vb, ki, 1, keepdims=False)
            logits = jnp.einsum("blkgd,bukd->bkglu", qblk, kblk
                                ).astype(jnp.float32) * sc   # (B,KH,G,Lq,Lk)
            qpos = q_lo + jnp.arange(lq)
            kpos = ki * lk + jnp.arange(lk)
            msk = kpos[None, :] <= qpos[:, None]
            msk &= kpos[None, :] < t
            if window is not None:
                msk &= (qpos[:, None] - kpos[None, :]) < window
            logits = jnp.where(msk[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkglu,bukd->bkgld", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((b, kh, g, lq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, lq), jnp.float32)
        a0 = jnp.zeros((b, kh, g, lq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), kis)
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KH,G,Lq,Dv)
        return jnp.moveaxis(out, 3, 1)                        # (B,Lq,KH,G,Dv)

    attend = jax.checkpoint(q_block_attend, static_argnums=(3,))
    qb = q.reshape(b, nq, lq, kh, g, dk)
    outs = [attend(qb[:, qi], kb, vb, qi) for qi in range(nq)]
    out = jnp.stack(outs, axis=1).reshape(b, nq * lq, h, dv)[:, :s]
    return out.astype(q.dtype)


# naive-vs-blockwise dispatch threshold (elements of the S×T score matrix)
_BLOCKWISE_MIN_SCORES = 2048 * 2048


def causal_mask(s, t_offset=0, window=None):
    """(S, T) mask for queries at positions t_offset..t_offset+s-1 over keys
    at 0..t_offset+s-1 (prefill: t_offset=0, square)."""
    t = t_offset + s
    qi = jnp.arange(s)[:, None] + t_offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window is not None:
        m &= (qi - kj) < window
    return m


def _self_attention(cfg, q, k, v):
    """Dispatch: blockwise (flash-style) for long sequences, naive for tiny."""
    s, t = q.shape[1], k.shape[1]
    if s * t >= _BLOCKWISE_MIN_SCORES:
        return blockwise_sdpa(q, k, v, cfg.n_kv_heads, window=cfg.window)
    mask = causal_mask(s, window=cfg.window)[None, None, None]
    return _sdpa(q, k, v, mask, cfg.n_kv_heads)


def gqa_forward(params, cfg, x, positions):
    """Train/prefill self-attention over the full sequence.

    q/k/v are constrained on the ``batch_attn`` logical axis: by default it
    equals ``batch``, but when the head count cannot shard over the model
    axis (qwen2's 12, internvl2's 14) the hillclimb rules point it at
    ("data","model") so the attention *batch* spreads over the otherwise-
    idle model ranks instead of replicating the whole attention computation
    16× (EXPERIMENTS.md §Perf cell A).
    """
    q, k, v = _qkv(params, cfg, x, positions)
    q = shard(q, "batch_attn", "seq_attn", "heads", None)
    k = shard(k, "batch_attn", "seq", "kv_heads", None)
    v = shard(v, "batch_attn", "seq", "kv_heads", None)
    o = _self_attention(cfg, q, k, v)
    o = shard(o, "batch_attn", "seq_attn", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return shard(y, "batch", "seq", "embed")


def gqa_prefill(params, cfg, x, positions, kv_cache_layer):
    """Prefill that also fills the layer's KV cache (window-aware)."""
    q, k, v = _qkv(params, cfg, x, positions)
    s = x.shape[1]
    o = _self_attention(cfg, q, k, v)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))

    cache_len = kv_cache_layer["k"].shape[1]  # (B, S_c, KH, D) per-layer slice
    pos = positions if positions.ndim == 1 else positions[0]
    if cfg.window is None or s <= cache_len:
        n = min(s, cache_len)
        new_k = jax.lax.dynamic_update_slice_in_dim(
            kv_cache_layer["k"], k[:, :n], 0, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            kv_cache_layer["v"], v[:, :n], 0, axis=1)
        slot = jnp.full((cache_len,), -1, jnp.int32)
        slot = jax.lax.dynamic_update_slice_in_dim(
            slot, pos[:n].astype(jnp.int32), 0, axis=0)
    else:  # SWA ring: last `window` keys, each at slot (position % window)
        pos_last = pos[-cache_len:].astype(jnp.int32)
        idx = pos_last % cache_len
        new_k = jnp.zeros_like(kv_cache_layer["k"]).at[:, idx].set(k[:, -cache_len:])
        new_v = jnp.zeros_like(kv_cache_layer["v"]).at[:, idx].set(v[:, -cache_len:])
        slot = jnp.full((cache_len,), -1, jnp.int32).at[idx].set(pos_last)
    new_cache = {"k": new_k, "v": new_v, "slot_pos": slot}
    return shard(y, "batch", "seq", "embed"), new_cache


def gqa_decode(params, cfg, x, t, kv_cache_layer):
    """One-token decode against the cache. x: (B, 1, d); t: scalar position."""
    b = x.shape[0]
    pos = jnp.full((b, 1), t, jnp.int32)
    q, k, v = _qkv(params, cfg, x, pos)

    cache = kv_cache_layer
    s_c = cache["k"].shape[1]
    w = cache_lib.slot_write_index(cache["slot_pos"], t, cfg.window)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, w, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, w, axis=1)
    new_slot = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], jnp.full((1,), t, jnp.int32), w, axis=0)

    new_k = shard(new_k, "batch", "kv_seq", "kv_heads", None)
    new_v = shard(new_v, "batch", "kv_seq", "kv_heads", None)
    mask = cache_lib.valid_mask(new_slot, t, cfg.window)  # (S_c,)
    o = _sdpa(q, new_k, new_v, mask[None, None, None, None, :], cfg.n_kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return y, {"k": new_k, "v": new_v, "slot_pos": new_slot}


def gqa_decode_paged(params, cfg, x, step, pool_layer):
    """Continuous-batch decode: one token per slot against the paged pool.

    x: (B, 1, d) with B = max_slots (idle slots carry garbage rows);
    pool_layer: {"k","v"} of shape (P, KH, D) — the flat token-row pool.
    ``step`` carries per-sequence bookkeeping (host-built, static shapes):
      pos    (B,)   int32 — this slot's decode position t (-1 = idle);
      write  (B,)   int32 — flat pool row for the new K/V (idle → scratch);
      gather (B, S) int32 — pool rows in position order per slot's table;
      mask   (B, S) bool  — paged_valid_mask(pos, S, window) for live slots.
    Unlike :func:`gqa_decode`, positions differ per sequence — the batch is
    continuous, so there is no shared scalar t.
    """
    pos = jnp.maximum(step["pos"], 0)[:, None]            # (B, 1)
    q, k, v = _qkv(params, cfg, x, pos)
    new_k = pool_layer["k"].at[step["write"]].set(k[:, 0])
    new_v = pool_layer["v"].at[step["write"]].set(v[:, 0])
    kg = jnp.take(new_k, step["gather"], axis=0)          # (B, S, KH, D)
    vg = jnp.take(new_v, step["gather"], axis=0)
    if jax.default_backend() == "tpu":
        from repro.kernels.decode_attention.ops import decode_attention
        o = decode_attention(q, kg, vg, step["mask"],
                             n_kv_heads=cfg.n_kv_heads)
    else:
        o = _sdpa(q, kg, vg, step["mask"][:, None, None, None, :],
                  cfg.n_kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return y, {"k": new_k, "v": new_v}


def gqa_prefill_paged(params, cfg, x, step, pool_layer):
    """Batched chunked prefill into the paged pool.

    x: (B, C, d) — one fixed-size chunk per prefilling request (B is the
    prefill batch width; idle rows and trailing pad rows are allowed);
    ``step``:
      pos    (B, C)    int32 — absolute position per chunk row (-1 = pad);
      write  (B, C)    int32 — flat pool row per chunk row (pad → scratch);
      gather (B, S)    int32 — each sequence's pool rows in position order;
      mask   (B, C, S) bool  — causal/window validity per chunk row.
    Sequences never share pool blocks, so the whole batch's K/V scatters
    into the pool in one op before the per-sequence gathers; row (b, i)
    attends exactly its own already-written prefix 0..pos[b, i] (the mask
    enforces causality within the chunk, and pad/idle rows only ever touch
    the scratch block).
    """
    pos = jnp.maximum(step["pos"], 0)                     # (B, C)
    q, k, v = _qkv(params, cfg, x, pos)
    kh, hd = k.shape[-2], k.shape[-1]
    flat = step["write"].reshape(-1)
    new_k = pool_layer["k"].at[flat].set(k.reshape(-1, kh, hd))
    new_v = pool_layer["v"].at[flat].set(v.reshape(-1, kh, hd))
    kg = jnp.take(new_k, step["gather"], axis=0)          # (B, S, KH, D)
    vg = jnp.take(new_v, step["gather"], axis=0)
    o = _sdpa(q, kg, vg, step["mask"][:, None, None], cfg.n_kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return y, {"k": new_k, "v": new_v}


# ===================================================================== #
# Cross-attention (musicgen text conditioning; no cache, no causal mask)
# ===================================================================== #

def init_cross_attn(key, cfg):
    return init_gqa(key, cfg)


def cross_attn(params, cfg, x, cond):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bcd,dhk->bchk", cond, params["wk"].astype(dt))
    v = jnp.einsum("bcd,dhk->bchk", cond, params["wv"].astype(dt))
    o = _sdpa(q, k, v, jnp.bool_(True), cfg.n_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))


# ===================================================================== #
# MLA — multi-head latent attention (DeepSeek-V3)
# ===================================================================== #

def init_mla(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, cfg.q_lora_rank), in_axis_size=d),
        "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.float32),
        "w_uq": dense_init(ks[1], (cfg.q_lora_rank,
                                   h, cfg.qk_nope_dim + cfg.qk_rope_dim),
                           in_axis_size=cfg.q_lora_rank),
        "w_dkv": dense_init(ks[2], (d, cfg.kv_lora_rank), in_axis_size=d),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
        "w_kr": dense_init(ks[3], (d, cfg.qk_rope_dim), in_axis_size=d),
        "w_uk": dense_init(ks[4], (cfg.kv_lora_rank, h, cfg.qk_nope_dim),
                           in_axis_size=cfg.kv_lora_rank),
        "w_uv": dense_init(ks[5], (cfg.kv_lora_rank, h, cfg.v_head_dim),
                           in_axis_size=cfg.kv_lora_rank),
        "wo": dense_init(ks[6], (h, cfg.v_head_dim, d),
                         in_axis_size=h * cfg.v_head_dim),
    }


def _mla_q(params, cfg, x, positions):
    from repro.models.layers import rmsnorm
    dt = x.dtype
    cq = rmsnorm({"scale": params["q_norm"]}, x @ params["w_dq"].astype(dt))
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params, cfg, x, positions):
    from repro.models.layers import rmsnorm
    dt = x.dtype
    ckv = rmsnorm({"scale": params["kv_norm"]}, x @ params["w_dkv"].astype(dt))
    kr = apply_rope((x @ params["w_kr"].astype(dt))[:, :, None, :],
                    positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def mla_forward(params, cfg, x, positions):
    """Train/prefill: expand the latent into per-head K/V (flop-optimal when
    S is large and every key attends), then flash-style blockwise attention."""
    dt = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv, kr = _mla_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("btr,rhk->bthk", ckv, params["w_uk"].astype(dt))
    v = jnp.einsum("btr,rhk->bthk", ckv, params["w_uv"].astype(dt))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)       # (B,S,H,192)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, cfg.qk_rope_dim))],
        axis=-1)
    # seq_attn (not seq): under sequence parallelism the residual stream is
    # seq-sharded but attention needs the full sequence per head shard —
    # the None here forces the Megatron-SP gather at the section boundary.
    q_full = shard(q_full, "batch", "seq_attn", "heads", None)
    k_full = shard(k_full, "batch", "seq_attn", "heads", None)
    v = shard(v, "batch", "seq_attn", "heads", None)
    if s * s >= _BLOCKWISE_MIN_SCORES:
        o = blockwise_sdpa(q_full, k_full, v, h)
    else:
        mask = causal_mask(s)[None, None, None]
        o = _sdpa(q_full, k_full, v, mask, h)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    return shard(y, "batch", "seq", "embed")


def mla_prefill(params, cfg, x, positions, mla_cache_layer):
    y = mla_forward(params, cfg, x, positions)
    ckv, kr = _mla_latent(params, cfg, x, positions)
    s = x.shape[1]
    cache_len = mla_cache_layer["ckv"].shape[1]
    new_ckv = jax.lax.dynamic_update_slice_in_dim(
        mla_cache_layer["ckv"], ckv, 0, axis=1)
    new_kr = jax.lax.dynamic_update_slice_in_dim(
        mla_cache_layer["krope"], kr, 0, axis=1)
    pos = positions if positions.ndim == 1 else positions[0]
    slot = jnp.full((cache_len,), -1, jnp.int32)
    slot = jax.lax.dynamic_update_slice_in_dim(
        slot, pos.astype(jnp.int32), 0, axis=0)
    return y, {"ckv": new_ckv, "krope": new_kr, "slot_pos": slot}


def mla_decode(params, cfg, x, t, mla_cache_layer):
    """Absorbed decode: attention runs in the 512-d latent space; the cache
    stores (kv_lora_rank + qk_rope_dim) = 576 bytes-per-token-per-layer of
    bf16 — the paper-relevant memory-bound win of MLA."""
    dt = x.dtype
    b = x.shape[0]
    pos = jnp.full((b, 1), t, jnp.int32)
    q_nope, q_rope = _mla_q(params, cfg, x, pos)          # (B,1,H,·)
    ckv_new, kr_new = _mla_latent(params, cfg, x, pos)    # (B,1,R), (B,1,Dr)

    cache = mla_cache_layer
    new_ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, t, axis=1)
    new_kr = jax.lax.dynamic_update_slice_in_dim(cache["krope"], kr_new, t, axis=1)
    new_slot = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], jnp.full((1,), t, jnp.int32), t, axis=0)
    new_ckv = shard(new_ckv, "batch", "kv_seq", None)
    new_kr = shard(new_kr, "batch", "kv_seq", None)

    # Absorb W_uk into the query: q_lat (B,1,H,R)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(dt))
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, new_ckv)
              + jnp.einsum("bshk,btk->bhst", q_rope, new_kr)).astype(jnp.float32)
    mask = cache_lib.valid_mask(new_slot, t, None)[None, None, None, :]
    logits = jnp.where(mask, logits * scale, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhst,btr->bshr", w, new_ckv)       # (B,1,H,R)
    # Expand through W_uv then project out.
    o = jnp.einsum("bshr,rhk->bshk", o_lat, params["w_uv"].astype(dt))
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    return y, {"ckv": new_ckv, "krope": new_kr, "slot_pos": new_slot}
