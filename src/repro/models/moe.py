"""Mixture-of-experts FFN with group-local, capacity-bounded gather dispatch.

Dispatch strategy (TPU adaptation, DESIGN.md §4): a one-hot dispatch einsum
is O(T·E·C) memory — infeasible at deepseek scale (1M tokens × 256 experts) —
and ragged grouped matmul shards poorly under GSPMD.  Instead tokens are
split into ``cfg.moe_groups`` groups (the launcher sets groups = number of
data shards, so a group == one device's tokens, exactly like per-device
expert-parallel dispatch), and within each group every expert *gathers* its
top-C tokens: an (E, C) index matrix drives a gather → batched expert matmul
(G,E,C,d)×(E,d,f) → scatter-add combine.  All dims static and MXU-aligned;
the G axis shards over (pod, data) and the E axis over model (EP, deepseek),
or E stays replicated with the expert FF dim sharded instead (expert-TP,
mixtral's 8 experts < 16-wide model axis).

Tokens beyond an expert's per-group capacity C = ceil(Tg·k/E·capacity_factor)
are dropped (their combine weight never lands) — the standard capacity trade,
kept rare by the Switch-style aux load-balance loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import dense_init


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), in_axis_size=d),
        "w_in": dense_init(ks[1], (e, d, f), in_axis_size=d),
        "w_gate": dense_init(ks[2], (e, d, f), in_axis_size=d),
        "w_out": dense_init(ks[3], (e, f, d), in_axis_size=f),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_in": dense_init(kss[0], (d, fs), in_axis_size=d),
            "w_gate": dense_init(kss[1], (d, fs), in_axis_size=d),
            "w_out": dense_init(kss[2], (fs, d), in_axis_size=fs),
        }
    return p


def capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    c = min(max(8, -(-c // 8) * 8), tokens_per_group)  # 8-aligned, <= Tg
    return c


def moe_ffn(params, cfg, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    dt = x.dtype
    b, s, d = x.shape
    t = b * s
    g = max(1, min(getattr(cfg, "moe_groups", 1), t))
    while t % g:
        g -= 1
    tg = t // g
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(tg, cfg)

    xt = x.reshape(g, tg, d)
    xt = shard(xt, "moe_groups", None, "embed")

    # --- route (per group) -------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xt,
                        params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (G,Tg,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * Σ_e mean-prob_e · mean-assignment_e
    me = probs.mean(axis=1)                                        # (G,E)
    ce = jax.vmap(lambda gi: jnp.zeros((e,), jnp.float32)
                  .at[gi.reshape(-1)].add(1.0))(gate_idx) / (tg * k)
    aux = e * jnp.sum(me.mean(0) * ce.mean(0)) * cfg.router_aux_coef

    # --- per-expert token selection (capacity C, within group) -------------
    def sel_group(gv, gi):
        z = jnp.zeros((tg, e), jnp.float32)
        return z.at[jnp.arange(tg)[:, None], gi].set(gv)
    sel = jax.vmap(sel_group)(gate_vals, gate_idx)                 # (G,Tg,E)
    scores = jnp.where(sel > 0, sel, -1.0).transpose(0, 2, 1)      # (G,E,Tg)
    scores = shard(scores, "moe_groups", "experts", None)
    top_scores, token_idx = jax.lax.top_k(scores, c)               # (G,E,C)
    keep = (top_scores > 0).astype(dt)

    # --- gather → expert matmul → scatter-add combine ----------------------
    xs = jax.vmap(lambda xg, ti: jnp.take(xg, ti, axis=0))(xt, token_idx)
    xs = shard(xs, "moe_groups", "experts", None, "embed")         # (G,E,C,d)
    h = jnp.einsum("gecd,edf->gecf", xs, params["w_in"].astype(dt))
    gg = jnp.einsum("gecd,edf->gecf", xs, params["w_gate"].astype(dt))
    h = jax.nn.silu(gg) * h
    h = shard(h, "moe_groups", "experts", None, "expert_ff")
    ys = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(dt))
    w = (top_scores.astype(dt) * keep)[..., None]                  # (G,E,C,1)

    def combine_group(yg, ti, wg):
        return jnp.zeros((tg, d), dt).at[ti.reshape(-1)].add(
            (yg * wg).reshape(-1, d))
    combined = jax.vmap(combine_group)(ys, token_idx, w)           # (G,Tg,d)
    combined = shard(combined, "moe_groups", None, "embed")

    # --- shared experts (dense path, deepseek) -----------------------------
    if "shared" in params:
        sp = params["shared"]
        hs = jax.nn.silu(jnp.einsum("gtd,df->gtf", xt, sp["w_gate"].astype(dt))) \
            * jnp.einsum("gtd,df->gtf", xt, sp["w_in"].astype(dt))
        combined = combined + jnp.einsum("gtf,fd->gtd", hs,
                                         sp["w_out"].astype(dt))

    return combined.reshape(b, s, d), aux
