"""Benchmark harness — drives every registered suite (paper tables/figures).

Thin front-end over ``python -m repro.bench`` (the unified OMB-style
subsystem in ``src/repro/bench/``): one child process per suite with the
right emulated device count, one schema artifact ``BENCH_<suite>.json`` per
suite at the repo root.  Suite ↔ paper map:

  pi           paper Listings 1-4 + Fig. 1 (JIT speedup; JIT-resident vs
               round-trip communication)                       [4 ranks]
  halo         paper Fig. 2 (Cahn-Hilliard strong scaling, sub-meshes
               n=1,2,4,8) + halo-exchange lowering sweep        [8 ranks]
  mpdata       paper Fig. 3 (decomposition layouts)             [8 ranks]
  p2p          OMB-style latency/bandwidth pair                 [2 ranks]
  collectives  collective microbenchmarks incl. nonblocking,
               persistent plans, neighborhood                   [8 ranks]
  trainer      trainer comm backends: jmpi vs hostbridge        [8 ranks]
  kernels      kernel-structure twins (blockwise/chunked)       [1 rank]

Gate the artifacts against the committed baselines with
``python -m repro.bench.compare`` (see docs/BENCHMARKS.md).
"""

from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.bench.cli import main  # noqa: E402


if __name__ == "__main__":
    sys.exit(main())
