"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section headers on
stderr-ish comment lines).  Emulated-device counts are process-global, so
each module runs in a child process with XLA_FLAGS set there (the main
process stays at 1 device).

  bench_pi            paper Listing 1 + Fig. 1 (JIT speedup; jmpi-vs-roundtrip
                      speedup over communication frequency)      [4 ranks]
  bench_halo          paper Fig. 2 (Cahn–Hilliard strong scaling) [1,2,4,8]
  bench_mpdata        paper Fig. 3 (decomposition layouts)        [8 ranks]
  bench_collectives   jmpi op microbenchmarks                     [8 ranks]
  bench_trainer_comm  trainer backends: jmpi vs hostbridge        [8 ranks]
  bench_kernels       kernel-structure twins (blockwise/chunked)  [1 rank]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.testing import child_env


MODULES = [
    ("benchmarks.bench_pi", 4, ()),
    ("benchmarks.bench_halo", 1, ()),
    ("benchmarks.bench_halo", 2, ()),
    ("benchmarks.bench_halo", 4, ()),
    ("benchmarks.bench_halo", 8, ()),
    ("benchmarks.bench_mpdata", 8, ()),
    ("benchmarks.bench_collectives", 8, ()),
    ("benchmarks.bench_collectives", 8, ("--persistent",)),
    ("benchmarks.bench_trainer_comm", 8, ()),
    ("benchmarks.bench_kernels", 1, ()),
]

#: CSV rows from these modules are also written to BENCH_collectives.json at
#: the repo root — one machine-readable artifact per run so the collective
#: perf trajectory (incl. persistent-plan reuse) is recorded PR over PR.
ARTIFACT_MODULE = "benchmarks.bench_collectives"
ARTIFACT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_collectives.json")


def _parse_rows(stdout: str) -> list[dict]:
    rows = []
    for line in stdout.splitlines():
        if line.startswith("#") or "," not in line:
            continue
        name, value, *rest = line.split(",")
        try:
            value = float(value)
        except ValueError:
            continue
        # "value" (not us_per_call): persistent-mode rows carry trace ms
        # and cache counters in this column, not only per-call microseconds.
        rows.append({"name": name, "value": value,
                     "derived": ",".join(rest)})
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    artifact_rows: list[dict] = []
    for mod, n_dev, extra in MODULES:
        print(f"# {mod} (n_devices={n_dev}{' ' + ' '.join(extra) if extra else ''})",
              flush=True)
        proc = subprocess.run(
            [sys.executable, "-m", mod, *extra], env=child_env(n_dev),
            capture_output=True, text=True, timeout=3600)
        sys.stdout.write(proc.stdout)
        if proc.returncode != 0:
            failures.append(mod)
            sys.stdout.write(f"# FAILED {mod}\n{proc.stderr[-2000:]}\n")
        elif mod == ARTIFACT_MODULE:
            artifact_rows.extend(_parse_rows(proc.stdout))
        sys.stdout.flush()
    if artifact_rows:
        with open(ARTIFACT_PATH, "w") as f:
            json.dump({"version": 1, "module": ARTIFACT_MODULE,
                       "rows": artifact_rows}, f, indent=1)
            f.write("\n")
        print(f"# wrote {len(artifact_rows)} rows to {ARTIFACT_PATH}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
