"""jmpi collective microbenchmarks (8 emulated ranks).

Per op × payload size: µs/call of the JIT-resident collective (whole timed
loop compiled — 100 chained calls per dispatch to amortize dispatch cost)
plus the host round-trip equivalent for allreduce (the Listing-2 cost).
Derived column reports effective GB/s through the emulated transport.
"""

from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi

INNER = 50


def timed_loop(mesh, op, numel):
    @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
    def f(x):
        def body(i, acc):
            if op == "allreduce":
                _, y = jmpi.allreduce(acc)
            elif op == "ring_allreduce":
                _, y = jmpi.ring_allreduce(acc)
            elif op == "allgather":
                _, g = jmpi.allgather(acc)
                y = g.reshape(jmpi.size(), -1).sum(0)
            elif op == "alltoall":
                _, y = jmpi.alltoall(acc)
            elif op == "bcast":
                _, y = jmpi.bcast(acc, root=0)
            elif op == "compressed8":
                st = jmpi.init_state(acc)
                _, y, _ = jmpi.compressed_allreduce(acc, st, bits=8)
            else:
                raise ValueError(op)
            return y / jnp.maximum(jnp.abs(y).max(), 1.0)

        return jax.lax.fori_loop(0, INNER, body, x)

    x = jnp.ones((numel,), jnp.float32)
    f(x).block_until_ready()
    t = min(timeit.repeat(lambda: f(x).block_until_ready(), number=1,
                          repeat=5))
    return t / INNER


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("ranks",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    n = mesh.devices.size
    for numel in (1024, 65536, 1048576):
        nbytes = numel * 4
        for op in ("allreduce", "ring_allreduce", "allgather", "alltoall",
                   "bcast", "compressed8"):
            if op == "alltoall" and numel % n:
                continue
            t = timed_loop(mesh, op, numel)
            wire = 2 * (n - 1) / n * nbytes if "allreduce" in op else nbytes
            print(f"coll_{op}_{numel},{t*1e6:.2f},"
                  f"bytes={nbytes} eff_GBps={wire/t/1e9:.2f}")


if __name__ == "__main__":
    main()
