"""jmpi collective microbenchmarks (8 emulated ranks).

Per op × payload size: µs/call of the JIT-resident collective (whole timed
loop compiled — chained calls per dispatch to amortize dispatch cost)
plus the host round-trip equivalent for allreduce (the Listing-2 cost).
Derived column reports effective GB/s through the emulated transport.

``--sweep-algorithms``: sweep every registered collective algorithm over the
payload grid, print the per-cell winners (crossover points) and the derived
size-aware policy table (``repro.launch.collective_tuner``); ``--emit-policy
PATH`` additionally writes the JSON table that ``jmpi.load_policy`` consumes.

``--persistent``: measure persistent-plan reuse (jmpi 2.0) vs ad-hoc
dispatch — trace time of a K-call chain with per-call registry/policy
dispatch vs one frozen ``allreduce_init`` plan restarted K times, runtime of
both (same lowering → should match), and the plan-cache hit/miss counters
proving the second trace re-used the cached Plan instead of re-selecting.
"""

from __future__ import annotations

import argparse
import os
import sys

# Process-global and read at backend init: emulate 8 devices when the caller
# (benchmarks/run.py child_env, CI) has not already pinned a device count.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", "")).strip()

# Self-contained invocation (`python benchmarks/bench_collectives.py`):
# make src/ importable without requiring the caller to export PYTHONPATH.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import timeit            # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.core as jmpi                    # noqa: E402
from repro.core import compat                # noqa: E402

INNER = 50


def timed_loop(mesh, op, numel):
    @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
    def f(x):
        def body(i, acc):
            if op == "allreduce":
                _, y = jmpi.allreduce(acc)
            elif op == "ring_allreduce":
                _, y = jmpi.ring_allreduce(acc)
            elif op == "allgather":
                _, g = jmpi.allgather(acc)
                y = g.reshape(jmpi.size(), -1).sum(0)
            elif op == "alltoall":
                _, y = jmpi.alltoall(acc)
            elif op == "bcast":
                _, y = jmpi.bcast(acc, root=0)
            elif op == "compressed8":
                st = jmpi.init_state(acc)
                _, y, _ = jmpi.compressed_allreduce(acc, st, bits=8)
            else:
                raise ValueError(op)
            return y / jnp.maximum(jnp.abs(y).max(), 1.0)

        return jax.lax.fori_loop(0, INNER, body, x)

    x = jnp.ones((numel,), jnp.float32)
    f(x).block_until_ready()
    t = min(timeit.repeat(lambda: f(x).block_until_ready(), number=1,
                          repeat=5))
    return t / INNER


def micro():
    mesh = compat.make_mesh((len(jax.devices()),), ("ranks",))
    n = mesh.devices.size
    for numel in (1024, 65536, 1048576):
        nbytes = numel * 4
        for op in ("allreduce", "ring_allreduce", "allgather", "alltoall",
                   "bcast", "compressed8"):
            if op == "alltoall" and numel % n:
                continue
            t = timed_loop(mesh, op, numel)
            wire = 2 * (n - 1) / n * nbytes if "allreduce" in op else nbytes
            print(f"coll_{op}_{numel},{t*1e6:.2f},"
                  f"bytes={nbytes} eff_GBps={wire/t/1e9:.2f}")


def sweep_algorithms(emit_policy: str | None):
    from repro.launch import collective_tuner

    mesh = collective_tuner.tune_mesh(len(jax.devices()))
    records = collective_tuner.sweep(mesh)
    print("op,algorithm,numel,us_per_call")
    for r in records:
        print(f"{r['op']},{r['algorithm']},{r['numel']},"
              f"{r['us_per_call']:.2f}")
    print()
    print(collective_tuner.crossover_report(records))
    table = collective_tuner.build_policy(records)
    print()
    print("derived policy table (non-default rules = measured wins):")
    print(table.describe())
    if emit_policy:
        table.save(emit_policy)
        print(f"\npolicy table written to {emit_policy}")


def persistent(numel: int = 65536, k: int = 24):
    """Plan-reuse measurement: ad-hoc dispatch vs persistent plans.

    Both programs chain ``k`` allreduces (unrolled, so the ad-hoc variant
    pays ``k`` registry/policy dispatches per trace while the plan variant
    dispatches once and restarts).  Identical math → identical HLO shape;
    the delta is trace-time dispatch cost, and the plan-cache counters show
    the second trace serving its *_init straight from the cache.
    """
    mesh = compat.make_mesh((len(jax.devices()),), ("ranks",))
    n = mesh.devices.size
    x = jnp.ones((numel,), jnp.float32)

    def adhoc_fn():
        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            acc = x
            for _ in range(k):
                _, acc = jmpi.allreduce(acc)
                acc = acc / n
            return acc
        return f

    def plan_fn():
        @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
        def f(x):
            comm = jmpi.world()
            plan = comm.allreduce_init(
                jax.ShapeDtypeStruct(x.shape, x.dtype))
            acc = x
            for _ in range(k):
                acc = jmpi.wait(plan.start(acc))[1] / n
            return acc
        return f

    def trace_ms(build):
        t0 = timeit.default_timer()
        build().lower(x)
        return (timeit.default_timer() - t0) * 1e3

    jmpi.plan_cache_clear()
    adhoc_t1, adhoc_t2 = trace_ms(adhoc_fn), trace_ms(adhoc_fn)
    s0 = jmpi.plan_cache_stats()
    plan_t1 = trace_ms(plan_fn)
    s1 = jmpi.plan_cache_stats()
    plan_t2 = trace_ms(plan_fn)          # second trace: *_init is a cache hit
    s2 = jmpi.plan_cache_stats()

    print(f"persistent_adhoc_trace_ms,{adhoc_t1:.1f},second={adhoc_t2:.1f} "
          f"k={k} numel={numel}")
    print(f"persistent_plan_trace_ms,{plan_t1:.1f},second={plan_t2:.1f} "
          f"k={k} numel={numel}")
    print(f"persistent_plan_cache,{s2['hits']},misses={s2['misses']} "
          f"first_trace=+{s1['misses'] - s0['misses']}miss "
          f"second_trace=+{s2['hits'] - s1['hits']}hit")
    assert s2["misses"] == s1["misses"] and s2["hits"] > s1["hits"], \
        "second trace must re-use the cached Plan (no new misses)"
    print("# plan reuse OK: second trace served allreduce_init from the "
          "plan cache (0 new selections); ad-hoc re-dispatched "
          f"{k}x per trace")

    fa, fp = adhoc_fn(), plan_fn()
    ya = fa(x).block_until_ready()
    yp = fp(x).block_until_ready()
    assert jnp.allclose(ya, yp), "plan and ad-hoc paths must agree"
    ta = min(timeit.repeat(lambda: fa(x).block_until_ready(), number=1,
                           repeat=5)) / k
    tp = min(timeit.repeat(lambda: fp(x).block_until_ready(), number=1,
                           repeat=5)) / k
    print(f"persistent_adhoc_run_us,{ta*1e6:.2f},per-call numel={numel}")
    print(f"persistent_plan_run_us,{tp*1e6:.2f},per-call numel={numel}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep-algorithms", action="store_true")
    ap.add_argument("--emit-policy", default=None)
    ap.add_argument("--persistent", action="store_true")
    args = ap.parse_args()
    if args.sweep_algorithms:
        sweep_algorithms(args.emit_policy)
    elif args.persistent:
        persistent()
    else:
        micro()


if __name__ == "__main__":
    main()
