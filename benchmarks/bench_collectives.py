"""jmpi collective microbenchmarks (8 emulated ranks).

Per op × payload size: µs/call of the JIT-resident collective (whole timed
loop compiled — chained calls per dispatch to amortize dispatch cost)
plus the host round-trip equivalent for allreduce (the Listing-2 cost).
Derived column reports effective GB/s through the emulated transport.

``--sweep-algorithms``: sweep every registered collective algorithm over the
payload grid, print the per-cell winners (crossover points) and the derived
size-aware policy table (``repro.launch.collective_tuner``); ``--emit-policy
PATH`` additionally writes the JSON table that ``jmpi.load_policy`` consumes.
"""

from __future__ import annotations

import argparse
import os
import sys

# Process-global and read at backend init: emulate 8 devices when the caller
# (benchmarks/run.py child_env, CI) has not already pinned a device count.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", "")).strip()

# Self-contained invocation (`python benchmarks/bench_collectives.py`):
# make src/ importable without requiring the caller to export PYTHONPATH.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import timeit            # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.core as jmpi                    # noqa: E402
from repro.core import compat                # noqa: E402

INNER = 50


def timed_loop(mesh, op, numel):
    @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
    def f(x):
        def body(i, acc):
            if op == "allreduce":
                _, y = jmpi.allreduce(acc)
            elif op == "ring_allreduce":
                _, y = jmpi.ring_allreduce(acc)
            elif op == "allgather":
                _, g = jmpi.allgather(acc)
                y = g.reshape(jmpi.size(), -1).sum(0)
            elif op == "alltoall":
                _, y = jmpi.alltoall(acc)
            elif op == "bcast":
                _, y = jmpi.bcast(acc, root=0)
            elif op == "compressed8":
                st = jmpi.init_state(acc)
                _, y, _ = jmpi.compressed_allreduce(acc, st, bits=8)
            else:
                raise ValueError(op)
            return y / jnp.maximum(jnp.abs(y).max(), 1.0)

        return jax.lax.fori_loop(0, INNER, body, x)

    x = jnp.ones((numel,), jnp.float32)
    f(x).block_until_ready()
    t = min(timeit.repeat(lambda: f(x).block_until_ready(), number=1,
                          repeat=5))
    return t / INNER


def micro():
    mesh = compat.make_mesh((len(jax.devices()),), ("ranks",))
    n = mesh.devices.size
    for numel in (1024, 65536, 1048576):
        nbytes = numel * 4
        for op in ("allreduce", "ring_allreduce", "allgather", "alltoall",
                   "bcast", "compressed8"):
            if op == "alltoall" and numel % n:
                continue
            t = timed_loop(mesh, op, numel)
            wire = 2 * (n - 1) / n * nbytes if "allreduce" in op else nbytes
            print(f"coll_{op}_{numel},{t*1e6:.2f},"
                  f"bytes={nbytes} eff_GBps={wire/t/1e9:.2f}")


def sweep_algorithms(emit_policy: str | None):
    from repro.launch import collective_tuner

    mesh = collective_tuner.tune_mesh(len(jax.devices()))
    records = collective_tuner.sweep(mesh)
    print("op,algorithm,numel,us_per_call")
    for r in records:
        print(f"{r['op']},{r['algorithm']},{r['numel']},"
              f"{r['us_per_call']:.2f}")
    print()
    print(collective_tuner.crossover_report(records))
    table = collective_tuner.build_policy(records)
    print()
    print("derived policy table (non-default rules = measured wins):")
    print(table.describe())
    if emit_policy:
        table.save(emit_policy)
        print(f"\npolicy table written to {emit_policy}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep-algorithms", action="store_true")
    ap.add_argument("--emit-policy", default=None)
    args = ap.parse_args()
    if args.sweep_algorithms:
        sweep_algorithms(args.emit_policy)
    else:
        micro()


if __name__ == "__main__":
    main()
