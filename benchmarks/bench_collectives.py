"""Legacy entry point for the ``collectives`` suite (8 emulated ranks).

The timing loops moved to ``repro.bench.suites.collectives`` (blocking +
nonblocking + persistent + neighborhood cases, plan-cache and policy
invariants); this wrapper keeps the historical flags working:

  (no flag)            run the suite in-process, print rows
  --persistent         persistent-plan cases + plan-cache reuse invariant
  --sweep-algorithms   full tuner sweep + derived policy table
                       (``repro.launch.collective_tuner`` — a tuning tool,
                       not a suite; unchanged)
  --emit-policy PATH   with --sweep-algorithms: write the JSON policy table

plus the shared suite flags (``--quick --repeats --warmup --sizes --cases
--json``).  Prefer ``python -m repro.bench --suite collectives``.
"""

from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.bench.suites import SUITES  # noqa: E402  (import-light)

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        f"{SUITES['collectives'].n_devices} "
        + os.environ.get("XLA_FLAGS", "")).strip()


def _sweep_algorithms(emit_policy: str | None) -> int:
    import jax
    from repro.launch import collective_tuner

    mesh = collective_tuner.tune_mesh(len(jax.devices()))
    records = collective_tuner.sweep(mesh)
    print("op,algorithm,numel,us_per_call")
    for r in records:
        print(f"{r['op']},{r['algorithm']},{r['numel']},"
              f"{r['us_per_call']:.2f}")
    print()
    print(collective_tuner.crossover_report(records))
    table = collective_tuner.build_policy(records)
    print()
    print("derived policy table (non-default rules = measured wins):")
    print(table.describe())
    if emit_policy:
        table.save(emit_policy)
        print(f"\npolicy table written to {emit_policy}")
    return 0


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--sweep-algorithms", action="store_true")
    ap.add_argument("--emit-policy", default=None, metavar="PATH")
    ap.add_argument("--persistent", action="store_true")
    args, rest = ap.parse_known_args(
        sys.argv[1:] if argv is None else argv)
    if args.sweep_algorithms:
        return _sweep_algorithms(args.emit_policy)
    if args.persistent:
        rest = rest + ["--cases", "persistent,adhoc"]
    from repro.bench.cli import legacy_main
    return legacy_main("collectives", rest)


if __name__ == "__main__":
    sys.exit(main())
