"""Paper Fig. 2: Cahn–Hilliard strong scaling (runtime vs rank count).

512² grid (the paper's Listing 7 size), fixed step count, N ∈ {1,2,4,8}
emulated ranks (decomposition [N,1]).  Host-device emulation runs shards on
real CPU threads, so the scaling trend is measurable (modulo the single-core
container this runs in — the CSV reports raw seconds; Fig. 2's t ∝ 1/N needs
multi-core hosts and is asserted as a trend only when cores allow).

This module runs under ONE device count; benchmarks.run spawns it once per N.
"""

from __future__ import annotations

import timeit

import jax
from repro.core import compat
import jax.numpy as jnp
import numpy as np

from repro.pde import cahn_hilliard as ch

GRID = 256
STEPS = 100


def main():
    n_dev = len(jax.devices())
    rows = min(2, n_dev)
    cols = n_dev // rows
    mesh = compat.make_mesh((rows, cols), ("px", "py"))
    rng = np.random.default_rng(0)
    c0 = jnp.asarray(0.5 + 0.01 * rng.standard_normal((GRID, GRID)),
                     jnp.float32)
    run = ch.make_solver(mesh, (rows, cols), inner_steps=STEPS)
    out = run(c0)  # compile + warm
    assert bool(jnp.isfinite(out).all())
    t = min(timeit.repeat(lambda: run(c0).block_until_ready(),
                          number=1, repeat=3))
    per_step_us = t / STEPS * 1e6
    print(f"cahn_hilliard_n{n_dev},{per_step_us:.1f},"
          f"grid={GRID} steps={STEPS} decomp={rows}x{cols} total_s={t:.3f}")


if __name__ == "__main__":
    main()
