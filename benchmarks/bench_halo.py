"""Paper Fig. 2: Cahn–Hilliard strong scaling (runtime vs rank count).

512² grid (the paper's Listing 7 size), fixed step count, N ∈ {1,2,4,8}
emulated ranks (decomposition [N,1]).  Host-device emulation runs shards on
real CPU threads, so the scaling trend is measurable (modulo the single-core
container this runs in — the CSV reports raw seconds; Fig. 2's t ∝ 1/N needs
multi-core hosts and is asserted as a trend only when cores allow).

This module runs under ONE device count; benchmarks.run spawns it once per N.

``--neighbor``: halo-exchange microbenchmark sweeping the MPI-3
neighborhood-collective lowerings (``xla_native`` shifts vs the p2p-fused
``ring``) against a hand-built p2p baseline (persistent ``sendrecv_init``
plans along ``cart_shift_perm`` patterns — what pde/stencil.py did before
the topology subsystem).  Prints µs/step per variant and a no-regression
check of the neighbor path vs the p2p baseline.
"""

from __future__ import annotations

import argparse
import os
import sys
import timeit

# --neighbor runs standalone too: emulate 8 devices unless already pinned
# (process-global, must be set before jax initializes its backend).
if "--neighbor" in sys.argv and \
        "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", "")).strip()

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

import repro.core as jmpi                     # noqa: E402
from repro.core import compat                 # noqa: E402
from repro.pde import cahn_hilliard as ch     # noqa: E402
from repro.pde.stencil import halo_exchange_2d, laplacian  # noqa: E402

GRID = 256
STEPS = 100
NEIGHBOR_STEPS = 50


def main():
    n_dev = len(jax.devices())
    rows = min(2, n_dev)
    cols = n_dev // rows
    mesh = compat.make_mesh((rows, cols), ("px", "py"))
    rng = np.random.default_rng(0)
    c0 = jnp.asarray(0.5 + 0.01 * rng.standard_normal((GRID, GRID)),
                     jnp.float32)
    run = ch.make_solver(mesh, (rows, cols), inner_steps=STEPS)
    out = run(c0)  # compile + warm
    assert bool(jnp.isfinite(out).all())
    t = min(timeit.repeat(lambda: run(c0).block_until_ready(),
                          number=1, repeat=3))
    per_step_us = t / STEPS * 1e6
    print(f"cahn_hilliard_n{n_dev},{per_step_us:.1f},"
          f"grid={GRID} steps={STEPS} decomp={rows}x{cols} total_s={t:.3f}")


# ---------------------------------------------------------------------------
# --neighbor: neighborhood-collective halo exchange vs the p2p baseline
# ---------------------------------------------------------------------------

def p2p_exchange_2d(field, cart, h: int = 1):
    """The pre-topology halo exchange: two persistent ``sendrecv_init``
    plans per decomposed axis, patterns from ``cart_shift_perm`` — the p2p
    baseline the ``--neighbor`` sweep compares against (kept in the bench,
    not in pde/: the solver rides the neighbor_alltoall plan)."""
    def ax(d, lo, hi):
        if cart.dims[d] == 1:
            return hi, lo
        dn = cart.sendrecv_init(jax.ShapeDtypeStruct(hi.shape, hi.dtype),
                                pairs=cart.cart_shift_perm(d, +1))
        up = cart.sendrecv_init(jax.ShapeDtypeStruct(lo.shape, lo.dtype),
                                pairs=cart.cart_shift_perm(d, -1))
        return jmpi.wait(dn.start(hi))[1], jmpi.wait(up.start(lo))[1]

    lead, trail = ax(0, field[:h, :], field[-h:, :])
    field = jnp.concatenate([lead, field, trail], axis=0)
    lead, trail = ax(1, field[:, :h], field[:, -h:])
    return jnp.concatenate([lead, field, trail], axis=1)


def _make_loop(mesh, rows, cols, exchange, steps):
    @jmpi.spmd(mesh, in_specs=P("px", "py"), out_specs=P("px", "py"))
    def run(c):
        cart = jmpi.world().cart_create((rows, cols), periods=(True, True))

        def body(i, f):
            fh = exchange(f, cart)
            return f + 1e-3 * laplacian(fh)

        return jax.lax.fori_loop(0, steps, body, c)

    return run


def neighbor_sweep(grid: int = 128, steps: int = NEIGHBOR_STEPS):
    n_dev = len(jax.devices())
    rows = min(2, n_dev)
    cols = n_dev // rows
    mesh = compat.make_mesh((rows, cols), ("px", "py"))
    rng = np.random.default_rng(0)
    c0 = jnp.asarray(0.5 + 0.01 * rng.standard_normal((grid, grid)),
                     jnp.float32)

    variants = [
        ("neighbor/xla_native",
         lambda f, cart: halo_exchange_2d(f, cart, algorithm="xla_native")),
        ("neighbor/ring",
         lambda f, cart: halo_exchange_2d(f, cart, algorithm="ring")),
        ("p2p_baseline", p2p_exchange_2d),
    ]
    results = {}
    print(f"halo exchange sweep: grid={grid} steps={steps} "
          f"decomp={rows}x{cols} ranks={n_dev}")
    print(f"{'variant':<24}{'us_per_step':>12}")
    for name, exchange in variants:
        run = _make_loop(mesh, rows, cols, exchange, steps)
        out = run(c0)
        assert bool(jnp.isfinite(out).all()), name
        t = min(timeit.repeat(lambda: run(c0).block_until_ready(),
                              number=1, repeat=5))
        results[name] = t / steps * 1e6
        print(f"{name:<24}{results[name]:>12.1f}")

    best_neighbor = min(results["neighbor/xla_native"],
                        results["neighbor/ring"])
    ratio = best_neighbor / results["p2p_baseline"]
    verdict = ("no regression" if ratio <= 1.25
               else "WARN: neighbor slower than p2p baseline")
    print(f"neighbor_vs_p2p ratio={ratio:.2f} ({verdict})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--neighbor", action="store_true",
                    help="sweep neighborhood-collective lowerings vs the "
                         "p2p halo baseline")
    args = ap.parse_args()
    if args.neighbor:
        neighbor_sweep()
    else:
        main()
