"""Legacy entry point for the ``halo`` suite (paper Fig. 2, 8 ranks).

The timing loops moved to ``repro.bench.suites.halo`` — Cahn-Hilliard
strong scaling over sub-meshes n ∈ {1,2,4,8} plus the halo-exchange
lowering sweep (neighborhood collectives vs the persistent-``sendrecv``
p2p baseline).  Historical flags:

  (no flag)    full suite (scaling + sweep)
  --neighbor   only the halo-exchange lowering sweep cases

plus the shared suite flags (``--quick --repeats --warmup --cases
--json``).  Prefer ``python -m repro.bench --suite halo``.
"""

from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.bench.suites import SUITES  # noqa: E402  (import-light)

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={SUITES['halo'].n_devices} "
        + os.environ.get("XLA_FLAGS", "")).strip()


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--neighbor" in argv:
        argv.remove("--neighbor")
        argv += ["--cases", "halo_"]
    from repro.bench.cli import legacy_main
    return legacy_main("halo", argv)


if __name__ == "__main__":
    sys.exit(main())
