"""Trainer comm-backend comparison (the paper's claim at trainer scale).

Same tiny LM, same data, 8 emulated DP ranks:

  jmpi       — whole train step (fwd/bwd + explicit in-program gradient
               allreduce + optimizer) in ONE compiled block,
  jmpi+int8  — ditto with compressed gradient allreduce,
  hostbridge — per-step host round-trip gradient mean between two compiled
               fragments (mpi4py analogue, paper Listing 2).

Reports ms/step; derived column = speedup vs hostbridge.
"""

from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as jmpi
from repro.core import compat
from repro.configs import get_tiny
from repro.configs.base import RunConfig
from repro.launch.specs import synth_batch
from repro.models import lm as lm_lib
from repro.train import optim
from repro.train.trainer import build_jmpi_train_step


def main():
    cfg = get_tiny("yi-6b")
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    n = mesh.devices.size
    batch = synth_batch(cfg, batch=8 * n, seq=64, kind="train")

    results = {}
    for mode, bits in (("jmpi", 0), ("jmpi_int8", 8)):
        rc = RunConfig(learning_rate=1e-3, grad_compression_bits=bits)
        params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.init(params, rc)
        comp = jax.tree.map(lambda p: jmpi.init_state(p), params)
        step = build_jmpi_train_step(cfg, rc, mesh, None)
        params, opt, comp, loss = step(params, opt, comp, batch)  # compile

        def one(params=params, opt=opt, comp=comp):
            p, o, c, l = step(params, opt, comp, batch)
            l.block_until_ready()

        results[mode] = min(timeit.repeat(one, number=1, repeat=5))

    # hostbridge: grads computed per-rank in one dispatch, reduced on host,
    # applied in a second dispatch — the round-trip every step.
    rc = RunConfig(learning_rate=1e-3)
    params = lm_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params, rc)

    from jax.sharding import PartitionSpec as P

    # --- roundtrip: SAME in-program psum allreduce, but the step is split
    # into two dispatches with a host synchronization between them (grads+
    # reduce | optimizer) — the communication mechanism held fixed, so
    # t_roundtrip/t_jmpi isolates the leave-the-compiled-block cost.
    grad_reduce_fn = jax.jit(compat.shard_map(
        lambda p, b: jax.tree.map(
            lambda g: jax.lax.pmean(g, "data"),
            jax.grad(lambda pp: lm_lib.train_loss(pp, cfg, b)[0])(p)),
        mesh=mesh, in_specs=(P(), P("data")), out_specs=P(),
        check_vma=False))
    apply_fn = jax.jit(lambda p, g, o: optim.update(p, g, o, rc))

    def roundtrip_step(params, opt):
        g = grad_reduce_fn(params, batch)
        jax.block_until_ready(g)          # leave the compiled block
        out = apply_fn(params, g, opt)
        jax.block_until_ready(out)
        return out

    params, opt = roundtrip_step(params, opt)  # compile
    results["roundtrip"] = min(timeit.repeat(
        lambda: roundtrip_step(params, opt), number=1, repeat=5))

    # --- hostbridge: per-rank grads to host, numpy reduction, re-upload —
    # the full mpi4py pattern (different transport: see EXPERIMENTS.md
    # emulation caveat).
    grad_fn = jax.jit(compat.shard_map(
        lambda p, b: jax.tree.map(
            lambda g: g[None],
            jax.grad(lambda pp: lm_lib.train_loss(pp, cfg, b)[0])(p)),
        mesh=mesh, in_specs=(P(), P("data")), out_specs=P("data"),
        check_vma=False))

    def host_step(params, opt):
        gstack = grad_fn(params, batch)
        jax.block_until_ready(gstack)
        gmean = jax.tree.map(lambda g: jnp.asarray(np.asarray(g).mean(0)),
                             gstack)
        return apply_fn(params, gmean, opt)

    params, opt = host_step(params, opt)  # compile
    results["hostbridge"] = min(timeit.repeat(
        lambda: jax.block_until_ready(host_step(params, opt)),
        number=1, repeat=5))

    base = results["roundtrip"]
    for mode, t in results.items():
        print(f"trainer_{mode},{t*1e3:.2f},speedup_vs_roundtrip="
              f"{base/t:.2f}x")


if __name__ == "__main__":
    main()
