"""Legacy entry point for the ``trainer`` suite (8 emulated DP ranks).

The timing loops moved to ``repro.bench.suites.trainer`` (jmpi /
int8-compressed / round-trip / hostbridge backends, ms per step).
Accepts the shared suite flags (``--quick --repeats --warmup --cases
--json``).  Prefer ``python -m repro.bench --suite trainer``.
"""

from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.bench.suites import SUITES  # noqa: E402  (import-light)

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={SUITES['trainer'].n_devices} "
        + os.environ.get("XLA_FLAGS", "")).strip()

from repro.bench.cli import legacy_main  # noqa: E402


if __name__ == "__main__":
    sys.exit(legacy_main("trainer"))
