"""Paper Fig. 3: MPDATA decomposition-layout study.

Same 256² advection problem, 8 ranks, decomposed along dim 0 (8×1),
dim 1 (1×8), or both (2×4) — PyMPDATA-MPI exposes exactly this choice.
Reports per-step time per layout (+ a correctness check: all layouts agree
with the single-device oracle bitwise-tolerance).
"""

from __future__ import annotations

import timeit

import jax
from repro.core import compat
import jax.numpy as jnp
import numpy as np

from repro.pde import mpdata

GRID = 256
STEPS = 50


def main():
    n_dev = len(jax.devices())
    rng = np.random.default_rng(0)
    x = np.arange(GRID)
    psi0 = jnp.asarray(
        np.exp(-((x - 96) ** 2)[:, None] / 512 - ((x - 128) ** 2)[None, :] / 512)
        + 0.01, jnp.float32)

    want = psi0
    for _ in range(5):
        want = mpdata.reference_step(want)

    layouts = [(n_dev, 1), (1, n_dev)]
    if n_dev >= 4:
        layouts.append((2, n_dev // 2))
    for rows, cols in layouts:
        mesh = compat.make_mesh((rows, cols), ("px", "py"))
        run = mpdata.make_solver(mesh, inner_steps=STEPS)
        check = mpdata.make_solver(mesh, inner_steps=5)
        got = check(psi0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4)
        run(psi0).block_until_ready()  # warm
        t = min(timeit.repeat(lambda: run(psi0).block_until_ready(),
                              number=1, repeat=3))
        print(f"mpdata_{rows}x{cols},{t / STEPS * 1e6:.1f},"
              f"grid={GRID} steps={STEPS} total_s={t:.3f}")


if __name__ == "__main__":
    main()
