"""Paper Listings 1–4 / Fig. 1: the π benchmark.

Row 1  (Listing 1): JIT speedup of the compute kernel — jax.jit vs the pure
        CPython loop (paper reports ~100×).
Rows 2+ (Fig. 1): speedup of JIT-resident communication (jmpi: the whole
        N_TIMES loop, compute *and* allreduce, in ONE compiled program) over
        the host round-trip baseline (hostbridge: one dispatch + host
        reduction per iteration — the mpi4py failure mode of Listing 2),
        swept over communication frequency N_TIMES/n_intervals.

Run via benchmarks.run (spawns this module under 4 emulated devices, the
paper's worker count).  Output: name,us_per_call,derived CSV rows.
"""

from __future__ import annotations

import math
import time
import timeit

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat

N_TIMES = 200          # paper uses 10000; scaled to CPU-emulated devices
RTOL = 1e-3


# --------------------------------------------------------------------- #
# Listing 1: get_pi_part, JIT on vs off
# --------------------------------------------------------------------- #

def get_pi_part_python(n_intervals=100000, rank=0, size=1):
    h = 1.0 / n_intervals
    partial_sum = 0.0
    for i in range(rank + 1, n_intervals, size):
        x = h * (i - 0.5)
        partial_sum += 4.0 / (1.0 + x * x)
    return h * partial_sum


@jax.jit
def get_pi_part(n_intervals_arr, rank=0, size=1):
    n = n_intervals_arr          # static-shaped grid, masked to n intervals
    idx = jnp.arange(rank + 1, MAX_INTERVALS, size)
    h = 1.0 / n
    x = h * (idx - 0.5)
    vals = jnp.where(idx < n, 4.0 / (1.0 + x * x), 0.0)
    return h * jnp.sum(vals)


MAX_INTERVALS = 100000


def bench_jit_speedup():
    n = MAX_INTERVALS
    t_py = min(timeit.repeat(lambda: get_pi_part_python(n), number=1,
                             repeat=3))
    narr = jnp.float64(n) if jax.config.jax_enable_x64 else jnp.float32(n)
    get_pi_part(narr).block_until_ready()
    t_jit = min(timeit.repeat(
        lambda: get_pi_part(narr).block_until_ready(), number=1, repeat=5))
    assert abs(float(get_pi_part(narr)) - math.pi) < 1e-2
    return [("pi_jit_speedup_x", t_py / t_jit,
             f"tpy={t_py*1e6:.0f}us tjit={t_jit*1e6:.0f}us")]


# --------------------------------------------------------------------- #
# Listing 3 analogue: whole loop inside one compiled block (jmpi)
# --------------------------------------------------------------------- #

def make_pi_jmpi(mesh, n_intervals):
    @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
    def pi_loop(dummy):
        rank = jmpi.rank()
        size = jmpi.size()
        h = 1.0 / n_intervals
        idx = jnp.arange(0, n_intervals // size + 1)

        def one(i, acc):
            gidx = rank + 1 + idx * size
            x = h * (gidx - 0.5)
            part = h * jnp.sum(jnp.where(gidx < n_intervals + 1,
                                         4.0 / (1.0 + x * x), 0.0))
            status, pi = jmpi.allreduce(part + 0.0 * acc)
            return pi

        pi = jax.lax.fori_loop(0, N_TIMES, one, 0.0 * dummy)
        return pi

    return pi_loop


# --------------------------------------------------------------------- #
# Listing 2 analogue: per-iteration dispatch + host reduction (hostbridge)
# --------------------------------------------------------------------- #

def make_pi_hostbridge(mesh, n_intervals):
    import numpy as np
    n_dev = mesh.devices.size

    @jax.jit
    def part_all_ranks(dummy):
        # one dispatch computes every rank's partial (sharded over ranks)
        ranks = jnp.arange(n_dev)
        h = 1.0 / n_intervals
        idx = jnp.arange(0, n_intervals // n_dev + 1)
        gidx = ranks[:, None] + 1 + idx[None, :] * n_dev
        x = h * (gidx - 0.5)
        parts = h * jnp.sum(jnp.where(gidx < n_intervals + 1,
                                      4.0 / (1.0 + x * x), 0.0), axis=1)
        return parts + 0.0 * dummy

    bridge = jmpi.HostBridge(mesh)

    def pi_loop():
        pi = 0.0
        for _ in range(N_TIMES):
            parts = part_all_ranks(jnp.float32(pi * 0.0))
            parts.block_until_ready()            # leave the compiled block
            pi = float(np.sum(np.asarray(parts)))  # host-side "MPI" reduce
        return pi

    return pi_loop


def make_pi_roundtrip(mesh, n_intervals):
    """Same psum-based allreduce as the jmpi path, but ONE JIT DISPATCH PER
    ITERATION with a host synchronization between dispatches — the paper's
    'leave the compiled block every call' pattern with the communication
    mechanism held fixed.  t_roundtrip/t_jmpi therefore isolates exactly the
    round-trip overhead the paper measures (Fig. 1), independent of how fast
    the emulated transport is."""
    from jax.sharding import PartitionSpec as P

    @jmpi.spmd(mesh, in_specs=P(), out_specs=P())
    def one(acc):
        rank = jmpi.rank()
        size = jmpi.size()
        h = 1.0 / n_intervals
        idx = jnp.arange(0, n_intervals // size + 1)
        gidx = rank + 1 + idx * size
        x = h * (gidx - 0.5)
        part = h * jnp.sum(jnp.where(gidx < n_intervals + 1,
                                     4.0 / (1.0 + x * x), 0.0))
        status, pi = jmpi.allreduce(part + 0.0 * acc)
        return pi

    def loop():
        pi = jnp.float32(0.0)
        for _ in range(N_TIMES):
            pi = one(pi * 0.0)
            pi.block_until_ready()        # the host round-trip
        return float(pi)

    return loop


def bench_speedup_sweep():
    mesh = compat.make_mesh((len(jax.devices()),), ("ranks",))
    rows = []
    for x in (1, 4, 16):
        n_intervals = max(64, N_TIMES // x)
        f_jmpi = make_pi_jmpi(mesh, n_intervals)
        pi = float(f_jmpi(jnp.float32(0.0)))
        assert abs(pi - math.pi) / math.pi < RTOL, pi
        t_jmpi = min(timeit.repeat(
            lambda: f_jmpi(jnp.float32(0.0)).block_until_ready(),
            number=1, repeat=5))

        f_rt = make_pi_roundtrip(mesh, n_intervals)
        pi_rt = f_rt()
        assert abs(pi_rt - math.pi) / math.pi < RTOL, pi_rt
        t_rt = min(timeit.repeat(f_rt, number=1, repeat=3))
        rows.append((f"pi_jitresident_speedup_x{x}", t_rt / t_jmpi,
                     f"n_intervals={n_intervals} tjmpi={t_jmpi*1e3:.1f}ms "
                     f"troundtrip={t_rt*1e3:.1f}ms (same collectives)"))

        f_host = make_pi_hostbridge(mesh, n_intervals)
        pi_h = f_host()
        assert abs(pi_h - math.pi) / math.pi < RTOL, pi_h
        t_host = min(timeit.repeat(f_host, number=1, repeat=3))
        rows.append((f"pi_vs_hostnumpy_x{x}", t_host / t_jmpi,
                     f"thostnumpy={t_host*1e3:.1f}ms (emulated-transport "
                     f"caveat: see EXPERIMENTS.md)"))
    return rows


def main():
    rows = bench_jit_speedup() + bench_speedup_sweep()
    for name, val, derived in rows:
        print(f"{name},{val:.4g},{derived}")


if __name__ == "__main__":
    main()
