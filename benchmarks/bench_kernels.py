"""Kernel-structure benchmarks (single device).

Interpret-mode Pallas timings are meaningless (Python loop per grid step),
so this measures the XLA-native *twins* that share the kernels' algorithmic
structure against their naive counterparts — the blockwise-vs-naive
attention memory/latency trade and the chunked-vs-sequential SSD scan —
plus the roofline-relevant derived quantities (achieved bytes, VMEM tile
sizes) that the §Perf analysis cites.
"""

from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import _sdpa, blockwise_sdpa, causal_mask
from repro.models.ssm import ssd_chunked
from repro.kernels.mamba2_ssd.ref import ssd_scan_ref

REPEAT = 3


def t_min(f):
    f()  # warm/compile
    return min(timeit.repeat(f, number=1, repeat=REPEAT))


def main():
    rng = np.random.default_rng(0)

    # --- attention: naive O(S²) memory vs blockwise ---------------------
    b, s, h, kh, d = 1, 2048, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, s, kh, d)), jnp.bfloat16)

    naive = jax.jit(lambda q, k, v: _sdpa(
        q, k, v, causal_mask(s)[None, None, None], kh))
    block = jax.jit(lambda q, k, v: blockwise_sdpa(q, k, v, kh, q_block=512,
                                                   kv_block=512))
    tn = t_min(lambda: naive(q, k, v).block_until_ready())
    tb = t_min(lambda: block(q, k, v).block_until_ready())
    err = float(jnp.abs(naive(q, k, v).astype(jnp.float32)
                        - block(q, k, v).astype(jnp.float32)).max())
    print(f"attn_naive_s{s},{tn*1e6:.0f},scores_mem="
          f"{b*h*s*s*4/2**20:.0f}MiB")
    print(f"attn_blockwise_s{s},{tb*1e6:.0f},"
          f"tile_mem={b*h*512*512*4/2**20:.0f}MiB err={err:.1e}")

    # --- SSD: chunked (matmul) vs sequential scan ------------------------
    b, H, s, P, N = 1, 8, 2048, 32, 64
    x = jnp.asarray(rng.standard_normal((b, s, H, P)) * 0.5, jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((b, s, H)) * 0.3,
                             jnp.float32)) + 0.01
    B = jnp.asarray(rng.standard_normal((b, s, N)) * 0.5, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, N)) * 0.5, jnp.float32)
    A = -jnp.abs(jnp.asarray(rng.uniform(0.5, 2.0, H), jnp.float32))
    D = jnp.zeros((H,), jnp.float32)

    chunked = jax.jit(lambda: ssd_chunked(x, dt, A, B, C, chunk=64)[0])
    seq = jax.jit(lambda: ssd_scan_ref(jnp.moveaxis(x, 2, 1),
                                       jnp.moveaxis(dt, 2, 1), B, C, A, D)[0])
    tc = t_min(lambda: chunked().block_until_ready())
    ts = t_min(lambda: seq().block_until_ready())
    print(f"ssd_chunked_s{s},{tc*1e6:.0f},chunk=64")
    print(f"ssd_sequential_s{s},{ts*1e6:.0f},speedup_chunked={ts/tc:.2f}x")


if __name__ == "__main__":
    main()
