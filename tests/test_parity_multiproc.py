"""Backend parity: the same unmodified oracle case functions
(tests/cases_parity.py) must pass under the emulated shard_map backend AND
under real multi-process jobs — at n=2 and n=4, over both wire transports.

Each (transport, nprocs) job runs the whole case module once (cached in
:func:`repro.transport.testing.module_results_multiproc`); the parametrized
tests then assert per-case slices, mirroring the emulated harness.  CI's
multiproc smoke lane selects the socket/n=2 slice with ``-k "sock-2"``.
"""

from __future__ import annotations

import pytest

from repro.testing import assert_case
from repro.transport.testing import assert_case_multiproc

MODULE = "tests.cases_parity"

# Cases meaningful at any world size >= 2.
CASES = [
    "case_allreduce_logical",
    "case_allreduce_operators",
    "case_alltoall_reduce_scatter",
    "case_barrier_and_token_sequencing",
    "case_bucketed_overlap_ordering",
    "case_compressed_rejects_integer_payloads",
    "case_disable_jit_debug_mode",
    "case_ef_determinism_bitwise",
    "case_ef_residual_norm_bounded",
    "case_ef_telescoping_identity_grid",
    "case_err_truncate_three_paths",
    "case_listing5_exchange",
    "case_p2p_datatype_payloads",
    "case_p2p_err_truncate",
    "case_property_collectives_match_oracle",
    "case_property_permute_roundtrip",
    "case_scatter_gather_allgather",
    "case_sendrecv_ring_all_dtypes",
    "case_view_strided_send_recv",
    "case_vvariant_requests_and_plans",
    "case_wire_bytes_compressed",
    "case_vvariant_validation_errors",
    "case_wtime",
]

# Join only at n >= 4 (rank schedules / error-path shapes need the room).
CASES_N4_ONLY = [
    "case_bcast_all_dtypes",
    "case_p2p_tag_matching",
    "case_p2p_trace_time_topology_errors",
    "case_view_transposed_fortran_analogue",
]

CONFIGS = [("sock", 2), ("shm", 2), ("sock", 4), ("shm", 4)]


@pytest.mark.parametrize("case", CASES + CASES_N4_ONLY)
def test_parity_emulated(case):
    assert_case(MODULE, case, n_devices=8)


@pytest.mark.parametrize("transport,nprocs", CONFIGS,
                         ids=[f"{t}-{n}" for t, n in CONFIGS])
@pytest.mark.parametrize("case", CASES + CASES_N4_ONLY)
def test_parity_multiproc(case, transport, nprocs):
    if nprocs < 4 and case in CASES_N4_ONLY:
        pytest.skip("case needs a world size >= 4")
    assert_case_multiproc(MODULE, case, nprocs, transport)
