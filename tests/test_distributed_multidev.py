"""Pytest wrappers for multi-rank distributed-runtime cases."""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_pipeline_matches_stacked_forward",
    "case_collective_matmul_ag_matches",
    "case_collective_matmul_rs_matches",
    "case_matmul_allgather_policy_routes",
    pytest.param("case_jmpi_trainer_matches_gspmd",
                 marks=pytest.mark.slow),
    pytest.param("case_jmpi_trainer_compressed_grads_converge",
                 marks=pytest.mark.slow),
    pytest.param("case_jmpi_trainer_overlap_bitwise",
                 marks=pytest.mark.slow),
]


@pytest.mark.parametrize("case", CASES)
def test_distributed_case(case):
    assert_case("tests.cases_distributed", case, n_devices=8)
