"""Pytest wrappers for multi-rank distributed-runtime cases."""

import pytest

from repro.testing import run_cases

CASES = [
    "case_pipeline_matches_stacked_forward",
    "case_collective_matmul_ag_matches",
    "case_collective_matmul_rs_matches",
    "case_jmpi_trainer_matches_gspmd",
    "case_jmpi_trainer_compressed_grads_converge",
]


@pytest.mark.parametrize("case", CASES)
def test_distributed_case(case):
    run_cases("tests.cases_distributed", n_devices=8, only=case)
