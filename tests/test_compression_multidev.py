"""Pytest wrappers for the error-feedback compression oracle suite at
world sizes 1, 2 and 8 (ISSUE 8: the cases derive N from the environment,
so the same bodies also run under real processes via the parity suite)."""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

MODULE = "tests.cases_compression"

CASES = [
    "case_bucketed_overlap_ordering",
    "case_compressed_rejects_integer_payloads",
    "case_ef_determinism_bitwise",
    "case_ef_residual_norm_bounded",
    "case_ef_telescoping_identity_grid",
    "case_wire_bytes_compressed",
]


@pytest.mark.parametrize("n", [1, 2, 8])
@pytest.mark.parametrize("case", CASES)
def test_compression_case(case, n):
    assert_case(MODULE, case, n_devices=n)
