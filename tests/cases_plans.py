"""jmpi 2.0 cases — nonblocking collectives, persistent Plans, communicator
methods, unified Request completion.  Device-count agnostic (run under 1, 2
and 8 emulated devices via tests/test_plans_multidev.py, reusing the
cases_registry machinery).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as jmpi
from repro.core import ref, registry
from tests.cases_registry import (N, OP_NAMES, _oracle_cmp, _tol, mesh1d,
                                  rand, spmd_collective)


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


# ---------------------------------------------------------------------- #
# nonblocking collectives: every i* op vs the numpy oracle
# ---------------------------------------------------------------------- #

def case_icollectives_match_oracle():
    """wait(i<collective>(x)) == oracle for every nonblocking collective."""
    src = [rand((N * 2, 3), jnp.float32, seed=5 * i + 1) for i in range(N)]
    np_src = [np.asarray(s, np.float64) for s in src]
    tol = _tol(jnp.float32, "", "sum")

    ops = {
        "iallreduce": (lambda x: jmpi.wait(jmpi.iallreduce(x))[1],
                       ref.allreduce(np_src, "sum")),
        "ibcast": (lambda x: jmpi.wait(jmpi.ibcast(x, root=N - 1))[1],
                   ref.bcast(np_src, root=N - 1)),
        "iscatter": (lambda x: jmpi.wait(jmpi.iscatter(x, root=0))[1],
                     ref.scatter(np_src, root=0)),
        "igather": (lambda x: jmpi.wait(jmpi.igather(x, root=0))[1],
                    ref.allgather(np_src)),
        "iallgather": (lambda x: jmpi.wait(jmpi.iallgather(x))[1],
                       ref.allgather(np_src)),
        "ialltoall": (lambda x: jmpi.wait(jmpi.ialltoall(x))[1],
                      ref.alltoall(np_src)),
        "ireduce_scatter": (lambda x: jmpi.wait(jmpi.ireduce_scatter(x))[1],
                            ref.reduce_scatter(np_src)),
    }
    for name, (fn, want) in ops.items():
        got = spmd_collective(fn, src)
        _oracle_cmp(got, want, **tol, err_msg=name)

    # ibarrier: completes, and ops sequenced after it still agree
    def barrier_then_sum(x):
        req = jmpi.ibarrier()
        st, _ = jmpi.wait(req)
        assert st == jmpi.SUCCESS
        return jmpi.wait(jmpi.iallreduce(x))[1]

    got = spmd_collective(barrier_then_sum, src)
    _oracle_cmp(got, ref.allreduce(np_src, "sum"), **tol, err_msg="ibarrier")


def case_communicator_method_surface():
    """Every v1.0 routine callable as a Communicator method, results equal
    to the module-level wrappers; dup() is a distinct context with the same
    group."""
    src = [rand((N * 2, 3), jnp.float32, seed=9 * i + 2) for i in range(N)]

    def f(x):
        comm = jmpi.world()
        dup = comm.dup()
        assert dup is not comm and dup != comm and dup.axes == comm.axes
        assert dup.size() == comm.size() == jmpi.size()
        _, a = comm.allreduce(x)
        _, a2 = jmpi.allreduce(x)
        _, b = dup.bcast(x, root=0)
        _, c = comm.allgather(x)
        _, d = comm.alltoall(x[: N * (x.shape[0] // N)]) if x.shape[0] % N == 0 \
            else (0, jnp.zeros_like(x))
        _, e = comm.reduce_scatter(x)
        _, g = comm.scatter(x, root=0)
        _, h = comm.gather(x, root=0)
        _, p = comm.sendrecv(x, pairs=comm.ring_perm(1))
        assert comm.barrier() == jmpi.SUCCESS
        return a - a2 + e.sum() * 0 + b.sum() * 0 + c.sum() * 0 \
            + d.sum() * 0 + g.sum() * 0 + h.sum() * 0 + p.sum() * 0

    got = spmd_collective(f, src)
    for gvals in got:
        np.testing.assert_allclose(gvals, 0.0, atol=1e-6)


# ---------------------------------------------------------------------- #
# unified Request model: mixed p2p + collective completion
# ---------------------------------------------------------------------- #

def case_mixed_waitall_p2p_and_collective():
    """A p2p request and a nonblocking-collective request complete through
    ONE waitall/testall call (the unified Request model)."""
    src = [rand((4,), jnp.float32, seed=31 * i + 7) for i in range(N)]
    np_src = [np.asarray(s, np.float64) for s in src]

    def f(x):
        comm = jmpi.world()
        r1 = comm.isendrecv(x, pairs=comm.ring_perm(1), tag=4)
        r2 = comm.iallreduce(x * 2, tag=4)
        status, [shifted, summed] = jmpi.waitall([r1, r2], tag=4)
        assert status == jmpi.SUCCESS
        st, flag, [shifted2, summed2] = jmpi.testall([r1, r2])
        return shifted + summed + (shifted2 - shifted) + (summed2 - summed) \
            + jnp.where(flag, 0.0, jnp.nan).astype(x.dtype)

    got = spmd_collective(f, src)
    shift_want = ref.ppermute(np_src, [(i, (i + 1) % N) for i in range(N)])
    sum_want = ref.allreduce([2 * s for s in np_src], "sum")
    want = [sh + sm for sh, sm in zip(shift_want, sum_want)]
    _oracle_cmp(got, want, rtol=1e-5, atol=1e-5)


def case_testall_waitall_tag_validation():
    """testall/waitall accept tag= (default ANY_TAG) and apply the same
    trace-time mismatch validation as wait/waitany."""
    src = [rand((3,), jnp.float32, seed=41 * i + 3) for i in range(N)]

    def good(x):
        comm = jmpi.world()
        r1 = comm.isendrecv(x, pairs=comm.ring_perm(1), tag=7)
        r2 = jmpi.iallreduce(x, tag=7)
        st, flag, [a, b] = jmpi.testall([r1, r2], tag=7)
        st2, [a2, b2] = jmpi.waitall([r1, r2], tag=jmpi.ANY_TAG)
        return a + b + a2 * 0 + b2 * 0 + jnp.where(flag, 0.0, jnp.nan)

    spmd_collective(good, src)  # must trace & run

    def bad(x):
        comm = jmpi.world()
        r1 = comm.isendrecv(x, pairs=comm.ring_perm(1), tag=7)
        _, _, [y] = jmpi.testall([r1], tag=8)
        return y

    try:
        spmd_collective(bad, src)
    except Exception as e:
        assert "tag mismatch" in str(e)
    else:
        raise AssertionError("expected trace-time tag mismatch from testall")


# ---------------------------------------------------------------------- #
# persistent plans
# ---------------------------------------------------------------------- #

def case_plans_match_oracle():
    """Every *_init plan's start/wait equals the oracle; a plan restarted
    within one trace reuses the frozen lowering."""
    src = [rand((N * 2, 3), jnp.float32, seed=3 * i + 11) for i in range(N)]
    np_src = [np.asarray(s, np.float64) for s in src]
    tol = _tol(jnp.float32, "", "sum")

    plans = {
        "allreduce": (lambda c, x: c.allreduce_init(_sds(x)),
                      ref.allreduce(np_src, "sum")),
        "bcast": (lambda c, x: c.bcast_init(_sds(x), root=N - 1),
                  ref.bcast(np_src, root=N - 1)),
        "scatter": (lambda c, x: c.scatter_init(_sds(x), root=0),
                    ref.scatter(np_src, root=0)),
        "gather": (lambda c, x: c.gather_init(_sds(x), root=0),
                   ref.allgather(np_src)),
        "allgather": (lambda c, x: c.allgather_init(_sds(x)),
                      ref.allgather(np_src)),
        "alltoall": (lambda c, x: c.alltoall_init(_sds(x)),
                     ref.alltoall(np_src)),
        "reduce_scatter": (lambda c, x: c.reduce_scatter_init(_sds(x)),
                           ref.reduce_scatter(np_src)),
    }
    for name, (make, want) in plans.items():
        def f(x, make=make):
            comm = jmpi.world()
            plan = make(comm, x)
            _, out = jmpi.wait(plan.start(x))
            return out

        got = spmd_collective(f, src)
        _oracle_cmp(got, want, **tol, err_msg=f"plan {name}")

    # restart within one trace: two starts, both correct
    def restart(x):
        comm = jmpi.world()
        plan = comm.allreduce_init(_sds(x))
        _, once = jmpi.wait(plan.start(x))
        _, twice = jmpi.wait(plan.start(once))
        return twice

    got = spmd_collective(restart, src)
    want = ref.allreduce(ref.allreduce(np_src, "sum"), "sum")
    _oracle_cmp(got, want, **tol, err_msg="plan restart")

    # barrier plan: no payload, ops after it still sequence correctly
    def barrier_plan(x):
        comm = jmpi.world()
        bp = comm.barrier_init()
        jmpi.wait(bp.start())
        return jmpi.wait(comm.allreduce_init(_sds(x)).start(x))[1]

    got = spmd_collective(barrier_plan, src)
    _oracle_cmp(got, ref.allreduce(np_src, "sum"), **tol,
                err_msg="barrier plan")


def case_plan_cache_hits_and_shape_misses():
    """Same signature → SAME Plan object (cache hit); a new shape is a cache
    miss building a new plan; starting a plan with the wrong shape is a
    trace-time error."""
    jmpi.plan_cache_clear()
    src = [rand((6, 2), jnp.float32, seed=50 + i) for i in range(N)]

    def f(x):
        comm = jmpi.world()
        p1 = comm.allreduce_init(_sds(x))
        p2 = comm.allreduce_init(_sds(x))          # hit: identical signature
        assert p1 is p2, "identical *_init must return the cached Plan"
        small = x[:3]
        p3 = comm.allreduce_init(_sds(small))      # miss: new shape
        assert p3 is not p1
        try:
            p1.start(small)
            raise AssertionError("plan.start must reject a mismatched shape")
        except ValueError as e:
            assert "frozen for" in str(e)
        _, a = jmpi.wait(p1.start(x))
        _, b = jmpi.wait(p3.start(small))
        return a + jnp.pad(b, ((0, 3), (0, 0)))

    spmd_collective(f, src)
    stats = jmpi.plan_cache_stats()
    assert stats["hits"] >= 1, stats          # p2 lookups
    assert stats["misses"] >= 2, stats        # p1 and p3 builds
    # re-trace with the same signatures: served fully from cache
    before = jmpi.plan_cache_stats()
    spmd_collective(f, src)
    after = jmpi.plan_cache_stats()
    assert after["misses"] == before["misses"], (before, after)
    assert after["hits"] > before["hits"], (before, after)


def case_plan_freezes_algorithm_choice():
    """allreduce_init freezes the algorithm at init: an explicit ring plan
    stays ring in the lowered HLO even when the active policy says native."""
    if N < 2:
        return  # single rank: every algorithm is the identity
    mesh = mesh1d()
    from jax.sharding import PartitionSpec as P

    @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
    def f(x):
        comm = jmpi.world()
        plan = comm.allreduce_init(
            jax.ShapeDtypeStruct(x[0].shape, x[0].dtype), algorithm="ring")
        assert plan.algorithm == "ring"
        _, y = jmpi.wait(plan.start(x[0]))
        return y[None]

    x = jnp.zeros((N, 64), jnp.float32)
    hlo = jax.jit(f).lower(x).as_text()
    assert hlo.count("collective_permute") >= 2 * (N - 1), \
        "ring plan must lower to the ppermute schedule"


# ---------------------------------------------------------------------- #
# operator-coverage bugfix: ring honors all six; unsupported pairs raise
# the uniform trace-time error
# ---------------------------------------------------------------------- #

def case_ring_all_operators_match_oracle():
    for op, name in OP_NAMES.items():
        for dt in (jnp.float32, jnp.int32):
            src = [rand((5, 2), dt, seed=17 * i + 1) for i in range(N)]
            np_src = [np.asarray(s, np.float64) if dt != jnp.int32
                      else np.asarray(s) for s in src]
            want = ref.allreduce(np_src, name)
            got = spmd_collective(
                lambda x, o=op: jmpi.allreduce(x, o, algorithm="ring")[1],
                src)
            _oracle_cmp(got, want, **_tol(dt, "ring", name),
                        err_msg=f"ring {name} {dt}")


def case_unsupported_operator_uniform_error():
    """(algorithm, Operator) pairs the lowering cannot honor raise the
    uniform trace-time error naming both — explicit and fallback paths."""
    src = [rand((4,), jnp.float32, seed=60 + i) for i in range(N)]

    def explicit(x):
        _, y = jmpi.allreduce(x, jmpi.Operator.LAND, algorithm="bf16_wire")
        return y

    try:
        spmd_collective(explicit, src)
    except Exception as e:
        msg = str(e)
        assert "bf16_wire" in msg and "LAND" in msg, msg
    else:
        raise AssertionError("expected uniform operator error (explicit)")

    def fallback(x):  # policy path: xla_native reduce_scatter is SUM-only
        _, y = jmpi.reduce_scatter(jnp.ones((N,), x.dtype) * x.sum(),
                                   jmpi.Operator.PROD)
        return y

    try:
        spmd_collective(fallback, src)
    except Exception as e:
        msg = str(e)
        assert "xla_native" in msg and "PROD" in msg, msg
    else:
        raise AssertionError("expected uniform operator error (fallback)")

    # ring CAN do PROD reduce_scatter now — explicit request must work
    src2 = [rand((N * 2,), jnp.float32, seed=70 + i) for i in range(N)]
    got = spmd_collective(
        lambda x: jmpi.reduce_scatter(x, jmpi.Operator.PROD,
                                      algorithm="ring")[1], src2)
    stacked = np.stack([np.asarray(s, np.float64) for s in src2])
    prod = np.prod(stacked, axis=0)
    want = [prod[i * 2:(i + 1) * 2] for i in range(N)]
    _oracle_cmp(got, want, rtol=1e-4, atol=1e-5, err_msg="ring prod rs")


def case_registry_operator_declarations():
    """Host-side: every registered algorithm either declares no operator
    restriction or the uniform error message names algorithm + operator."""
    from repro.core.operators import Operator
    for op_name in ("allreduce", "reduce_scatter"):
        for algo_name in registry.algorithms(op_name):
            algo = registry.get(op_name, algo_name)
            for red_op in Operator:
                if algo.supports_operator(red_op):
                    continue
                msg = algo.operator_error(red_op)
                assert algo_name in msg and red_op.name in msg
