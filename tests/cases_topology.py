"""Cartesian-topology + neighborhood-collective cases — device-count
agnostic (run under 1, 2 and 8 emulated devices via
tests/test_topology_multidev.py).

Covers the ISSUE-3 edge cases: periodic vs non-periodic ``cart_shift`` at
boundaries (null-rank semantics), ``cart_sub`` on degenerate dims, both
registered lowerings of every neighbor collective against the independent
numpy oracle at n ∈ {1, 2, 8}, plans/i*-forms through the unified Request
model, and the policy-selectable ``hierarchical`` allreduce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as jmpi
from repro.core import compat, ref, registry
from tests.cases_registry import N, mesh1d, rand, spmd_collective

NEIGHBOR_ALGOS = ("xla_native", "ring")


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _cart1d(periodic: bool):
    return jmpi.world().cart_create((N,), periods=(periodic,))


def mesh2d_split():
    """A 2-axis mesh (2, N//2) for node/local two-level cases (N >= 4)."""
    return compat.make_mesh((2, N // 2), ("node", "local"))


def spmd2d(fn, shards):
    """Run ``fn`` per device on a (2, N//2) mesh; returns per-rank outputs
    in row-major rank order (rank = node * (N//2) + local)."""
    mesh = mesh2d_split()

    @jmpi.spmd(mesh, in_specs=P("node", "local"), out_specs=P("node", "local"))
    def run(x):
        return fn(x[0][0])[None, None]

    rows = jnp.stack([jnp.stack(shards[i * (N // 2):(i + 1) * (N // 2)])
                      for i in range(2)])
    out = run(rows)
    return [np.asarray(out[i][j]) for i in range(2) for j in range(N // 2)]


# ---------------------------------------------------------------------- #
# cart_create / coords / rank / shift statics + traced agreement
# ---------------------------------------------------------------------- #

def case_cart_create_round_trip():
    """Static cart_coords/cart_rank invert each other for every rank, and
    the traced per-device coords agree with the static unflattening."""
    src = [rand((3,), jnp.float32, seed=i) for i in range(N)]

    def f(x):
        cart = _cart1d(True)
        for r in range(N):
            coords = cart.cart_coords(r)
            assert cart.cart_rank(coords) == r, (r, coords)
        assert cart.dims == (N,) and cart.ndims == 1
        assert cart.neighbor_count == 2
        (c0,) = cart.cart_coords()
        return x * 0 + jnp.asarray(c0, x.dtype)

    got = spmd_collective(f, src)
    for r in range(N):
        np.testing.assert_allclose(got[r], float(r), err_msg=f"rank {r}")


def case_cart_create_validation():
    """Size mismatches, arity mismatches and non-factoring grids are
    trace-time ValueErrors (static topology discipline)."""
    src = [rand((2,), jnp.float32, seed=i) for i in range(N)]

    def bad(build):
        def f(x):
            build()
            return x
        try:
            spmd_collective(f, src)
        except Exception as e:
            assert "ValueError" in type(e).__name__ or "dims" in str(e) \
                or "periods" in str(e), e
        else:
            raise AssertionError(f"expected trace-time error from {build}")

    bad(lambda: jmpi.world().cart_create((N + 1,)))          # wrong size
    bad(lambda: jmpi.world().cart_create((N,), periods=()))  # arity
    bad(lambda: jmpi.world().cart_create(()))                # empty dims
    if N == 8:  # (4, 2) cannot split a single size-8 axis: 4 is no prefix
        bad(lambda: jmpi.world().cart_create((4, 2)))


def case_cart_shift_null_semantics():
    """Periodic cart_shift wraps at the boundary; non-periodic reports
    PROC_NULL — per rank, against the static neighbor_ranks oracle."""
    src = [rand((2,), jnp.float32, seed=i) for i in range(N)]
    for periodic in (True, False):
        def f(x, periodic=periodic):
            cart = _cart1d(periodic)
            s, d = cart.cart_shift(0, 1)
            return jnp.stack([s, d]).astype(x.dtype) + x[:2] * 0

        got = spmd_collective(f, src)
        for r in range(N):
            if periodic:
                want_src, want_dst = (r - 1) % N, (r + 1) % N
            else:
                want_src = (r - 1) if r > 0 else jmpi.PROC_NULL
                want_dst = (r + 1) if r < N - 1 else jmpi.PROC_NULL
            np.testing.assert_allclose(
                got[r], [want_src, want_dst],
                err_msg=f"rank {r} periodic={periodic}")

    # the static pattern agrees: every in-range pair, boundary pair dropped
    def g(x):
        cart = _cart1d(False)
        pairs = cart.cart_shift_perm(0, 1)
        assert pairs == [(i, i + 1) for i in range(N - 1)], pairs
        wrap = _cart1d(True).cart_shift_perm(0, 1)
        assert wrap == [(i, (i + 1) % N) for i in range(N)], wrap
        nbrs = cart.neighbor_ranks(0)
        assert nbrs[0] == jmpi.PROC_NULL or N == 1, nbrs
        return x

    spmd_collective(g, src)


# ---------------------------------------------------------------------- #
# neighbor collectives vs the numpy oracle (both lowerings, both periods)
# ---------------------------------------------------------------------- #

def case_neighbor_allgather_matches_oracle():
    src = [rand((3, 2), jnp.float32, seed=7 * i + 1) for i in range(N)]
    np_src = [np.asarray(s) for s in src]
    for periodic in (True, False):
        want = ref.neighbor_allgather(np_src, (N,), (periodic,))
        for algo in NEIGHBOR_ALGOS:
            got = spmd_collective(
                lambda x, a=algo, p=periodic: jmpi.wait(
                    _cart1d(p).ineighbor_allgather(x, algorithm=a))[1], src)
            for g, w in zip(got, want):
                np.testing.assert_allclose(
                    g, w, err_msg=f"allgather {algo} periodic={periodic}")


def case_neighbor_alltoall_matches_oracle():
    src = [rand((2, 3), jnp.float32, seed=11 * i + 3) for i in range(N)]
    np_src = [np.asarray(s) for s in src]
    for periodic in (True, False):
        want = ref.neighbor_alltoall(np_src, (N,), (periodic,))
        for algo in NEIGHBOR_ALGOS:
            got = spmd_collective(
                lambda x, a=algo, p=periodic: _cart1d(p).neighbor_alltoall(
                    x, algorithm=a)[1], src)
            for g, w in zip(got, want):
                np.testing.assert_allclose(
                    g, w, err_msg=f"alltoall {algo} periodic={periodic}")


def case_neighbor_alltoall_2d_matches_oracle():
    """2-D (2, N//2) grid: both lowerings vs the coordinate-math oracle."""
    if N < 4:
        return  # needs a genuine 2-D grid
    dims = (2, N // 2)
    src = [rand((4, 3), jnp.float32, seed=13 * i + 5) for i in range(N)]
    np_src = [np.asarray(s) for s in src]
    for periods in ((True, True), (False, True), (False, False)):
        want = ref.neighbor_alltoall(np_src, dims, periods)
        for algo in NEIGHBOR_ALGOS:
            def f(x, a=algo, p=periods):
                cart = jmpi.world().cart_create(dims, periods=p)
                return cart.neighbor_alltoall(x, algorithm=a)[1]

            got = spmd2d(f, src)
            for g, w in zip(got, want):
                np.testing.assert_allclose(
                    g, w, err_msg=f"2d alltoall {algo} periods={periods}")


def case_neighbor_alltoallv_ragged_slots():
    """v-variant: per-slot shapes differ; receive shapes follow the mirror
    slot; contents match the slot-wise oracle."""
    lo = [rand((1, 4), jnp.float32, seed=17 * i + 1) for i in range(N)]
    hi = [rand((2, 4), jnp.float32, seed=17 * i + 2) for i in range(N)]

    def f(x):
        cart = _cart1d(True)
        st, out = cart.neighbor_alltoallv([x[:1], x[1:3]])
        assert out[0].shape == (2, 4), out[0].shape   # mirror of slot 1
        assert out[1].shape == (1, 4), out[1].shape   # mirror of slot 0
        return jnp.concatenate([o.reshape(-1) for o in out])

    src = [jnp.concatenate([a, b], axis=0) for a, b in zip(lo, hi)]
    got = spmd_collective(f, src)
    for r in range(N):
        want = np.concatenate([
            np.asarray(hi[(r - 1) % N]).ravel(),   # from -1: its hi slot
            np.asarray(lo[(r + 1) % N]).ravel(),   # from +1: its lo slot
        ])
        np.testing.assert_allclose(got[r], want, err_msg=f"rank {r}")


# ---------------------------------------------------------------------- #
# jmpi 2.0 surface: i*-forms in mixed waitall, persistent plans
# ---------------------------------------------------------------------- #

def case_ineighbor_unified_requests():
    """ineighbor_* Requests complete through the same waitall as p2p and
    nonblocking-collective requests."""
    src = [rand((2, 3), jnp.float32, seed=23 * i + 1) for i in range(N)]
    np_src = [np.asarray(s) for s in src]

    def f(x):
        comm = jmpi.world()
        cart = comm.cart_create((N,), periods=(True,))
        r1 = cart.ineighbor_alltoall(x, tag=5)
        r2 = comm.isendrecv(x, pairs=comm.ring_perm(1), tag=5)
        r3 = comm.iallreduce(x, tag=5)
        status, [na, shifted, summed] = jmpi.waitall([r1, r2, r3], tag=5)
        assert status == jmpi.SUCCESS
        return na + shifted * 0 + summed * 0

    got = spmd_collective(f, src)
    want = ref.neighbor_alltoall(np_src, (N,), (True,))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w)


def case_neighbor_plans_cache_and_freeze():
    """neighbor_*_init: same signature → same cached Plan; the algorithm is
    frozen at init; a mismatched start is a trace-time error; the v-plan
    round-trips ragged slots."""
    jmpi.plan_cache_clear()
    src = [rand((2, 3), jnp.float32, seed=31 * i + 7) for i in range(N)]
    np_src = [np.asarray(s) for s in src]

    def f(x):
        cart = _cart1d(True)
        p1 = cart.neighbor_alltoall_init(_sds(x), algorithm="ring")
        p2 = cart.neighbor_alltoall_init(_sds(x), algorithm="ring")
        assert p1 is p2, "identical *_init must return the cached Plan"
        assert p1.algorithm == "ring"
        try:
            p1.start(x[:, :1])
            raise AssertionError("plan.start must reject a mismatched shape")
        except ValueError as e:
            assert "frozen for" in str(e)
        _, a = jmpi.wait(p1.start(x))
        pg = cart.neighbor_allgather_init(_sds(x))
        _, g = jmpi.wait(pg.start(x))
        pv = cart.neighbor_alltoallv_init([_sds(x[:1]), _sds(x)])
        _, vs = jmpi.wait(pv.start([x[:1], x]))
        assert vs[0].shape == x.shape and vs[1].shape == x[:1].shape
        return a + g.sum() * 0 + sum(v.sum() for v in vs) * 0

    got = spmd_collective(f, src)
    want = ref.neighbor_alltoall(np_src, (N,), (True,))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w)
    stats = jmpi.plan_cache_stats()
    assert stats["hits"] >= 1, stats
    # re-trace: fully served from the plan cache (no new selections)
    before = jmpi.plan_cache_stats()
    spmd_collective(f, src)
    after = jmpi.plan_cache_stats()
    assert after["misses"] == before["misses"], (before, after)


# ---------------------------------------------------------------------- #
# cart_sub: groups, degenerate dims, all-degenerate error
# ---------------------------------------------------------------------- #

def case_cart_sub_groups_and_degenerate_dims():
    """cart_sub groups rows/cols correctly (group-local allreduce) and a
    degenerate dim backed by a size-1 mesh axis survives cart_sub; a sub
    retaining only axis-less degenerate dims raises."""
    if N >= 4:
        dims = (2, N // 2)
        src = [rand((2,), jnp.float32, seed=41 * i) for i in range(N)]

        def f(x):
            cart = jmpi.world().cart_create(dims, periods=(True, True))
            rows = cart.cart_sub((True, False))   # groups sharing a column
            cols = cart.cart_sub((False, True))   # groups sharing a row
            assert rows.dims == (2,) and cols.dims == (N // 2,)
            assert rows.periods == (True,)
            _, col_sum = cols.allreduce(x)
            return col_sum

        got = spmd2d(f, src)
        half = N // 2
        for r in range(N):
            row = r // half
            want = np.sum([np.asarray(src[row * half + j])
                           for j in range(half)], axis=0)
            np.testing.assert_allclose(got[r], want, rtol=1e-6, atol=1e-6,
                                       err_msg=f"rank {r}")

    # degenerate trailing dim on a 1-axis mesh: (N, 1) has no axis for the
    # second dim — cart_sub keeping only it must raise
    src = [rand((2,), jnp.float32, seed=43 * i) for i in range(N)]

    def g(x):
        cart = jmpi.world().cart_create((N, 1), periods=(True, True))
        if N > 1:  # dim 1 has no backing axis (the single axis feeds dim 0)
            sub = cart.cart_sub((True, False))
            assert sub.dims == (N,)
            try:
                cart.cart_sub((False, True))
                raise AssertionError(
                    "expected all-degenerate cart_sub to raise")
            except ValueError as e:
                assert "degenerate" in str(e)
        # degenerate dim semantics still work without a backing axis
        st, nb = cart.neighbor_allgather(x)
        assert nb.shape == (4,) + x.shape
        return x

    spmd_collective(g, src)


# ---------------------------------------------------------------------- #
# hierarchical two-level allreduce: oracle + policy-table selection
# ---------------------------------------------------------------------- #

def case_hierarchical_allreduce_matches_oracle():
    """reduce-scatter intra-group + allreduce inter-group == plain sum, and
    the lowering is selectable via the policy table (falling back cleanly
    on 1-axis comms)."""
    if N < 4:
        return  # needs >= 2 mesh axes with >= 2 devices each
    src = [rand((N, 3), jnp.float32, seed=47 * i + 1) for i in range(N)]
    np_src = [np.asarray(s, np.float64) for s in src]
    want = ref.allreduce(np_src, "sum")

    got = spmd2d(lambda x: jmpi.allreduce(x, algorithm="hierarchical")[1],
                 src)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    table = registry.PolicyTable(
        rules=[registry.PolicyRule("allreduce", "hierarchical")])
    registry.set_policy(table)
    try:
        def f(x):
            plan = jmpi.world().allreduce_init(_sds(x))
            assert plan.algorithm == "hierarchical", plan.algorithm
            return jmpi.wait(plan.start(x))[1]

        got = spmd2d(f, src)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

        # 1-axis comm: supports() rejects → silent xla_native fallback
        def g1(x):
            plan = jmpi.world().allreduce_init(_sds(x))
            assert plan.algorithm == registry.DEFAULT_ALGORITHM, \
                plan.algorithm
            return jmpi.wait(plan.start(x))[1]

        got = spmd_collective(g1, src)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    finally:
        registry.set_policy(None)


# ---------------------------------------------------------------------- #
# halo exchange rides the topology path end to end
# ---------------------------------------------------------------------- #

def case_halo_exchange_via_neighbor_plan():
    """halo_exchange_2d on a CartComm equals the jnp.roll oracle under both
    neighbor lowerings (plan path, corners included)."""
    from repro.pde.stencil import halo_exchange_2d
    n = 4 * N
    x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
    mesh = mesh1d()

    def pad_oracle(a):
        a = jnp.concatenate([a[-1:], a, a[:1]], axis=0)
        return jnp.concatenate([a[:, -1:], a, a[:, :1]], axis=1)

    for algo in NEIGHBOR_ALGOS:
        @jmpi.spmd(mesh, in_specs=P("ranks"), out_specs=P("ranks"))
        def f(blk, algo=algo):
            cart = jmpi.world().cart_create((N, 1), periods=(True, True))
            return halo_exchange_2d(blk, cart, halo=1, algorithm=algo)[1:-1]

        got = np.asarray(f(x))  # interior rows of each padded block
        want = np.asarray(pad_oracle(x))
        for r in range(N):
            rows = slice(r * (n // N), (r + 1) * (n // N))
            np.testing.assert_allclose(
                got[rows], want[1:-1][rows], err_msg=f"{algo} rank {r}")
