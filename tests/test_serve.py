"""Pytest wrappers for the serving-engine cases (continuous-vs-sequential
bitwise oracle on full-attention and SWA families, the EOS/output-contract
fixes on both engines, paged-vs-dense equivalence through the block-table
datatype view, block/slot recycling, admission under block pressure, the
gather-row/datatype pin, and the scheduler FIFO unit).

Acceptance (ISSUE 6): every case passes for n ∈ {1, 8} emulated devices —
the engine is single-device, so the device count must be irrelevant.  Each
count runs the case module once in its own child process (cached
transcript); the 8-device run is marked slow (quick lane covers 1),
mirroring tests/test_datatypes_multidev.py.
"""

import pytest

from repro.testing import assert_case

pytestmark = pytest.mark.multidev

CASES = [
    "case_continuous_matches_sequential",
    "case_swa_continuous_matches_sequential",
    "case_eos_contract_continuous",
    "case_eos_contract_padded",
    "case_paged_equals_dense",
    "case_block_recycling",
    "case_admission_under_pressure",
    "case_gather_matches_datatype_view",
    "case_scheduler_fifo",
]

N_DEVICES = [1, pytest.param(8, marks=pytest.mark.slow)]


@pytest.mark.parametrize("n", N_DEVICES)
@pytest.mark.parametrize("case", CASES)
def test_serve_case(case, n):
    assert_case("tests.cases_serve", case, n_devices=n)
